"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSONL records.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load(mesh: str) -> list[dict]:
    path = os.path.join(RESULTS, f"dryrun_{mesh}.jsonl")
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"])] = r   # last write wins
    return sorted(recs.values(), key=lambda r: (r["arch"], r["shape"]))


def fmt_ms(t: float) -> str:
    return f"{t * 1e3:.2f}"


def dryrun_table(mesh: str) -> str:
    rows = [
        "| arch | shape | kind | mem/chip GiB | compile s | "
        "FLOPs/chip G | HBM GB/chip | wire GB/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['memory'].get('total_gib', '?')} "
            f"| {r['compile_s']} "
            f"| {ro['hlo_gflops_per_chip']:.1f} "
            f"| {ro['hlo_gbytes_per_chip']:.1f} "
            f"| {ro['wire_gbytes_per_chip']:.2f} |")
    return "\n".join(rows)


def roofline_table(mesh: str) -> str:
    rows = [
        "| arch | shape | t_compute ms | t_memory ms | t_collective ms "
        "| bound | MODEL_GF | useful ratio | step bound s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_ms(ro['t_compute'])} | {fmt_ms(ro['t_memory'])} "
            f"| {fmt_ms(ro['t_collective'])} | **{ro['bottleneck']}** "
            f"| {ro['model_gflops']:.0f} "
            f"| {ro.get('useful_flop_ratio', 0):.3f} "
            f"| {ro['step_time_bound_s']:.3f} |")
    return "\n".join(rows)


def summary(mesh: str) -> str:
    recs = load(mesh)
    if not recs:
        return f"(no records for {mesh})"
    over = [(r["arch"], r["shape"], r["memory"].get("total_gib"))
            for r in recs if r["memory"].get("total_gib", 0) > 16]
    from collections import Counter
    bounds = Counter(r["roofline"]["bottleneck"] for r in recs)
    lines = [f"{len(recs)} cells compiled on {mesh}; "
             f"bottlenecks: {dict(bounds)}"]
    if over:
        lines.append(f"cells over the 16 GiB/chip budget: {over}")
    return "\n".join(lines)


def main() -> None:
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Dry-run, {mesh}\n")
        print(summary(mesh))
        print()
        print(dryrun_table(mesh))
        print(f"\n### Roofline, {mesh}\n")
        print(roofline_table(mesh))


if __name__ == "__main__":
    main()
