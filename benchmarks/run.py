"""Benchmark harness — one function per paper table/figure.

  table1      Graph properties of the scaled Table I stand-ins.
  fig5        Variant comparison (soman -> +multijump -> +atomic ->
              adaptive): wall-clock, host syncs, work counters — the
              paper's Fig. 5 in this container's currency (CPU-backend
              wall-clock is a secondary signal; work counts are primary).
  fig6        Segmentation sweep: speedup + work vs number of segments;
              the paper's Fig. 6 (optimum expected near s = 2|E|/|V|).
  kernels     Pallas kernel microbenches (interpret mode: correctness +
              overhead accounting, not TPU wall-clock — §Roofline covers
              TPU perf).
  batched     Batched-throughput table: a fleet of small graphs through
              the shape-bucketed vmapped engine vs a per-graph loop
              (DESIGN.md §4).
  incremental Incremental-vs-full-recompute table: streaming edge
              insertions absorbed by ``IncrementalCC`` vs a from-scratch
              adaptive run per batch (DESIGN.md §6).
  service     Connectivity-service table: a mixed insert/query stream
              through the multi-tenant registry (policy-routed inserts,
              microbatched on-device queries) vs the recompute-per-query
              counterfactual (DESIGN.md §7). Warm-starts the policy's
              autotune cache (JSON under results/).
  dynamic     Fully-dynamic table (DESIGN.md §9): interleaved
              insert/delete churn through ``DynamicCC`` (tombstone +
              scoped recompute over affected components only) vs a
              full recompute per mutation batch, across delete:insert
              ratios. hook_ops saved is the signal; asserts scoped
              beats full at ratio <= 1:10.
  api         Facade-overhead table (DESIGN.md §10): repro.api.solve
              (plan + policy + registry dispatch) vs the direct engine
              entry on the same DeviceGraph; asserts dispatch adds no
              measurable per-call overhead and plans stay host-only.
  fused       Fused-vs-per-round Pallas backend (DESIGN.md §8): the
              whole segment scan in ONE pallas_call (cc_fused kernel,
              method="pallas_fused") vs one launch per segment hook +
              one per compress sweep, interpret mode on CPU. Launch
              counts are the hardware-independent signal.
  sampled     Sampling-accelerated table (DESIGN.md §13): k-out
              sampling + residue-only scan (``sampled`` /
              ``sampled_fused``) vs the full-scan ``adaptive`` and
              ``pallas_fused`` backends on skewed (soc/kron) and
              road stand-ins; asserts the residue scan pays less than
              the full scan on skewed inputs and that the degree-skew
              policy routes ``auto`` onto/off sampling per class.

Output: CSV blocks on stdout + files under benchmarks/results/; the
batched/incremental/service/fused tables additionally emit one standard
``BENCH {json}`` line per row (machine-scrapable), a
``results/<name>.jsonl``, AND a ``BENCH_<name>.json`` summary at the
REPO ROOT so the perf trajectory is diffable across PRs.
Usage: ``python -m benchmarks.run [--only fig5] [--scale 0.004]``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _block(r):
    import jax
    for leaf in jax.tree.leaves(r):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _bench(fn, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        _block(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _emit(name: str, header: str, rows: list) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(header + "\n")
        for row in rows:
            f.write(",".join(str(x) for x in row) + "\n")
    print(f"\n## {name} -> {path}")
    print(header)
    for row in rows:
        print(",".join(str(x) for x in row))


def _emit_bench(name: str, rows: list[dict]) -> None:
    """Standard BENCH JSON: one ``BENCH {...}`` line per row on stdout
    (scraped by CI/report tooling), a JSONL file under results/, and a
    ``BENCH_<name>.json`` summary at the repo root — the root files are
    committed-adjacent artifacts that make the perf trajectory diffable
    across PRs (CI uploads them)."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.jsonl")
    with open(path, "w") as f:
        for row in rows:
            rec = {"bench": name, **row}
            line = json.dumps(rec)
            f.write(line + "\n")
            print("BENCH " + line)
    summary = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(summary, "w") as f:
        json.dump({"bench": name, "rows": rows}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
    print(f"## {name} -> {path} + {summary}")


def graphs_for_scale(scale: float):
    from repro.graphs.generators import table1_scaled
    return [table1_scaled(name, scale=scale, seed=1)
            for name in ("usa-osm", "euro-osm-karls", "soc-live-journal",
                         "kron-logn21")]


def table1(scale: float) -> None:
    rows = []
    for g in graphs_for_scale(scale):
        s = g.stats()
        rows.append([s["name"], s["nodes"], s["edges"], s["avg_degree"],
                     s["max_degree"], s["size_mb"]])
    _emit("table1", "name,nodes,edges,avg_degree,max_degree,size_mb",
          rows)


def fig5(scale: float) -> None:
    """Fig. 5 analogue. ``soman``/``multijump`` also run under HOST-side
    control flow (the GPU baseline's CPU-GPU round trips, measured,
    via the facade's ``hostloop`` backend); fused variants are one jit.
    Work counters are the hardware-independent signal."""
    from repro.api import Solver
    from repro.core.unionfind import connected_components_oracle

    def hostloop(solver, method):
        plan = solver.plan(backend="hostloop", hostloop_method=method)
        res = plan.run()
        return res.labels, plan.artifacts["hostloop_stats"]

    rows = []
    for g in graphs_for_scale(scale):
        edges, n = g.edges, g.num_nodes
        want = connected_components_oracle(edges, n)
        solver = Solver.open(g)
        for method in ("soman", "multijump", "atomic_hook", "adaptive"):
            res = solver.solve(backend=method)
            assert np.array_equal(np.asarray(res.labels), want), method
            t_fused = _bench(
                lambda m=method: solver.solve(backend=m).labels)
            if method in ("soman", "multijump"):
                t_host = _bench(
                    lambda m=method: hostloop(solver, m)[0], reps=1)
                _, stats = hostloop(solver, method)
                syncs = stats["sync_rounds"]
            else:
                t_host, syncs = t_fused, 1
            w = res.work
            rows.append([
                g.name, method, round(t_host * 1e3, 2),
                round(t_fused * 1e3, 2), syncs,
                int(w.hook_ops), int(w.jump_ops), int(w.jump_sweeps),
                int(w.hook_rounds)])
    _emit("fig5", "graph,method,ms_hostloop,ms_fused,host_syncs,"
          "hook_ops,jump_ops,jump_sweeps,hook_rounds", rows)


def fig6(scale: float) -> None:
    """Segmentation sweep (Fig. 6): speedup over the single-segment
    Atomic-Hook baseline vs number of segments."""
    from repro.api import solve
    from repro.core.segmentation import adaptive_num_segments

    rows = []
    for g in graphs_for_scale(scale):
        edges, n = g.edges, g.num_nodes
        s_star = adaptive_num_segments(g.num_edges, n)
        candidates = sorted({1, max(2, s_star // 4), max(2, s_star // 2),
                             s_star, s_star * 2, s_star * 4})
        t1 = _bench(lambda: solve(
            edges, n, method="adaptive", num_segments=1).labels)
        for s in candidates:
            t = _bench(lambda s=s: solve(
                edges, n, method="adaptive", num_segments=s).labels)
            res = solve(edges, n, method="adaptive", num_segments=s)
            rows.append([g.name, s, int(s == s_star), round(t * 1e3, 2),
                         round(t1 / t, 3), int(res.work.jump_sweeps),
                         int(res.work.hook_ops)])
    _emit("fig6", "graph,segments,is_heuristic,ms,speedup_vs_1seg,"
          "jump_sweeps,hook_ops", rows)


def kernels() -> None:
    import jax.numpy as jnp
    from repro.kernels.embedding_bag import ops as eb, ref as ebr
    from repro.kernels.flash_attention import ops as fa, ref as far
    from repro.kernels.hook import ops as hk, ref as hkr
    from repro.kernels.multi_jump import ops as mj, ref as mjr
    from repro.kernels.segment_reduce import ops as sr, ref as srr

    rng = np.random.default_rng(0)
    rows = []

    q = jnp.asarray(rng.standard_normal((4, 256, 64)), jnp.float32)
    rows.append(["flash_attention", "4x256x64",
                 round(_bench(lambda: fa.flash_attention_pallas(
                     q, q, q, sm_scale=0.125, causal=True,
                     interpret=True), reps=1) * 1e3, 2),
                 round(_bench(lambda: far.ref_attention(
                     q, q, q, sm_scale=0.125, causal=True)) * 1e3, 2)])

    pi = jnp.asarray(np.maximum(np.arange(4096) - 1, 0), jnp.int32)
    rows.append(["multi_jump", "chain-4096",
                 round(_bench(lambda: mj.multi_jump_pallas(
                     pi, interpret=True), reps=1) * 1e3, 2),
                 round(_bench(lambda: mjr.ref_full_compress(pi))
                       * 1e3, 2)])

    edges = jnp.asarray(rng.integers(0, 1024, (4096, 2)), jnp.int32)
    pi0 = jnp.arange(1024, dtype=jnp.int32)
    rows.append(["hook", "V1024-E4096",
                 round(_bench(lambda: hk.hook_pallas(
                     pi0, edges, interpret=True), reps=1) * 1e3, 2),
                 round(_bench(lambda: hkr.ref_hook_round(pi0, edges))
                       * 1e3, 2)])

    vals = jnp.asarray(rng.standard_normal((4096, 32)), jnp.float32)
    ids = jnp.sort(jnp.asarray(rng.integers(0, 256, 4096), jnp.int32))
    rows.append(["segment_reduce", "4096x32-to-256",
                 round(_bench(lambda: sr.segment_reduce_pallas(
                     vals, ids, 256, interpret=True), reps=1) * 1e3, 2),
                 round(_bench(lambda: srr.ref_segment_reduce(
                     vals, ids, 256)) * 1e3, 2)])

    table = jnp.asarray(rng.standard_normal((10000, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 10000, (512, 4)), jnp.int32)
    rows.append(["embedding_bag", "512bagsx4",
                 round(_bench(lambda: eb.embedding_bag_pallas(
                     table, idx, interpret=True), reps=1) * 1e3, 2),
                 round(_bench(lambda: ebr.ref_embedding_bag(
                     table, idx)) * 1e3, 2)])

    _emit("kernels", "kernel,shape,ms_interpret,ms_ref", rows)


def batched() -> None:
    """Batched-throughput table (DESIGN.md §4): a mixed fleet of small
    graphs through the shape-bucketed vmapped adaptive engine vs the
    per-graph jit loop. Labels are asserted bit-identical.

    ``jit_calls`` (device dispatches per fleet) is the primary,
    hardware-independent signal — the per-graph loop pays one dispatch
    per graph, the batched engine one per shape bucket. CPU-backend
    wall-clock does not reward dispatch amortization the way a real
    accelerator does (same caveat as fig5)."""
    from repro.api import Solver, solve
    from repro.core.batch import bucketize
    from repro.graphs.generators import (chain, disjoint_cliques,
                                         grid_road, rmat)

    fleets = {
        "molecules-64": [rmat(5, 3, seed=s) for s in range(64)],
        "mixed-48": ([chain(40 + s) for s in range(16)] +
                     [disjoint_cliques(3, 4 + s % 3, seed=s)
                      for s in range(16)] +
                     [grid_road(8, seed=s) for s in range(16)]),
        "medium-16": [rmat(8, 8, seed=s) for s in range(16)],
    }
    rows = []
    for name, graphs in fleets.items():
        batched_out = Solver.solve_batch(graphs)
        for g, r in zip(graphs, batched_out):
            want = solve(g.edges, g.num_nodes, method="adaptive").labels
            assert np.array_equal(np.asarray(r.labels),
                                  np.asarray(want)), name
        t_loop = _bench(lambda: [solve(
            g.edges, g.num_nodes, method="adaptive").labels
            for g in graphs])
        t_batched = _bench(
            lambda: [r.labels for r in Solver.solve_batch(graphs)])
        n_buckets = len(bucketize([(g.edges, g.num_nodes)
                                   for g in graphs]))
        rows.append({
            "fleet": name, "n_graphs": len(graphs),
            "n_buckets": n_buckets,
            "jit_calls_pergraph": len(graphs),
            "jit_calls_batched": n_buckets,
            "ms_pergraph_loop": round(t_loop * 1e3, 2),
            "ms_batched": round(t_batched * 1e3, 2),
            "speedup": round(t_loop / t_batched, 2),
            "graphs_per_s_batched": round(len(graphs) / t_batched, 1),
        })
    _emit_bench("batched", rows)


def incremental(scale: float) -> None:
    """Incremental-vs-full-recompute table (DESIGN.md §6): absorb a
    stream of edge-insertion batches into a ``Solver`` streaming
    session (policy-routed through the incremental engine) vs running
    the adaptive engine from scratch on the accumulated edge set after
    every batch. hook_ops is the hardware-independent signal."""
    from repro.api import Solver, solve
    from repro.core.unionfind import connected_components_oracle

    rows = []
    for g in graphs_for_scale(scale):
        edges, n = np.asarray(g.edges), g.num_nodes
        rng = np.random.default_rng(0)
        order = rng.permutation(edges.shape[0])
        n_batches = 8
        splits = np.array_split(order, n_batches)

        def run_incremental():
            inc = Solver.open(num_nodes=n)
            for s in splits:
                inc.insert(edges[s])
            return inc

        def run_full():
            ops = 0
            acc = np.zeros((0, 2), np.int32)
            labels = None
            for s in splits:
                acc = np.concatenate([acc, edges[s]], axis=0)
                r = solve(acc, n, method="adaptive")
                ops += int(r.work.hook_ops)
                labels = r.labels
            return ops, labels

        inc = run_incremental()
        full_ops, full_labels = run_full()
        want = connected_components_oracle(edges, n)
        assert np.array_equal(np.asarray(inc.labels), want), g.name
        assert np.array_equal(np.asarray(full_labels), want), g.name
        t_inc = _bench(lambda: run_incremental().labels, reps=2)
        t_full = _bench(lambda: run_full()[1], reps=2)
        rows.append({
            "graph": g.name, "nodes": n, "edges": int(edges.shape[0]),
            "batches": n_batches,
            "ms_incremental": round(t_inc * 1e3, 2),
            "ms_full_recompute": round(t_full * 1e3, 2),
            "speedup": round(t_full / t_inc, 2),
            "hook_ops_incremental": inc.work["hook_ops"],
            "hook_ops_full": full_ops,
            "hook_ops_saved_x": round(full_ops /
                                      max(inc.work["hook_ops"], 1), 2),
        })
    _emit_bench("incremental", rows)


def service(scale: float) -> None:
    """Connectivity-service table (DESIGN.md §7): two live tenants (a
    social/kron R-MAT and a road grid), a mixed stream of insert and
    query requests through the slot-based engine. The counterfactual a
    query service without live labels would pay — one full adaptive
    recompute of the accumulated edge set per query request — is
    measured for real (same engine, same inputs). hook_ops is the
    hardware-independent signal; every service query is answered from
    the live label array (zero recomputes)."""
    from repro import obs
    from repro.api import solve
    from repro.connectivity.policy import AutotuneCache, warm_start
    from repro.connectivity.registry import GraphRegistry
    from repro.connectivity.service import (QUERY_KINDS,
                                            ConnectivityService)
    from repro.core.unionfind import connected_components_oracle
    from repro.graphs.generators import grid_road, rmat

    side = max(8, int((24e6 * scale) ** 0.5))
    sc = max(8, int(np.log2(max(5e6 * scale, 2))))
    tenants = {
        "social": rmat(sc, 7, a=0.45, b=0.22, c=0.22, seed=1,
                       name="social"),
        "road": grid_road(side, extra_prob=0.02, seed=1, name="road"),
    }

    os.makedirs(RESULTS_DIR, exist_ok=True)
    cache_path = os.path.join(RESULTS_DIR, "autotune_cache.json")
    policy_cache = warm_start(tenants.values(), AutotuneCache(cache_path))

    n_rounds, queries_per_round = 6, 4
    pairs_per_query = 64

    def run_stream(collect_counterfactual: bool):
        registry = GraphRegistry(policy_cache=policy_cache)
        svc = ConnectivityService(registry, slots=32)
        rng = np.random.default_rng(0)
        counter_ops = 0
        for name, g in tenants.items():
            registry.create(name, g.num_nodes)
        splits = {name: np.array_split(
            rng.permutation(g.num_edges), n_rounds)
            for name, g in tenants.items()}
        for rnd in range(n_rounds):
            for name, g in tenants.items():
                edges = np.asarray(g.edges)
                svc.submit_insert(name, edges[splits[name][rnd]])
                for _ in range(queries_per_round):
                    pairs = rng.integers(0, g.num_nodes,
                                         (pairs_per_query, 2))
                    svc.submit_query(name, "same_component", pairs)
                svc.submit_query(name, "count_components")
            svc.run()
            if collect_counterfactual:
                for name, g in tenants.items():
                    acc = np.concatenate(
                        [np.asarray(g.edges)[s]
                         for s in splits[name][: rnd + 1]], axis=0)
                    res = solve(acc, g.num_nodes, method="adaptive")
                    counter_ops += (queries_per_round + 1) * int(
                        res.work.hook_ops)
        return svc, counter_ops

    # the stream runs with span tracing + on-device metrics ENABLED —
    # the SLO table below prices the instrumented service, and the
    # trace/SLO exports are the CI artifacts
    tracer = obs.enable(capacity=1 << 14)
    tracer.reset()
    svc, counter_ops = run_stream(True)
    # correctness gate: final labels equal the union-find oracle
    for name, g in tenants.items():
        want = connected_components_oracle(g.edges, g.num_nodes)
        got = np.asarray(svc.registry.get(name).labels)
        assert np.array_equal(got, want), name

    # export the counted run's telemetry before the timed reruns
    # overwrite the ring buffer
    trace_path = os.path.join(RESULTS_DIR, "service_trace.jsonl")
    tracer.export_jsonl(trace_path)
    with open(os.path.join(RESULTS_DIR, "service_slo.json"), "w") as fh:
        json.dump(svc.obs_summary(), fh, indent=1, sort_keys=True)

    t = _bench(lambda: run_stream(False)[0].registry.get(
        "road").labels, reps=2)
    obs.disable()
    service_ops = sum(s["hook_ops"] for s in svc.registry.stats().values())
    assert service_ops < counter_ops, (service_ops, counter_ops)

    def q_ms(quantile, tenant=None):
        return round(svc.slo.percentile(quantile, tenant=tenant,
                                        kinds=QUERY_KINDS) * 1e3, 4)

    counters = tracer.counters
    st = svc.stats
    rows = [{
        "workload": "mixed-insert-query",
        "tenants": len(tenants),
        "rounds": n_rounds,
        "insert_requests": st["inserts_absorbed"],
        "query_requests": st["queries_served"],
        "pairs_answered": st["pairs_answered"],
        "query_calls": st["query_calls"],
        "ms_stream": round(t * 1e3, 2),
        "queries_per_s": round(st["queries_served"] / t, 1),
        "recomputes_avoided": st["recomputes_avoided"],
        "hook_ops_service": service_ops,
        "hook_ops_perquery_recompute": counter_ops,
        "hook_ops_saved_x": round(counter_ops / max(service_ops, 1), 2),
        "autotune_cache": os.path.basename(cache_path),
        # latency SLOs (repro.obs; query kinds only, milliseconds):
        # per-tenant + exact merged global p50/p99
        **{f"p{int(p * 100)}_ms_query_{name}": q_ms(p, name)
           for name in tenants for p in (0.50, 0.99)},
        "p50_ms_query_global": q_ms(0.50),
        "p99_ms_query_global": q_ms(0.99),
        "autotune_hits": counters.get("autotune.hit", 0),
        "autotune_misses": counters.get("autotune.miss", 0),
        "trace_spans": tracer.log.total,
    }]
    _emit_bench("service", rows)


def dynamic(scale: float) -> None:
    """Fully-dynamic table (DESIGN.md §9 + §14): interleaved
    insert/delete churn absorbed by a ``Solver`` streaming session on
    THREE delete designs — the maintained-forest tree-aware route
    (classify against the device-resident forest, short-circuit
    all-non-tree batches, skeleton + crossing reconnection otherwise),
    the plain scoped recompute (PR 5), and the full-recompute
    counterfactual (a from-scratch adaptive run over the survivors
    after EVERY mutation batch) — swept across delete:insert ratios.

    The forest stream runs only on the graphs the policy actually
    routes to it (``tree_edge_ratio <= FOREST_TREE_RATIO``): on
    road-like graphs nearly every edge IS a tree edge, so a skeleton
    the size of the edge set cannot beat the scoped recompute and the
    router sends them down the plain path; their forest columns are
    null. On routed graphs deletes arrive as a stream of micro-batches
    (at most ~9 ticks per round) — the steady-state shape the
    maintained forest is for, and what makes ``tree_hit_ratio``
    meaningful — while unrouted graphs keep the one-batch-per-round
    stream of the PR 5 table.

    hook_ops is the hardware-independent signal; the acceptance bars
    are (a) the forest route billing >= 5x fewer delete-side hook_ops
    than the scoped recompute at 1:20 and 1:10 churn on every routed
    graph, (b) an explicitly all-non-tree batch billing ZERO hook work
    (the lax.cond short-circuit), and (c) the 1:20 forest stream
    beating the scoped stream on wall clock across the routed graphs
    (the BENCH_dynamic smoke gate — the ratio-insensitive ms plateau
    this PR is motivated against). Labels are oracle-checked at the
    end of every stream. The steady-state delete tick's zero-transfer
    property is pinned by the facade/service transfer-guard tests,
    not here."""
    from repro.api import Solver, solve
    from repro.connectivity import policy
    from repro.core.unionfind import DynamicConnectivityOracle

    FOREST, SCOPED = policy.DYNAMIC_DELETE_FOREST, policy.DYNAMIC_DELETE
    n_rounds = 6
    ratios = (0.05, 0.1, 0.25, 1.0)       # delete:insert per round
    smoke_ratio = 0.05                    # the 1:20 wall-clock gate
    micro_batch = 64                      # steady-state delete tick size
    rows = []
    gate_ms = {FOREST: 0.0, SCOPED: 0.0}
    for g in graphs_for_scale(scale):
        edges, n = np.asarray(g.edges, np.int32), g.num_nodes
        order = np.random.default_rng(0).permutation(edges.shape[0])
        splits = np.array_split(order, n_rounds)
        forest_routed = policy.extract_features(
            n, edges.shape[0]).tree_edge_ratio <= policy.FOREST_TREE_RATIO
        forest_dyn = None                 # last counted forest session
        for ratio in ratios:
            # Build the mutation schedule ONCE per (graph, ratio): the
            # per-round insert chunks, the micro-batched kill stream
            # (drawn from the oracle's evolving live set), the
            # full-recompute counterfactual bill (route-independent —
            # it depends only on the mutation stream), and the expected
            # end labels. Timed replays below then drive ONLY the
            # solver, so the forest-vs-scoped wall comparison measures
            # engine work rather than shared oracle bookkeeping.
            rng = np.random.default_rng(1)
            oracle = DynamicConnectivityOracle(n)
            sched = []
            full_ops = 0
            for s in splits:
                chunk = edges[s]
                oracle.insert(chunk)
                r = solve(oracle.alive(), n, method="adaptive")
                full_ops += int(r.work.hook_ops)
                k = max(1, int(round(ratio * chunk.shape[0])))
                live = oracle.alive()
                kills = live[rng.integers(0, live.shape[0], k)]
                # routed graphs: stream the round quota in bounded
                # micro-batches; both routes replay the SAME ticks
                step = max(micro_batch, -(-k // 8)) if forest_routed \
                    else k
                batches = [kills[lo:lo + step] for lo in range(0, k, step)]
                for batch in batches:
                    oracle.delete(batch)
                r = solve(oracle.alive(), n, method="adaptive")
                full_ops += int(r.work.hook_ops)
                sched.append((chunk, batches))
            want_labels = oracle.labels()

            def run_stream(route: str, count_deletes: bool = False):
                dyn = Solver.open(num_nodes=n, delete_route=route)
                del_ops = 0
                for chunk, batches in sched:
                    dyn.insert(chunk)
                    if route == FOREST:
                        # the bulk first insert adopts (forest stales);
                        # repair on the insert side so delete billing
                        # prices the steady state, not the one-off
                        dyn.state.ensure_forest()
                    for batch in batches:
                        if count_deletes:
                            before = dyn.work["hook_ops"]
                        dyn.delete(batch)
                        if count_deletes:
                            del_ops += dyn.work["hook_ops"] - before
                return dyn, del_ops

            sdyn, scoped_del_ops = run_stream(SCOPED, count_deletes=True)
            assert np.array_equal(np.asarray(sdyn.labels),
                                  want_labels), g.name
            scoped_ops = sdyn.work["hook_ops"]
            t_scoped = _bench(
                lambda: np.asarray(run_stream(SCOPED)[0].labels),
                reps=2 if ratio == smoke_ratio else 1)
            forest_ops = forest_del_ops = tree_hit_ratio = None
            t_forest = None
            if forest_routed:
                fdyn, forest_del_ops = run_stream(
                    FOREST, count_deletes=True)
                assert np.array_equal(np.asarray(fdyn.labels),
                                      want_labels), g.name
                forest_ops = fdyn.work["hook_ops"]
                rc = fdyn.state.delete_route_counts()
                ticks = rc["nontree_shortcircuit"] + rc["tree_scoped"]
                tree_hit_ratio = rc["tree_scoped"] / max(ticks, 1)
                if ratio <= 0.1:          # the ISSUE 9 bar: >= 5x
                    assert forest_del_ops * 5 <= scoped_del_ops, \
                        (g.name, ratio, forest_del_ops, scoped_del_ops)
                t_forest = _bench(
                    lambda: np.asarray(run_stream(FOREST)[0].labels),
                    reps=2 if ratio == smoke_ratio else 1)
                if ratio == smoke_ratio:
                    gate_ms[FOREST] += t_forest
                    gate_ms[SCOPED] += t_scoped
                forest_dyn = fdyn
            engine_ops = forest_ops if forest_routed else scoped_ops
            t_engine = t_forest if forest_routed else t_scoped
            if ratio <= 0.1:    # the PR-5 bar, on the routed engine:
                # under micro-batched churn the E-wide scoped baseline
                # legitimately loses to full recompute — the plateau
                # the maintained forest removes
                assert engine_ops < full_ops, (g.name, ratio,
                                               engine_ops, full_ops)
            rows.append({
                "graph": g.name, "nodes": n,
                "edges_inserted": int(edges.shape[0]),
                "rounds": n_rounds,
                "delete_insert_ratio": ratio,
                "edges_deleted": int(sdyn.state.num_edges_deleted),
                "partition_changes": int(sdyn.version),
                "forest_routed_by_policy": forest_routed,
                "tree_hit_ratio": None if tree_hit_ratio is None
                else round(tree_hit_ratio, 4),
                "ms_stream": round(t_engine * 1e3, 2),
                "ms_stream_scoped": round(t_scoped * 1e3, 2),
                "hook_ops_dynamic": engine_ops,
                "hook_ops_deletes_forest": forest_del_ops,
                "hook_ops_deletes_scoped": scoped_del_ops,
                "hook_ops_full_recompute": full_ops,
                "hook_ops_saved_x": round(full_ops / max(engine_ops, 1), 2),
                "delete_hook_ops_saved_x": None if forest_del_ops is None
                else round(scoped_del_ops / max(forest_del_ops, 1), 2),
            })

        # the all-non-tree short-circuit bills ZERO hook work: kill a
        # batch drawn from the alive NON-forest edges of the last
        # counted forest session (host set-difference against the
        # maintained forest) and assert the counters did not move
        if forest_dyn is not None:
            st = forest_dyn.state
            st.ensure_forest()
            parents = np.asarray(st.forest[0])
            tree = {tuple(sorted(map(int, parents[r])))
                    for r in np.flatnonzero(parents[:, 0] >= 0)}
            log_e = np.asarray(st.log.edges)[:st.log.rows]
            log_a = np.asarray(st.log.alive)[:st.log.rows]
            alive_pairs = {tuple(sorted(map(int, e)))
                           for e, a in zip(log_e, log_a) if a}
            non_tree = sorted(alive_pairs - tree)[:16]
            if non_tree:
                before = forest_dyn.work["hook_ops"]
                forest_dyn.delete(np.asarray(non_tree, np.int32))
                assert forest_dyn.work["hook_ops"] == before, g.name

    # BENCH_dynamic smoke gate: at 1:20 churn the forest route must
    # beat the scoped recompute on wall clock across the routed graphs
    if gate_ms[SCOPED]:
        assert gate_ms[FOREST] < gate_ms[SCOPED], gate_ms
    _emit_bench("dynamic", rows)


def fused(scale: float) -> None:
    """Fused-vs-per-round Pallas backend (DESIGN.md §8). The per-round
    backend launches one hook kernel per segment plus one multi_jump
    kernel per compress sweep (``num_segments + jump_sweeps`` per
    segment scan); the fused ``cc_fused`` kernel runs the whole scan in
    ONE pallas_call with scalar-prefetched segment boundaries. Launch
    counts are the hardware-independent signal — CPU interpret-mode
    wall-clock (reported for completeness) does not price launch
    overhead the way a real accelerator does."""
    import jax.numpy as jnp
    from repro.api import Solver, solve
    from repro.core import rounds as R
    from repro.core.segmentation import plan_segmentation
    from repro.core.unionfind import connected_components_oracle
    from repro.kernels.cc_fused.ops import fused_segment_scan

    rows = []
    for g in graphs_for_scale(scale):
        edges, n = g.edges, g.num_nodes
        plan = plan_segmentation(g.num_edges, n)
        want = connected_components_oracle(edges, n)
        solver = Solver.open(g)
        fused_res = solver.solve(backend="pallas_fused")
        assert np.array_equal(np.asarray(fused_res.labels), want), g.name
        assert np.array_equal(
            np.asarray(solver.solve(backend="pallas",
                                    interpret=True).labels),
            want), g.name
        # SCAN-ONLY sweep count from the fused kernel's per-segment
        # counters (bit-compatible with the jnp composition) — the
        # trailing cleanup rounds cost extra launches on BOTH backends
        # and are excluded so the per-scan ratio is honest
        segs = R.pad_and_segment(
            jnp.asarray(np.asarray(edges), jnp.int32).reshape(-1, 2),
            plan)
        counts = R.segment_true_counts(plan.num_edges, plan)
        pi0 = jnp.arange(n, dtype=jnp.int32)
        _, sweeps = fused_segment_scan(pi0, segs, counts, interpret=True)
        scan_sweeps = int(sweeps.sum())
        # time BOTH backends in interpret mode (the fused public path
        # resolves interpret from the backend, which on a TPU host
        # would compare a compiled kernel against the emulated
        # baseline under a column name claiming otherwise)
        from repro.core.cc import _cc_fused_jit
        ej = jnp.asarray(np.asarray(edges), jnp.int32).reshape(-1, 2)
        t_perround = _bench(lambda: solver.solve(
            backend="pallas", interpret=True).labels, reps=1)
        t_fused = _bench(lambda: _cc_fused_jit(
            ej, None, num_nodes=n, num_segments=plan.num_segments,
            lift_steps=2, interpret=True).labels, reps=1)
        launches_old = plan.num_segments + scan_sweeps
        rows.append({
            "graph": g.name, "nodes": n, "edges": g.num_edges,
            "num_segments": plan.num_segments,
            "scan_jump_sweeps": scan_sweeps,
            # 1 hook launch/segment + 1 multi_jump launch/sweep
            "launches_perround_scan": launches_old,
            "launches_fused_scan": 1,
            "launch_reduction_x": launches_old,
            "ms_perround_interpret": round(t_perround * 1e3, 2),
            "ms_fused_interpret": round(t_fused * 1e3, 2),
            "hook_ops": int(fused_res.work.hook_ops),
        })
    _emit_bench("fused", rows)


def sampled(scale: float) -> None:
    """Sampling-accelerated table (DESIGN.md §13): the k-out sampling
    phase + residue-only adaptive scan (``sampled`` / ``sampled_fused``)
    vs the full-scan jnp ``adaptive`` and ``pallas_fused`` backends, on
    the Table I stand-ins. The skewed classes (soc/kron R-MATs) are the
    sampling phase's home turf — two cheap k-out rounds collapse the
    giant component and the expensive scan touches only the residue;
    the road grids are the contrast rows where sampling does NOT pay
    and the degree-skew policy keeps ``auto`` off it. hook_ops is the
    hardware-independent signal (Pallas wall-clock is interpret-mode,
    same caveat as the fused table)."""
    from repro.api import Solver
    from repro.connectivity.policy import AutotuneCache
    from repro.core.unionfind import connected_components_oracle

    skewed_classes = {"soc-live-journal", "kron-logn21"}
    rows = []
    for g in graphs_for_scale(scale):
        is_skewed = g.name in skewed_classes
        want = connected_components_oracle(g.edges, g.num_nodes)
        solver = Solver.open(g, policy_cache=AutotuneCache())

        res = {}
        ms = {}
        for backend in ("adaptive", "sampled", "sampled_fused"):
            res[backend] = solver.solve(backend=backend)
            assert np.array_equal(np.asarray(res[backend].labels),
                                  want), (g.name, backend)
            if backend == "sampled":
                stats = dict(solver.last_plan.artifacts["sampled_stats"])
            ms[backend] = _bench(
                lambda b=backend: solver.solve(backend=b).labels,
                reps=1 if backend == "sampled_fused" else 2)
        res["pallas_fused"] = solver.solve(backend="pallas_fused")
        assert np.array_equal(np.asarray(res["pallas_fused"].labels),
                              want), g.name
        ms["pallas_fused"] = _bench(
            lambda: solver.solve(backend="pallas_fused").labels, reps=1)

        full_ops = int(res["adaptive"].work.hook_ops)
        samp_ops = int(res["sampled"].work.hook_ops)
        # phase billing folds exactly into the total (bit-exact gate)
        assert stats["sample_hook_ops"] + stats["residue_hook_ops"] \
            == samp_ops, g.name
        # the satellite's acceptance signal: on skewed inputs the
        # sampling phase shrinks the scan — the residue pays less than
        # the full scan did, and the TOTAL (sampling included) wins too
        if is_skewed:
            assert stats["residue_hook_ops"] < full_ops, (
                g.name, stats["residue_hook_ops"], full_ops)
            assert samp_ops < full_ops, (g.name, samp_ops, full_ops)
        # ...and the degree-skew feature routes "auto" accordingly
        auto = solver.plan().backend
        if is_skewed:
            assert auto == "sampled", (g.name, auto)
        else:
            assert auto != "sampled", (g.name, auto)

        rows.append({
            "graph": g.name, "nodes": g.num_nodes, "edges": g.num_edges,
            "skewed": int(is_skewed),
            "auto_backend": auto,
            "ms_adaptive": round(ms["adaptive"] * 1e3, 2),
            "ms_pallas_fused_interpret":
                round(ms["pallas_fused"] * 1e3, 2),
            "ms_sampled": round(ms["sampled"] * 1e3, 2),
            "ms_sampled_fused_interpret":
                round(ms["sampled_fused"] * 1e3, 2),
            "hook_ops_adaptive": full_ops,
            "hook_ops_sampled": samp_ops,
            "hook_ops_saved_x": round(full_ops / max(samp_ops, 1), 2),
            "sample_hook_ops": stats["sample_hook_ops"],
            "residue_hook_ops": stats["residue_hook_ops"],
            "n_residue": stats["n_residue"],
            "giant_size": stats["giant_size"],
        })
    _emit_bench("sampled", rows)


def api(scale: float) -> None:
    """Facade-overhead table (DESIGN.md §10): ``repro.api.solve``
    (plan construction + policy lookup + registry dispatch) vs calling
    the engine entry (``cc.solve_static``) directly on the SAME
    pre-coerced DeviceGraph. The facade's per-call cost is pure host
    Python — planning is also timed standalone (µs) to show it never
    touches the device. Asserts dispatch adds no measurable per-call
    overhead (way under the noise floor of one jitted solve)."""
    from repro import obs
    from repro.api import Solver, solve
    from repro.core import cc as cc_mod
    from repro.graphs.device import as_device_graph

    # disabled-mode tracing cost: one no-op span (flag check + shared
    # null context manager), measured standalone so the <=5% gate below
    # is deterministic instead of a wall-clock diff in CI-runner noise
    obs.disable()
    noop_reps = 100_000
    t0 = time.perf_counter()
    for _ in range(noop_reps):
        with obs.span("noop", backend="adaptive", reason="forced",
                      bucket="v0_e0"):
            pass
    noop_span_ns = (time.perf_counter() - t0) / noop_reps * 1e9

    rows = []
    for g in graphs_for_scale(scale):
        dg = as_device_graph(g)
        solver = Solver.open(dg)
        t_direct = _bench(lambda: cc_mod.solve_static(
            dg, method="adaptive").labels, reps=5)
        t_facade = _bench(lambda: solver.solve("adaptive").labels,
                          reps=5)
        # instrumented column: same dispatch with span tracing ON
        tracer = obs.enable(capacity=1 << 15)
        t_traced = _bench(lambda: solver.solve("adaptive").labels,
                          reps=5)
        tracer.log.clear()
        solver.solve("adaptive").labels.block_until_ready()
        spans_per_solve = len(tracer.log)
        obs.disable()
        # planning alone: host metadata only (µs-scale)
        reps = 200
        t0 = time.perf_counter()
        for _ in range(reps):
            solver.plan("adaptive")
        plan_us = (time.perf_counter() - t0) / reps * 1e6
        overhead_ms = (t_facade - t_direct) * 1e3
        # "no measurable overhead": the deterministic signal is the
        # plan's host-only cost (µs-scale); the wall-clock ratio gate
        # is deliberately loose — shared CI runners jitter, and every
        # other table in this file gates on deterministic counters
        assert plan_us < 2000, (g.name, plan_us)
        assert t_facade <= t_direct * 2.5 + 5e-3, (g.name, t_facade,
                                                   t_direct)
        # the PR-7 overhead gate: disabled-mode tracing (no-op spans x
        # instrumented sites on this dispatch) must cost <= 5% of the
        # facade call — upper-bounded from the standalone no-op cost,
        # so the gate cannot pass by timing luck
        disabled_obs_pct = 100 * (noop_span_ns * spans_per_solve) \
            / max(t_facade * 1e9, 1e-9)
        assert disabled_obs_pct <= 5.0, (g.name, disabled_obs_pct,
                                         noop_span_ns, spans_per_solve)
        rows.append({
            "graph": g.name, "nodes": g.num_nodes, "edges": g.num_edges,
            "ms_direct_engine": round(t_direct * 1e3, 3),
            "ms_facade": round(t_facade * 1e3, 3),
            "ms_facade_traced": round(t_traced * 1e3, 3),
            "overhead_ms": round(overhead_ms, 3),
            "overhead_pct": round(100 * overhead_ms /
                                  max(t_direct * 1e3, 1e-9), 1),
            "plan_us": round(plan_us, 1),
            "spans_per_solve": spans_per_solve,
            "noop_span_ns": round(noop_span_ns, 1),
            "disabled_obs_pct": round(disabled_obs_pct, 3),
        })
    _emit_bench("api", rows)


def fleet(scale: float) -> None:
    """Fleet serving table (DESIGN.md §15): the SAME mixed multi-tenant
    open-loop arrival stream driven through (a) the mesh-wide
    ``FleetService`` — per-device shards, cross-tenant batched query
    kernels, double-buffered pipelined ticks, one sharded whale tenant
    across the whole mesh — and (b) the single-device
    ``ConnectivityService`` baseline holding every tenant. Open loop:
    the schedule is pre-drawn and advanced by TICK, not by completion,
    so arrival pressure is identical for both paths and queue wait is
    part of the measured latency.

    Acceptance gate (ISSUE 10): on an 8-device mesh the fleet's
    aggregate request throughput must be >= 2x the single-device
    service on the same workload. The win on a host-parallelism-free
    CPU mesh comes from dispatch structure, not cores: the baseline
    pays one kernel launch + one version sync + one device->host
    materialization per (tenant, kind) group per tick, the fleet pays
    ~one stacked launch per (shard, kind, |V|) group and syncs a whole
    tick's answers one tick later. Query answers are cross-checked
    request-by-request between the two paths, and final labels against
    the union-find oracle."""
    import jax
    from repro import obs
    from repro.connectivity.service import (QUERY_KINDS,
                                            ConnectivityService)
    from repro.core.unionfind import connected_components_oracle
    from repro.fleet import FleetService

    n_dev = len(jax.devices())
    names = [f"t{i:04d}" for i in range(128 * n_dev)]
    n = max(64, int(2e5 * scale))
    whale_nodes = max(1 << 11, 4 * n)
    ticks, pairs_per_q, ins_edges = 6, 128, 24

    rng = np.random.default_rng(0)
    base_edges = {t: rng.integers(0, n, (n // 2, 2)).astype(np.int32)
                  for t in names}
    whale_edges = np.stack(
        [np.arange(4 * n, dtype=np.int32),
         np.arange(1, 4 * n + 1, dtype=np.int32)], axis=1)
    # pre-drawn open-loop arrivals, per tick: query-heavy with a
    # round-robin trickle of inserts (each tick a different slice of
    # tenants mutates). Both serving planes coalesce per (tenant,
    # kind), so per tick the baseline dispatches one kernel per tenant
    # per query kind while the fleet dispatches one STACKED kernel per
    # shard per query kind — the tenants-per-device ratio is the
    # dispatch-amplification the fleet removes.
    schedule = []
    for tick in range(ticks):
        arrivals = []
        for i, t in enumerate(names):
            if i % 256 == tick % 256:
                arrivals.append((t, "insert", rng.integers(
                    0, n, (ins_edges, 2)).astype(np.int32)))
            arrivals.append((t, "same_component", rng.integers(
                0, n, (pairs_per_q, 2)).astype(np.int32)))
            arrivals.append((t, "component_size", rng.integers(
                0, n, (pairs_per_q,)).astype(np.int32)))
        arrivals.append(("whale", "same_component", rng.integers(
            0, whale_nodes, (pairs_per_q, 2)).astype(np.int32)))
        schedule.append(arrivals)
    n_requests = sum(len(a) for a in schedule)

    probe = np.zeros((pairs_per_q, 2), np.int32)

    def preload(submit, submit_insert, run):
        for t in names:
            submit_insert(t, base_edges[t])
        submit_insert("whale", whale_edges)
        run()
        # one probe query per tenant: resolves every label array and
        # (fleet path) builds the cached label planes, so the timed
        # stream starts from serving steady state on BOTH paths
        for t in names:
            submit(t, "same_component", probe)
        submit("whale", "same_component", probe)
        run()

    def drive(submit, step, run):
        """Replay the open-loop schedule; returns {(tenant, kind, i):
        i-th answer of that kind} so the two paths cross-check exactly.
        Request uids are per-shard (not fleet-global), but retirement
        is FIFO per (tenant, kind), so the sequence number is a stable
        key even though the two paths interleave kinds differently."""
        retired = []
        for arrivals in schedule:
            for t, kind, payload in arrivals:
                submit(t, kind, payload)
            retired.extend(step())
        retired.extend(run())
        answers, seq = {}, {}
        for r in retired:
            assert r.error is None, (r.tenant, r.kind, r.error)
            if r.kind in QUERY_KINDS:
                i = seq.get((r.tenant, r.kind), 0)
                seq[(r.tenant, r.kind)] = i + 1
                answers[(r.tenant, r.kind, i)] = np.asarray(r.result)
        return answers

    shared_runners = []   # one compiled shard_map cache, every rep

    def build_fleet():
        fs = FleetService(slots_per_device=1024, rebalance_every=0,
                          shard_threshold=whale_nodes,
                          runners=shared_runners[0] if shared_runners
                          else None)
        if not shared_runners:
            shared_runners.append(fs.runners)
        for t in names:
            fs.admit(t, n, expected_edges=n)
        fs.admit("whale", whale_nodes, expected_edges=4 * n)
        assert fs.placement_of("whale") == "mesh"
        preload(fs.submit, fs.submit_insert, fs.run)
        return fs

    def build_single():
        svc = ConnectivityService(slots=4096)
        for t in names:
            svc.registry.create(t, n)
        svc.registry.create("whale", whale_nodes)
        preload(svc.submit, svc.submit_insert, svc.run)
        return svc

    def run_fleet():
        fs = build_fleet()
        return fs, drive(fs.submit, fs.step, fs.run)

    def run_single():
        svc = build_single()
        return svc, drive(svc.submit, svc.step, svc.run)

    def bench_streams(reps: int = 5):
        """Median wall time of the serving STREAM only, for both
        paths. A fresh service is built and preloaded per rep (tenant
        state mutates during the stream) but admission + bulk load are
        setup, not arrival traffic, so they stay outside the clock.
        The two paths' reps INTERLEAVE so machine-wide drift between
        measurement blocks cancels out of the throughput ratio, and
        the reported speedup is the MEDIAN OF PER-REP PAIRWISE ratios
        — each ratio compares adjacent-in-time runs, so slow-machine
        episodes hit both sides of the division."""
        ts = {"fleet": [], "single": []}
        for _ in range(reps):
            for label, build in (("fleet", build_fleet),
                                 ("single", build_single)):
                svc = build()
                t0 = time.perf_counter()
                drive(svc.submit, svc.step, svc.run)
                ts[label].append(time.perf_counter() - t0)
        ratio = float(np.median([s / f for f, s in
                                 zip(ts["fleet"], ts["single"])]))
        return (float(np.median(ts["fleet"])),
                float(np.median(ts["single"])), ratio)

    # warmup pass, identical shapes: compiles every kernel (including
    # the whale's shard_map program) so neither the counted SLO run
    # nor the timed reps pay compile time
    run_fleet()
    run_single()

    # counted run, tracing on: SLO percentiles + correctness
    tracer = obs.enable(capacity=1 << 14)
    tracer.reset()
    fs, fleet_answers = run_fleet()
    _, single_answers = run_single()
    assert fleet_answers.keys() == single_answers.keys()
    for k in fleet_answers:
        np.testing.assert_array_equal(fleet_answers[k],
                                      single_answers[k], err_msg=str(k))
    # oracle gate on one packed tenant + the sharded whale
    t0 = names[0]
    acc = np.concatenate([base_edges[t0]] + [
        p for a in schedule for (t, kind, p) in a
        if t == t0 and kind == "insert"])
    shard = fs.shards[fs.placement_of(t0)]
    np.testing.assert_array_equal(
        np.asarray(shard.registry.get(t0).labels),
        connected_components_oracle(acc, n))
    np.testing.assert_array_equal(
        np.asarray(fs._sharded["whale"].labels),
        connected_components_oracle(whale_edges, whale_nodes))
    fleet_slo = fs.slo()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "fleet_slo.json"), "w") as fh:
        json.dump(fs.slo_summary(), fh, indent=1, sort_keys=True)
    obs.disable()

    # timed runs, tracing off for both paths (identical settings)
    t_fleet, t_single, throughput_x = bench_streams()
    tput_fleet = n_requests / t_fleet
    tput_single = n_requests / t_single

    def q_ms(quantile):
        return round(fleet_slo.percentile(
            quantile, kinds=("same_component",)) * 1e3, 4)

    def tenant_q_ms(tenant):
        # per-tenant query percentiles for the BENCH row: one
        # representative packed tenant + the sharded whale (the full
        # per-tenant table is results/fleet_slo.json)
        return {q: round(fleet_slo.percentile(
                    p, tenant=tenant, kinds=("same_component",)) * 1e3, 4)
                for q, p in (("p50_ms", 0.50), ("p99_ms", 0.99))}

    engine = fs.engine.stats
    rows = [{
        "workload": "open-loop-mixed",
        "devices": n_dev,
        "tenants": len(names) + 1,
        "sharded_tenants": 1,
        "ticks": ticks,
        "requests": n_requests,
        "ms_fleet": round(t_fleet * 1e3, 2),
        "ms_single_device": round(t_single * 1e3, 2),
        "requests_per_s_fleet": round(tput_fleet, 1),
        "requests_per_s_single": round(tput_single, 1),
        "throughput_x": round(throughput_x, 2),
        "batched_dispatches": engine["batched_dispatches"],
        "query_calls_fleet": sum(s.stats["query_calls"]
                                 for s in fs.shards),
        "runner_cache": dict(fs.runners.stats),
        "p50_ms_query_fleet": q_ms(0.50),
        "p99_ms_query_fleet": q_ms(0.99),
        "per_tenant_query_ms": {t: tenant_q_ms(t)
                                for t in (names[0], "whale")},
        "per_tenant_slo_table": "results/fleet_slo.json",
    }]
    # the ISSUE 10 acceptance bar: >= 2x aggregate throughput on the
    # 8-device mesh (single-device runs report the ratio, no gate)
    if n_dev >= 8:
        assert throughput_x >= 2.0, rows
    _emit_bench("fleet", rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["table1", "fig5", "fig6", "kernels",
                             "batched", "incremental", "service",
                             "dynamic", "fused", "sampled", "api",
                             "fleet"])
    ap.add_argument("--scale", type=float, default=1 / 256,
                    help="Table I graph scale factor")
    args = ap.parse_args()
    jobs = {"table1": lambda: table1(args.scale),
            "fig5": lambda: fig5(args.scale),
            "fig6": lambda: fig6(args.scale),
            "kernels": kernels,
            "batched": batched,
            "incremental": lambda: incremental(args.scale),
            "service": lambda: service(args.scale),
            "dynamic": lambda: dynamic(args.scale),
            "fused": lambda: fused(args.scale),
            "sampled": lambda: sampled(args.scale),
            "api": lambda: api(args.scale),
            "fleet": lambda: fleet(args.scale)}
    for name, job in jobs.items():
        if args.only and name != args.only:
            continue
        job()


if __name__ == "__main__":
    main()
