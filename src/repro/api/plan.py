"""ExecutionPlan — the adaptive decision as an inspectable object.

The paper's contribution is picking the right CC schedule per input;
before this module that decision vanished inside ``method="auto"``
string plumbing. ``Solver.plan()`` reifies it: which backend runs,
why (forced override / measured autotune winner / the paper's
heuristic), which power-of-two shape bucket the graph lands in (the
jit-cache and autotune key), the segmentation plan (s = 2|E|/|V|),
and the predicted per-round work. ``plan.explain()`` renders it for
humans; ``plan.run()`` executes it through the ``BACKENDS`` registry.

A plan is cheap host metadata and performs no host<->device transfers,
so the steady-state mutation paths can plan under
``jax.transfer_guard("disallow")``. On a static, device-resident
session planning touches the device not at all; on a live streaming
session the plan captures the log's compacted alive view, which lazily
enqueues one on-device compaction program (still transfer-free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.segmentation import SegmentationPlan


@dataclasses.dataclass
class ExecutionPlan:
    """One routed execution: backend choice + everything that drove it."""

    backend: str                   # BACKENDS key that will run
    reason: str                    # forced | autotune | heuristic | policy | sharded
    num_nodes: int
    num_edges: int                 # true edges when statically known
    bucket: tuple                  # pow2 (V_pad, E_pad) — jit/autotune key
    segmentation: Optional[SegmentationPlan]
    lift_steps: int = 2
    num_segments: Optional[int] = None      # caller override (None = heuristic)
    graph: Any = dataclasses.field(default=None, repr=False)
    graphs: Any = dataclasses.field(default=None, repr=False)   # batched plans
    opts: dict = dataclasses.field(default_factory=dict, repr=False)
    predicted: dict = dataclasses.field(default_factory=dict)
    artifacts: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def bucket_key(self) -> str:
        """The autotune-cache spelling of the shape bucket."""
        return f"v{self.bucket[0]}_e{self.bucket[1]}"

    def run(self):
        """Execute through the registered backend; returns its
        ``CCResult`` (a list of them for batched plans). Extra outputs
        land in ``self.artifacts``."""
        from repro.api.registry import get_backend
        return get_backend(self.backend).run(self)

    def explain(self) -> str:
        """Human-readable account of the adaptive decision."""
        from repro.api.registry import BACKENDS
        lines = [f"plan: backend={self.backend} ({self.reason})"]
        if self.graphs is not None:
            lines.append(f"  batch: {len(self.graphs)} graphs, "
                         f"total |E|={self.num_edges}")
        density = 2.0 * self.num_edges / max(self.num_nodes, 1)
        lines.append(f"  graph: |V|={self.num_nodes} |E|={self.num_edges} "
                     f"density={density:.2f} bucket={self.bucket_key}")
        s = self.segmentation
        if s is not None:
            src = "override" if self.num_segments is not None \
                else "s=2|E|/|V| heuristic"
            lines.append(f"  segmentation: {s.num_segments} segment(s) x "
                         f"{s.segment_size} edges (padded {s.padded_edges}"
                         f"; {src})")
        if self.predicted:
            lines.append("  predicted: " + " ".join(
                f"{k}={v}" for k, v in sorted(self.predicted.items())))
        backend = BACKENDS.get(self.backend)
        if backend is not None:
            lines.append(f"  capabilities: "
                         f"{backend.capabilities.describe()}")
        return "\n".join(lines)
