"""ExecutionPlan — the adaptive decision as an inspectable object.

The paper's contribution is picking the right CC schedule per input;
before this module that decision vanished inside ``method="auto"``
string plumbing. ``Solver.plan()`` reifies it: which backend runs,
why (forced override / measured autotune winner / the paper's
heuristic), which power-of-two shape bucket the graph lands in (the
jit-cache and autotune key), the segmentation plan (s = 2|E|/|V|),
and the predicted per-round work. ``plan.explain()`` renders it for
humans; ``plan.run()`` executes it through the ``BACKENDS`` registry.

A plan is cheap host metadata and performs no host<->device transfers,
so the steady-state mutation paths can plan under
``jax.transfer_guard("disallow")``. On a static, device-resident
session planning touches the device not at all; on a live streaming
session the plan captures the log's compacted alive view, which lazily
enqueues one on-device compaction program (still transfer-free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.segmentation import SegmentationPlan
from repro.obs import trace as obs


@dataclasses.dataclass
class ExecutionPlan:
    """One routed execution: backend choice + everything that drove it."""

    backend: str                   # BACKENDS key that will run
    reason: str                    # forced | autotune | heuristic | policy | sharded
    num_nodes: int
    num_edges: int                 # true edges when statically known
    bucket: tuple                  # pow2 (V_pad, E_pad) — jit/autotune key
    segmentation: Optional[SegmentationPlan]
    lift_steps: int = 2
    num_segments: Optional[int] = None      # caller override (None = heuristic)
    graph: Any = dataclasses.field(default=None, repr=False)
    graphs: Any = dataclasses.field(default=None, repr=False)   # batched plans
    opts: dict = dataclasses.field(default_factory=dict, repr=False)
    predicted: dict = dataclasses.field(default_factory=dict)
    artifacts: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def bucket_key(self) -> str:
        """The autotune-cache spelling of the shape bucket."""
        return f"v{self.bucket[0]}_e{self.bucket[1]}"

    def run(self):
        """Execute through the registered backend; returns its
        ``CCResult`` (a list of them for batched plans). Extra outputs
        land in ``self.artifacts``. Traced as a ``plan.run`` span
        tagged with the plan provenance when ``repro.obs`` is
        enabled."""
        from repro.api.registry import get_backend
        if not obs.enabled():
            return get_backend(self.backend).run(self)
        with obs.span("plan.run", **self.trace_tags()):
            return get_backend(self.backend).run(self)

    def as_dict(self) -> dict:
        """The decision as one plain-JSON dict — THE schema shared by
        the ``explain()`` renderer and the tracer's span tags (pinned
        by a snapshot test so traces and ``explain()`` can't drift)."""
        seg = self.segmentation
        return {
            "backend": self.backend,
            "reason": self.reason,
            "num_nodes": self.num_nodes,
            "num_edges": self.num_edges,
            "density": 2.0 * self.num_edges / max(self.num_nodes, 1),
            "bucket": list(self.bucket),
            "bucket_key": self.bucket_key,
            "lift_steps": self.lift_steps,
            "num_segments": self.num_segments,
            "batch_size": (len(self.graphs) if self.graphs is not None
                           else None),
            "segmentation": None if seg is None else {
                "num_segments": seg.num_segments,
                "segment_size": seg.segment_size,
                "padded_edges": seg.padded_edges,
                "source": ("override" if self.num_segments is not None
                           else "s=2|E|/|V| heuristic"),
            },
            "predicted": dict(self.predicted),
        }

    def trace_tags(self) -> dict:
        """The provenance subset of ``as_dict()`` that rides on every
        span touching this plan: backend, why it won, shape bucket."""
        d = self.as_dict()
        return {"backend": d["backend"], "reason": d["reason"],
                "bucket": d["bucket_key"]}

    def explain(self) -> str:
        """Human-readable account of the adaptive decision (rendered
        from ``as_dict()`` — same fields the tracer tags see)."""
        from repro.api.registry import BACKENDS
        d = self.as_dict()
        lines = [f"plan: backend={d['backend']} ({d['reason']})"]
        if d["batch_size"] is not None:
            lines.append(f"  batch: {d['batch_size']} graphs, "
                         f"total |E|={d['num_edges']}")
        lines.append(f"  graph: |V|={d['num_nodes']} |E|={d['num_edges']} "
                     f"density={d['density']:.2f} "
                     f"bucket={d['bucket_key']}")
        s = d["segmentation"]
        if s is not None:
            lines.append(f"  segmentation: {s['num_segments']} segment(s)"
                         f" x {s['segment_size']} edges "
                         f"(padded {s['padded_edges']}; {s['source']})")
        if d["predicted"]:
            lines.append("  predicted: " + " ".join(
                f"{k}={v}" for k, v in sorted(d["predicted"].items())))
        backend = BACKENDS.get(self.backend)
        if backend is not None:
            lines.append(f"  capabilities: "
                         f"{backend.capabilities.describe()}")
        return "\n".join(lines)
