"""The built-in backends — every execution mode, one decorator each.

This module is the whole wiring between the facade and the engines:
each backend is a ``@register_backend`` declaration plus a few lines
delegating to the engine entry in ``repro.core``. Adding an execution
mode to the stack = adding one block here (see DESIGN.md §10 for the
generated capability matrix).

Counter semantics: backends with ``bit_exact_counters=True`` return
exact true-work ``WorkCounters`` (padding never billed); the fused
Pallas backend's are additionally bit-identical to the jnp adaptive
composition (the conformance matrix holds it to that). The per-round
Pallas, hostloop, and distributed backends return labels with
zero/partial counters — their value is wall-clock/launch-count
comparison, not work billing.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.api.plan import ExecutionPlan
from repro.api.registry import Capabilities, register_backend
from repro.core import batch as batch_mod
from repro.core import cc as cc_mod
from repro.core import distributed as dist_mod
from repro.core.cc import CCResult
from repro.core.incremental import DynamicCC, IncrementalCC
from repro.core.rounds import WorkCounters

__all__ = []            # nothing public; importing registers everything


# ---------------------------------------------------------------------------
# Single-graph jnp variants (the paper's Fig. 5 ladder)
# ---------------------------------------------------------------------------

def _register_jnp_variant(method: str) -> None:
    @register_backend(method, Capabilities(static=True,
                                           bit_exact_counters=True))
    def _run(plan: ExecutionPlan, _method=method) -> CCResult:
        return cc_mod.solve_static(plan.graph, method=_method,
                                   num_segments=plan.num_segments,
                                   lift_steps=plan.lift_steps)


for _m in cc_mod.METHODS:       # soman multijump atomic_hook adaptive labelprop
    _register_jnp_variant(_m)


# ---------------------------------------------------------------------------
# Pallas kernel backends
# ---------------------------------------------------------------------------

@register_backend("pallas_fused",
                  Capabilities(static=True, bit_exact_counters=True))
def _pallas_fused(plan: ExecutionPlan) -> CCResult:
    """The whole Fig. 4 segment scan in ONE pallas_call (DESIGN.md §8);
    labels AND counters bit-identical to the jnp adaptive composition."""
    return cc_mod.solve_static(plan.graph, method=cc_mod.FUSED_METHOD,
                               num_segments=plan.num_segments,
                               lift_steps=plan.lift_steps)


@register_backend("pallas", Capabilities(static=True,
                                         bit_exact_counters=False))
def _pallas_per_round(plan: ExecutionPlan) -> CCResult:
    """Per-round Pallas kernels (one launch per segment hook / compress
    sweep). Labels only — counters are zeros by contract."""
    labels = cc_mod.solve_pallas(plan.graph,
                                 num_segments=plan.num_segments,
                                 lift_steps=plan.lift_steps,
                                 interpret=plan.opts.get("interpret"))
    return CCResult(labels, WorkCounters.zeros())


# ---------------------------------------------------------------------------
# Host-driven baseline loop (benchmarking: the GPU baseline's syncs)
# ---------------------------------------------------------------------------

@register_backend("hostloop", Capabilities(static=True, device_loop=False,
                                           bit_exact_counters=False))
def _hostloop(plan: ExecutionPlan) -> CCResult:
    """Soman/multijump under HOST control flow — one device round trip
    per convergence check. The raw loop stats land in
    ``plan.artifacts["hostloop_stats"]``."""
    g = plan.graph
    t = g.true_edges_static
    edges = np.asarray(g.edges)
    if t is not None:
        edges = edges[:t]
    labels, stats = cc_mod.solve_hostloop(
        edges, g.num_nodes,
        method=plan.opts.get("hostloop_method", "soman"))
    plan.artifacts["hostloop_stats"] = stats
    work = WorkCounters.zeros().add(
        hook_rounds=stats["hook_rounds"], jump_sweeps=stats["jump_sweeps"],
        sync_rounds=stats["sync_rounds"])
    return CCResult(jnp.asarray(labels), work)


# ---------------------------------------------------------------------------
# Batched engine (many graphs, one device program per shape bucket)
# ---------------------------------------------------------------------------

@register_backend("batched", Capabilities(static=True, batched=True,
                                          bit_exact_counters=True))
def _batched(plan: ExecutionPlan) -> list[CCResult]:
    """Shape-bucketed vmapped engine; one ``CCResult`` per input graph,
    bit-identical to per-graph adaptive runs."""
    return batch_mod.solve_batched(plan.graphs,
                                   num_segments=plan.num_segments,
                                   lift_steps=plan.lift_steps)


# ---------------------------------------------------------------------------
# Streaming engines (live state via make_state)
# ---------------------------------------------------------------------------

@register_backend("incremental",
                  Capabilities(static=True, streaming=True,
                               bit_exact_counters=True))
class _Incremental:
    """Insert-only streaming engine (Hong et al.; DESIGN.md §6)."""

    def make_state(self, num_nodes: int, *, lift_steps: int = 2,
                   scan_method: str | None = None) -> IncrementalCC:
        return IncrementalCC(num_nodes, lift_steps=lift_steps)

    def run(self, plan: ExecutionPlan) -> CCResult:
        state = self.make_state(plan.num_nodes,
                                lift_steps=plan.lift_steps)
        state.insert_graph(plan.graph)
        return CCResult(state.labels, WorkCounters(**state.work))


@register_backend("dynamic",
                  Capabilities(static=True, streaming=True, deletions=True,
                               bit_exact_counters=True))
class _Dynamic:
    """Fully-dynamic engine: tombstone log + scoped recompute
    (DESIGN.md §9). ``Solver`` sessions get their live state here."""

    def make_state(self, num_nodes: int, *, lift_steps: int = 2,
                   scan_method: str | None = None) -> DynamicCC:
        return DynamicCC(num_nodes, lift_steps=lift_steps,
                         scan_method=scan_method or "jnp")

    def run(self, plan: ExecutionPlan) -> CCResult:
        state = self.make_state(plan.num_nodes,
                                lift_steps=plan.lift_steps)
        state.insert_graph(plan.graph)
        return CCResult(state.labels, WorkCounters(**state.work))


# ---------------------------------------------------------------------------
# Distributed engine (spatial segmentation across a mesh)
# ---------------------------------------------------------------------------

@register_backend("distributed",
                  Capabilities(static=True, sharded=True,
                               bit_exact_counters=False))
def _distributed(plan: ExecutionPlan) -> CCResult:
    """shard_map engine over the plan's mesh (DESIGN.md §5). Labels
    only — per-chip counters are not folded globally."""
    mesh = plan.opts.get("mesh")
    if mesh is None:
        raise ValueError("the distributed backend needs a mesh "
                         "(Solver.open(graph, mesh=...))")
    labels = dist_mod.solve_distributed(
        plan.graph, mesh,
        axis_names=plan.opts.get("axis_names", ("data",)),
        lift_steps=plan.lift_steps)
    return CCResult(labels, WorkCounters.zeros())
