"""The built-in backends — every execution mode, one decorator each.

This module is the whole wiring between the facade and the engines:
each backend is a ``@register_backend`` declaration plus a few lines
delegating to the engine entry in ``repro.core``. Adding an execution
mode to the stack = adding one block here (see DESIGN.md §10 for the
generated capability matrix).

Counter semantics: backends with ``bit_exact_counters=True`` return
exact true-work ``WorkCounters`` (padding never billed); the fused
Pallas backend's are additionally bit-identical to the jnp adaptive
composition (the conformance matrix holds it to that). The per-round
Pallas, hostloop, and distributed backends return labels with
zero/partial counters — their value is wall-clock/launch-count
comparison, not work billing.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.api.plan import ExecutionPlan
from repro.api.registry import (Capabilities, TraceEntry, VarInfo,
                                register_backend, register_trace_spec)
from repro.core import batch as batch_mod
from repro.core import cc as cc_mod
from repro.core import distributed as dist_mod
from repro.core.cc import CCResult
from repro.core.incremental import DynamicCC, IncrementalCC
from repro.core.rounds import WorkCounters

__all__ = []            # nothing public; importing registers everything


# ---------------------------------------------------------------------------
# Single-graph jnp variants (the paper's Fig. 5 ladder)
# ---------------------------------------------------------------------------

def _register_jnp_variant(method: str) -> None:
    @register_backend(
        method,
        Capabilities(static=True, bit_exact_counters=True,
                     spanning_forest=method in cc_mod.FOREST_METHODS))
    def _run(plan: ExecutionPlan, _method=method) -> CCResult:
        return cc_mod.solve_static(plan.graph, method=_method,
                                   num_segments=plan.num_segments,
                                   lift_steps=plan.lift_steps)


for _m in cc_mod.METHODS:       # soman multijump atomic_hook adaptive labelprop
    _register_jnp_variant(_m)


# ---------------------------------------------------------------------------
# Pallas kernel backends
# ---------------------------------------------------------------------------

@register_backend("pallas_fused",
                  Capabilities(static=True, bit_exact_counters=True))
def _pallas_fused(plan: ExecutionPlan) -> CCResult:
    """The whole Fig. 4 segment scan in ONE pallas_call (DESIGN.md §8);
    labels AND counters bit-identical to the jnp adaptive composition."""
    return cc_mod.solve_static(plan.graph, method=cc_mod.FUSED_METHOD,
                               num_segments=plan.num_segments,
                               lift_steps=plan.lift_steps)


@register_backend("pallas", Capabilities(static=True,
                                         bit_exact_counters=False))
def _pallas_per_round(plan: ExecutionPlan) -> CCResult:
    """Per-round Pallas kernels (one launch per segment hook / compress
    sweep). Labels only — counters are zeros by contract."""
    labels = cc_mod.solve_pallas(plan.graph,
                                 num_segments=plan.num_segments,
                                 lift_steps=plan.lift_steps,
                                 interpret=plan.opts.get("interpret"))
    return CCResult(labels, WorkCounters.zeros())


# ---------------------------------------------------------------------------
# Sampling-accelerated backends (k-out / Afforest-style; DESIGN.md §13)
# ---------------------------------------------------------------------------

def _run_sampled(plan: ExecutionPlan, fused: bool) -> CCResult:
    from repro.core import sampled as sampled_mod
    res = sampled_mod.solve_sampled(plan.graph,
                                    num_segments=plan.num_segments,
                                    lift_steps=plan.lift_steps,
                                    fused=fused,
                                    interpret=plan.opts.get("interpret"))
    # phase-split telemetry (device scalars synced once, post-solve)
    plan.artifacts["sampled_stats"] = {k: int(v)
                                       for k, v in res.stats.items()}
    return CCResult(res.labels, res.work)


@register_backend("sampled",
                  Capabilities(static=True, bit_exact_counters=True,
                               spanning_forest=True))
def _sampled(plan: ExecutionPlan) -> CCResult:
    """k-out sampling phase collapses the giant component, then the
    adaptive Fig. 4 scan covers the residue only (Hong et al.). The
    sampled-vs-residue work split lands in
    ``plan.artifacts["sampled_stats"]``."""
    return _run_sampled(plan, fused=False)


@register_backend("sampled_fused",
                  Capabilities(static=True, bit_exact_counters=True))
def _sampled_fused(plan: ExecutionPlan) -> CCResult:
    """``sampled`` with the residue scan routed through the fused
    Pallas kernel (one launch per scan). The kernel does not record
    forest edges, so this variant does not claim ``spanning_forest``."""
    return _run_sampled(plan, fused=True)


# ---------------------------------------------------------------------------
# Host-driven baseline loop (benchmarking: the GPU baseline's syncs)
# ---------------------------------------------------------------------------

@register_backend("hostloop", Capabilities(static=True, device_loop=False,
                                           bit_exact_counters=False))
def _hostloop(plan: ExecutionPlan) -> CCResult:
    """Soman/multijump under HOST control flow — one device round trip
    per convergence check. The raw loop stats land in
    ``plan.artifacts["hostloop_stats"]``."""
    g = plan.graph
    t = g.true_edges_static
    edges = np.asarray(g.edges)
    if t is not None:
        edges = edges[:t]
    labels, stats = cc_mod.solve_hostloop(
        edges, g.num_nodes,
        method=plan.opts.get("hostloop_method", "soman"))
    plan.artifacts["hostloop_stats"] = stats
    work = WorkCounters.zeros().add(
        hook_rounds=stats["hook_rounds"], jump_sweeps=stats["jump_sweeps"],
        sync_rounds=stats["sync_rounds"])
    return CCResult(jnp.asarray(labels), work)


# ---------------------------------------------------------------------------
# Batched engine (many graphs, one device program per shape bucket)
# ---------------------------------------------------------------------------

@register_backend("batched", Capabilities(static=True, batched=True,
                                          bit_exact_counters=True))
def _batched(plan: ExecutionPlan) -> list[CCResult]:
    """Shape-bucketed vmapped engine; one ``CCResult`` per input graph,
    bit-identical to per-graph adaptive runs."""
    return batch_mod.solve_batched(plan.graphs,
                                   num_segments=plan.num_segments,
                                   lift_steps=plan.lift_steps)


# ---------------------------------------------------------------------------
# Streaming engines (live state via make_state)
# ---------------------------------------------------------------------------

@register_backend("incremental",
                  Capabilities(static=True, streaming=True,
                               bit_exact_counters=True))
class _Incremental:
    """Insert-only streaming engine (Hong et al.; DESIGN.md §6)."""

    def make_state(self, num_nodes: int, *, lift_steps: int = 2,
                   scan_method: str | None = None) -> IncrementalCC:
        return IncrementalCC(num_nodes, lift_steps=lift_steps)

    def run(self, plan: ExecutionPlan) -> CCResult:
        state = self.make_state(plan.num_nodes,
                                lift_steps=plan.lift_steps)
        state.insert_graph(plan.graph)
        return CCResult(state.labels, WorkCounters(**state.work))


@register_backend("dynamic",
                  Capabilities(static=True, streaming=True, deletions=True,
                               bit_exact_counters=True,
                               maintained_forest=True))
class _Dynamic:
    """Fully-dynamic engine: tombstone log + scoped recompute
    (DESIGN.md §9). ``Solver`` sessions get their live state here."""

    def make_state(self, num_nodes: int, *, lift_steps: int = 2,
                   scan_method: str | None = None) -> DynamicCC:
        return DynamicCC(num_nodes, lift_steps=lift_steps,
                         scan_method=scan_method or "jnp")

    def run(self, plan: ExecutionPlan) -> CCResult:
        state = self.make_state(plan.num_nodes,
                                lift_steps=plan.lift_steps)
        state.insert_graph(plan.graph)
        return CCResult(state.labels, WorkCounters(**state.work))


# ---------------------------------------------------------------------------
# Distributed engine (spatial segmentation across a mesh)
# ---------------------------------------------------------------------------

@register_backend("distributed",
                  Capabilities(static=True, sharded=True,
                               bit_exact_counters=False))
def _distributed(plan: ExecutionPlan) -> CCResult:
    """shard_map engine over the plan's mesh (DESIGN.md §5). Labels
    only — per-chip counters are not folded globally."""
    mesh = plan.opts.get("mesh")
    if mesh is None:
        raise ValueError("the distributed backend needs a mesh "
                         "(Solver.open(graph, mesh=...))")
    labels = dist_mod.solve_distributed(
        plan.graph, mesh,
        axis_names=plan.opts.get("axis_names", ("data",)),
        lift_steps=plan.lift_steps)
    return CCResult(labels, WorkCounters.zeros())


# ---------------------------------------------------------------------------
# Traceable entry specs — one per backend (repro.analysis; DESIGN.md §11)
# ---------------------------------------------------------------------------
# Each spec closes the backend's device program over symbolic shape
# buckets (ShapeDtypeStructs — no data is allocated) so the static
# analyzer can hold it to its contracts: transfer-freedom on tick
# paths, int32 range safety at scale-tier shapes, pow2 bucketing, and
# padding-mask discipline. Builders construct DeviceGraphs INSIDE the
# traced function so the flat argument list aligns 1:1 with VarInfo.

def _graph_fn_build(v: int, e: int, run):
    """Shared builder for entries of shape fn(edges, true_edges)."""
    import jax

    from repro.core.segmentation import (adaptive_num_segments,
                                         plan_segmentation)
    from repro.graphs.device import DeviceGraph
    plan = plan_segmentation(e, v, adaptive_num_segments(e, v))

    def fn(edges, true_edges):
        return run(DeviceGraph(edges, v, true_edges, plan))

    args = (jax.ShapeDtypeStruct((e, 2), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    info = [VarInfo(range=(0, v - 1), padded=True),
            VarInfo(range=(0, e), mask=True)]
    return fn, args, info


def _static_solve_entry(method: str) -> TraceEntry:
    def build(v, e, _method=method):
        return _graph_fn_build(
            v, e, lambda g: cc_mod.solve_static(g, method=_method))
    return TraceEntry(name=f"backend.{method}", build=build,
                      backend=method)


@register_trace_spec("static")
def _static_specs():
    return [_static_solve_entry(m)
            for m in cc_mod.METHODS + (cc_mod.FUSED_METHOD,)]


@register_trace_spec("sampled")
def _sampled_specs():
    import jax

    from repro.core import rounds, sampled as sampled_mod
    from repro.core.segmentation import adaptive_num_segments

    def build_sample(v, e):
        def fn(edges, true_edges):
            return sampled_mod._sample_phase_jit(
                edges, true_edges, num_nodes=v, k=sampled_mod.SAMPLE_K,
                sample_rounds=sampled_mod.SAMPLE_ROUNDS, lift_steps=2)
        return (fn, (jax.ShapeDtypeStruct((e, 2), jnp.int32),
                     jax.ShapeDtypeStruct((), jnp.int32)),
                [VarInfo(range=(0, v - 1), padded=True),
                 VarInfo(range=(0, e), mask=True)])

    def residue_build(v, e, fused):
        # parents/work start fresh inside the trace (scalar constants
        # only); pi arrives as the sampling phase's label array
        def fn(edges, true_edges, pi):
            return sampled_mod._residue_scan_jit(
                edges, true_edges, pi, rounds.empty_forest(v),
                WorkCounters.zeros(), num_nodes=v,
                num_segments=adaptive_num_segments(e, v),
                lift_steps=2, fused=fused, interpret=True)
        return (fn, (jax.ShapeDtypeStruct((e, 2), jnp.int32),
                     jax.ShapeDtypeStruct((), jnp.int32),
                     jax.ShapeDtypeStruct((v,), jnp.int32)),
                [VarInfo(range=(0, v - 1), padded=True),
                 VarInfo(range=(0, e), mask=True),
                 VarInfo(range=(0, v - 1))])

    return [TraceEntry(name="backend.sampled.sample_phase",
                       build=build_sample, backend="sampled"),
            TraceEntry(name="backend.sampled.residue",
                       build=lambda v, e: residue_build(v, e, False),
                       backend="sampled"),
            TraceEntry(name="backend.sampled_fused.residue",
                       build=lambda v, e: residue_build(v, e, True),
                       backend="sampled_fused")]


@register_trace_spec("pallas")
def _pallas_specs():
    def build(v, e):
        fn, args, info = _graph_fn_build(
            v, e, lambda g: cc_mod.solve_pallas(g))
        return fn, args, info
    return [TraceEntry(name="backend.pallas", build=build,
                       backend="pallas")]


@register_trace_spec("hostloop")
def _hostloop_specs():
    # the hostloop backend is CONTRACTED to sync (device_loop=False);
    # its per-step device programs still must stage cleanly, so each
    # step is its own entry without the transfer_free contract
    import jax

    def build_hook(v, e):
        def fn(pi, edges):
            return cc_mod._host_hook(pi, edges)
        return (fn, (jax.ShapeDtypeStruct((v,), jnp.int32),
                     jax.ShapeDtypeStruct((e, 2), jnp.int32)),
                [VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1), padded=True)])

    def build_jump(v, e):
        def fn(pi):
            return cc_mod._host_jump(pi)
        return (fn, (jax.ShapeDtypeStruct((v,), jnp.int32),),
                [VarInfo(range=(0, v - 1))])

    def build_compress(v, e):
        def fn(pi):
            return cc_mod._host_compress(pi)
        return (fn, (jax.ShapeDtypeStruct((v,), jnp.int32),),
                [VarInfo(range=(0, v - 1))])

    bucketed = frozenset({"bucketed"})
    return [TraceEntry("backend.hostloop.hook", build_hook, bucketed,
                       backend="hostloop"),
            TraceEntry("backend.hostloop.jump", build_jump, bucketed,
                       backend="hostloop"),
            TraceEntry("backend.hostloop.compress", build_compress,
                       bucketed, backend="hostloop")]


@register_trace_spec("batched")
def _batched_specs():
    def build(v, e, batch=4):
        import jax
        per = max(e // batch, 8)

        def fn(edges, true_edges, true_nodes):
            return batch_mod._cc_batched_jit(
                edges, true_edges, true_nodes, num_nodes=v,
                num_segments=None, lift_steps=2)
        return (fn,
                (jax.ShapeDtypeStruct((batch, per, 2), jnp.int32),
                 jax.ShapeDtypeStruct((batch,), jnp.int32),
                 jax.ShapeDtypeStruct((batch,), jnp.int32)),
                [VarInfo(range=(0, v - 1), padded=True),
                 VarInfo(range=(0, per), mask=True),
                 VarInfo(range=(0, v), mask=True)])
    return [TraceEntry(name="backend.batched", build=build,
                       backend="batched")]


@register_trace_spec("incremental")
def _incremental_specs():
    from repro.core import incremental as inc_mod

    def build(v, e):
        import jax

        def fn(pi, new_edges, true_count, version):
            return inc_mod._absorb_jit(pi, new_edges, true_count,
                                       version, lift_steps=2)
        return (fn,
                (jax.ShapeDtypeStruct((v,), jnp.int32),
                 jax.ShapeDtypeStruct((e, 2), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32)),
                [VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1), padded=True),
                 VarInfo(range=(0, e), mask=True),
                 VarInfo()])
    return [TraceEntry(name="backend.incremental.absorb", build=build,
                       backend="incremental")]


def _delete_build(v: int, e: int, scan_method: str):
    import jax

    from repro.core import incremental as inc_mod
    from repro.core.segmentation import adaptive_num_segments
    d = max(e // 4, 8)

    def fn(edges, alive, pi, dels, d_true, version, deleted):
        return inc_mod._delete_jit(
            edges, alive, pi, dels, d_true, version, deleted,
            lift_steps=2, num_segments=adaptive_num_segments(e, v),
            scan_method=scan_method, interpret=True)
    return (fn,
            (jax.ShapeDtypeStruct((e, 2), jnp.int32),
             jax.ShapeDtypeStruct((e,), jnp.bool_),
             jax.ShapeDtypeStruct((v,), jnp.int32),
             jax.ShapeDtypeStruct((d, 2), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32)),
            [VarInfo(range=(0, v - 1), padded=True),
             VarInfo(mask=True),
             VarInfo(range=(0, v - 1)),
             VarInfo(range=(0, v - 1), padded=True),
             VarInfo(range=(0, d), mask=True),
             VarInfo(),
             VarInfo()])


def _absorb_forest_build(v: int, e: int):
    import jax

    from repro.core import incremental as inc_mod

    def fn(pi, parents, parent_eidx, new_edges, eid_base, true_count,
           version):
        return inc_mod._absorb_forest_jit(
            pi, parents, parent_eidx, new_edges, eid_base, true_count,
            version, lift_steps=2)
    return (fn,
            (jax.ShapeDtypeStruct((v,), jnp.int32),
             jax.ShapeDtypeStruct((v, 2), jnp.int32),
             jax.ShapeDtypeStruct((v,), jnp.int32),
             jax.ShapeDtypeStruct((e, 2), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32)),
            [VarInfo(range=(0, v - 1)),
             VarInfo(range=(-1, v - 1)),
             VarInfo(range=(-1, e - 1)),
             VarInfo(range=(0, v - 1), padded=True),
             VarInfo(range=(0, e)),
             VarInfo(range=(0, e), mask=True),
             VarInfo()])


def _delete_forest_build(v: int, e: int):
    import jax

    from repro.core import incremental as inc_mod
    d = max(e // 4, 8)

    def fn(edges, alive, pi, parents, parent_eidx, dels, d_true,
           version, deleted, routes):
        return inc_mod._delete_forest_jit(
            edges, alive, pi, parents, parent_eidx, dels, d_true,
            version, deleted, routes, lift_steps=2)
    return (fn,
            (jax.ShapeDtypeStruct((e, 2), jnp.int32),
             jax.ShapeDtypeStruct((e,), jnp.bool_),
             jax.ShapeDtypeStruct((v,), jnp.int32),
             jax.ShapeDtypeStruct((v, 2), jnp.int32),
             jax.ShapeDtypeStruct((v,), jnp.int32),
             jax.ShapeDtypeStruct((d, 2), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32),
             jax.ShapeDtypeStruct((2,), jnp.int32)),
            [VarInfo(range=(0, v - 1), padded=True),
             VarInfo(mask=True),
             VarInfo(range=(0, v - 1)),
             VarInfo(range=(-1, v - 1)),
             VarInfo(range=(-1, e - 1)),
             VarInfo(range=(0, v - 1), padded=True),
             VarInfo(range=(0, d), mask=True),
             VarInfo(),
             VarInfo(),
             VarInfo()])


@register_trace_spec("dynamic")
def _dynamic_specs():
    def build_absorb(v, e):
        return _incremental_specs()[0].build(v, e)

    return [TraceEntry(name="backend.dynamic.absorb",
                       build=build_absorb, backend="dynamic"),
            TraceEntry(name="backend.dynamic.absorb_forest",
                       build=_absorb_forest_build, backend="dynamic"),
            TraceEntry(name="backend.dynamic.delete",
                       build=lambda v, e: _delete_build(v, e, "jnp"),
                       backend="dynamic"),
            TraceEntry(name="backend.dynamic.delete_fused",
                       build=lambda v, e: _delete_build(
                           v, e, "pallas_fused"),
                       backend="dynamic"),
            TraceEntry(name="backend.dynamic.delete_forest",
                       build=_delete_forest_build, backend="dynamic")]


@register_trace_spec("distributed")
def _distributed_specs():
    def build(v, e):
        import jax
        from jax.sharding import Mesh

        from repro.graphs.device import DeviceGraph
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        dg = DeviceGraph.from_edges(jnp.zeros((e, 2), jnp.int32), v)
        call = dist_mod.build_distributed_cc(dg, mesh, ("data",))
        return (call.on_edges,
                (jax.ShapeDtypeStruct((e, 2), jnp.int32),),
                [VarInfo(range=(0, v - 1), padded=True)])
    return [TraceEntry(name="backend.distributed", build=build,
                       backend="distributed")]
