"""repro.api — the unified public API (DESIGN.md §10).

One front door to the paper's adaptivity: the ``Solver`` facade routes
every workload shape (one-shot, batched, streaming insert/delete,
sharded) through the adaptive policy and a pluggable ``BACKENDS``
registry, and reifies each decision as an inspectable
``ExecutionPlan``::

    from repro import Solver

    s = Solver.open(edges, num_nodes=n)      # a session
    print(s.plan().explain())                # the adaptive decision
    res = s.solve()                          # CCResult(labels, work)
    s.insert(more_edges); s.delete(dead_edges)
    s.connected(u, v); s.num_components()

Backends register with one decorator (``register_backend``); the
capability matrix (``capability_matrix()``) and this module's
``__all__`` are snapshot-tested so the public surface cannot drift
silently. Legacy entrypoints (``connected_components`` et al.) forward
here behind one-shot ``DeprecationWarning``s.
"""
from repro.api.registry import (BACKENDS, Backend, Capabilities,
                                available_backends, capability_matrix,
                                get_backend, register_backend)
from repro.api.plan import ExecutionPlan
from repro.api import backends as _backends          # registers built-ins
from repro.api.solver import Solver, solve
from repro.core.cc import CCResult
from repro.core.rounds import WorkCounters
from repro.graphs.device import DeviceGraph

__all__ = [
    "Solver",
    "solve",
    "ExecutionPlan",
    "Backend",
    "Capabilities",
    "BACKENDS",
    "register_backend",
    "get_backend",
    "available_backends",
    "capability_matrix",
    "CCResult",
    "WorkCounters",
    "DeviceGraph",
]
