"""Backend protocol + the pluggable ``BACKENDS`` registry (DESIGN.md §10).

Every execution mode of the stack — the five jnp single-graph variants,
the per-round and fused Pallas kernel backends, the host-driven
baseline loop, the shape-bucketed batched engine, the incremental and
fully-dynamic streaming engines, and the sharded distributed engine —
registers here under a uniform contract:

  * a ``Capabilities`` descriptor saying what workloads the backend can
    take (static / batched / streaming / deletions / sharded) and
    whether its ``WorkCounters`` are bit-exact against the jnp adaptive
    composition (the repo's counter ground truth);
  * a ``run(plan) -> CCResult`` entry point consuming an
    ``ExecutionPlan`` (``repro.api.plan``);
  * optionally a ``make_state(num_nodes, ...)`` factory for streaming
    backends — the ``Solver`` session asks the registry for its live
    state instead of hard-coding an engine class.

Adding a backend is a one-file, one-decorator change::

    @register_backend("my-engine", Capabilities(static=True))
    def _run(plan):
        return my_engine(plan.graph, lift_steps=plan.lift_steps)

The ``Solver`` facade and the adaptive policy then route to it by name;
nothing else in the stack needs to know it exists.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, runtime_checkable


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend can run, as data (the capability matrix in
    DESIGN.md §10 is generated from these)."""

    static: bool = True            # one-shot solve over a fixed edge set
    batched: bool = False          # many graphs, one device program
    streaming: bool = False        # absorbs edge insertions into live state
    deletions: bool = False        # absorbs edge deletions (tombstone log)
    sharded: bool = False          # runs over a multi-device mesh
    device_loop: bool = True       # control flow on device (no host syncs)
    # exact true-work WorkCounters (padding never billed; trustworthy
    # for cross-mode comparison — pallas_fused's are additionally
    # bit-identical to the jnp adaptive composition, asserted in tests)
    bit_exact_counters: bool = False
    # records the spanning forest during hook rounds (the parent-edge
    # table behind Solver.spanning_forest(); property-tested)
    spanning_forest: bool = False
    # keeps the spanning forest as a MAINTAINED device resident across
    # mutations (extended in-jit on insert, consumed by the tree-aware
    # delete route; DESIGN.md §14) rather than recompute-on-demand
    maintained_forest: bool = False

    def describe(self) -> str:
        flag = lambda b: "y" if b else "n"          # noqa: E731
        return (f"static={flag(self.static)} batched={flag(self.batched)} "
                f"streaming={flag(self.streaming)} "
                f"deletions={flag(self.deletions)} "
                f"sharded={flag(self.sharded)} "
                f"device_loop={flag(self.device_loop)} "
                f"bit_exact_counters={flag(self.bit_exact_counters)} "
                f"spanning_forest={flag(self.spanning_forest)} "
                f"maintained_forest={flag(self.maintained_forest)}")


@runtime_checkable
class Backend(Protocol):
    """The uniform backend contract the Solver dispatches against."""

    name: str
    capabilities: Capabilities

    def run(self, plan: Any) -> Any:                 # -> CCResult (or list)
        ...


class _FunctionBackend:
    """Adapter: a plain ``run(plan)`` function as a Backend."""

    def __init__(self, name: str, capabilities: Capabilities,
                 fn: Callable[[Any], Any],
                 make_state: Optional[Callable[..., Any]] = None):
        self.name = name
        self.capabilities = capabilities
        self._fn = fn
        self._make_state = make_state

    def run(self, plan):
        return self._fn(plan)

    def make_state(self, num_nodes: int, **kw):
        if self._make_state is None:
            raise TypeError(f"backend {self.name!r} is not a streaming "
                            "backend (no make_state)")
        return self._make_state(num_nodes, **kw)

    def __repr__(self) -> str:
        return f"<Backend {self.name!r} {self.capabilities.describe()}>"


BACKENDS: Dict[str, Backend] = {}


# ---------------------------------------------------------------------------
# Traceable entry specs (consumed by repro.analysis — DESIGN.md §11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VarInfo:
    """Static facts the analyzer knows about ONE flat traced argument.

    * ``range``  — inclusive (lo, hi) value bounds for integer inputs
      (vertex ids lie in [0, |V|-1], true counts in [0, |E|], ...);
      None = unbounded/unknown (the int32 pass treats it as TOP and
      never reports overflow through it);
    * ``padded`` — the array carries rows past a true count (the §8
      prefix-padding / tombstone-log discipline) — the padding-mask
      pass seeds its taint here;
    * ``mask``   — the argument IS a true-count scalar or alive mask:
      a sanitizer source for the padding-mask pass.
    """

    range: Optional[tuple] = None
    padded: bool = False
    mask: bool = False


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    """One traceable program of the stack, as data.

    ``build(num_nodes, num_edges)`` returns ``(fn, args, arg_info)``:
    a pure function over FLAT array arguments, example arguments
    (``jax.ShapeDtypeStruct``s — nothing is allocated), and a
    ``VarInfo`` per argument. ``repro.analysis`` closes each entry to
    a jaxpr via ``jax.make_jaxpr`` at symbolic shape buckets and runs
    its checker passes over the graph.

    ``contracts`` name the invariants the entry is held to:
      * ``"transfer_free"`` — the program must stage with zero host
        round trips (the steady-state tick contract the
        ``jax.transfer_guard`` tests pin at runtime);
      * ``"bucketed"``      — inputs must land on the pow2 shape-bucket
        rule (``repro.core.batch``), the retrace-storm guard.
    """

    name: str
    build: Callable[[int, int], tuple]
    contracts: frozenset = frozenset({"transfer_free", "bucketed"})
    backend: Optional[str] = None        # owning BACKENDS key, if any


TRACE_SPECS: Dict[str, Callable[[], list]] = {}


def register_trace_spec(name: str):
    """Decorator registering a zero-arg builder returning the
    ``TraceEntry`` list for one backend (or subsystem). The analysis
    toolkit discovers every traceable program through this registry —
    adding a backend without a trace spec is caught by its sweep test."""
    def deco(fn):
        if name in TRACE_SPECS:
            raise ValueError(f"trace spec {name!r} already registered")
        TRACE_SPECS[name] = fn
        return fn
    return deco


def trace_entries() -> list:
    """Every registered ``TraceEntry``, sorted by name. Importing
    ``repro.api.backends`` (and ``repro.analysis.entries``) populates
    the registry; this accessor only reads it."""
    out = []
    for name in sorted(TRACE_SPECS):
        out.extend(TRACE_SPECS[name]())
    return sorted(out, key=lambda e: e.name)


def register_backend(name: str, capabilities: Capabilities,
                     make_state: Optional[Callable[..., Any]] = None):
    """Class/function decorator registering an execution backend.

    Decorate either a class exposing ``run(self, plan)`` (instantiated
    once, ``name``/``capabilities`` attached) or a bare ``run(plan)``
    function (wrapped). ``make_state`` (or a ``make_state`` method on
    the class) marks a streaming backend whose live session state the
    ``Solver`` obtains through the registry.
    """
    def deco(obj):
        if name in BACKENDS:
            raise ValueError(f"backend {name!r} already registered")
        if isinstance(obj, type):
            backend = obj()
            backend.name = name
            backend.capabilities = capabilities
        else:
            backend = _FunctionBackend(name, capabilities, obj,
                                       make_state=make_state)
        BACKENDS[name] = backend
        return obj
    return deco


def get_backend(name: str) -> Backend:
    if name not in BACKENDS:
        raise KeyError(f"unknown backend {name!r}; registered backends: "
                       f"{sorted(BACKENDS)}")
    return BACKENDS[name]


def available_backends() -> list[str]:
    return sorted(BACKENDS)


def capability_matrix() -> dict[str, dict]:
    """``{backend: {capability: bool}}`` — the registry's contents as
    data (snapshot-tested so the public surface cannot drift silently)."""
    return {name: dataclasses.asdict(b.capabilities)
            for name, b in sorted(BACKENDS.items())}
