"""Solver — the one front door to every execution mode (DESIGN.md §10).

``Solver.open(graph_or_edges, **opts)`` returns a session that handles:

  * **static solve** — ``solve()`` routes through the adaptive policy
    (``method="auto"``: autotune cache, then the paper's density
    heuristic) or any forced method/backend, dispatching through the
    ``BACKENDS`` registry;
  * **streaming mutation** — ``insert()`` / ``delete()`` lazily promote
    the session to the fully-dynamic engine and route every batch
    through ``policy.select_for`` (small insert → incremental absorb,
    bulk → static rebuild + adopt; small delete → tombstone + scoped
    recompute, bulk drop → rebuild over survivors). Steady-state
    mutation with ``DeviceGraph`` payloads is transfer-free under
    ``jax.transfer_guard("disallow")`` — same contract as the service
    tick, pinned in tests;
  * **queries** — every ``connectivity.queries`` lookup
    (``same_component`` / ``component_size`` / ``num_components`` /
    ``component_histogram``), answered from the live canonical label
    array, batches padded to the shared pow2 jit buckets;
  * **inspection** — ``plan()`` reifies the adaptive decision as an
    ``ExecutionPlan`` whose ``explain()`` shows the chosen backend, the
    pow2 shape bucket, the segmentation plan, and the predicted work,
    BEFORE anything runs.

One-shot convenience: ``repro.api.solve(graph, ...) -> CCResult``;
fleets: ``Solver.solve_batch(graphs)``; meshes:
``Solver.open(graph, mesh=mesh).solve()``.

>>> from repro.api import Solver
>>> s = Solver.open([[0, 1], [1, 2]], num_nodes=4)
>>> s.plan().backend
'atomic_hook'
>>> int(s.num_components())
2
>>> _ = s.insert([[2, 3]])
>>> s.connected(0, 3)
True
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import numpy as np

from repro.api.plan import ExecutionPlan
from repro.api.registry import get_backend
from repro.connectivity import policy, queries
from repro.core.batch import bucket_shape, pad_rows_pow2
from repro.core.cc import ALL_METHODS, CCResult
from repro.core.segmentation import plan_segmentation
from repro.graphs.device import (DeviceGraph, as_device_graph,
                                 validate_edge_bounds)
from repro.obs import trace as obs

# method spellings a plan accepts beyond "auto" (each is a backend name)
_PLANNABLE = tuple(ALL_METHODS) + ("pallas", "hostloop")

# per-call backend options plan()/solve() accept via **opts — validated
# so a typo'd tuning kwarg (lift_step, interpert, ...) raises instead of
# silently running with defaults, matching the legacy entrypoints'
# TypeError strictness
_KNOWN_OPTS = frozenset({"interpret", "hostloop_method"})


class Solver:
    """A connectivity session over one vertex set. Use ``open()``."""

    def __init__(self, graph: Optional[DeviceGraph], num_nodes: int, *,
                 lift_steps: int = 2, num_segments: int | None = None,
                 mesh=None, axis_names=("data",),
                 policy_cache: policy.AutotuneCache | None = None,
                 scan_method: str | None = None,
                 delete_route: str | None = None, name: str = "solver",
                 device=None):
        self._graph = graph            # opened static snapshot (or None)
        self._device = device          # pinned device (None = default)
        self.num_nodes = int(num_nodes)
        self.lift_steps = lift_steps
        self.num_segments = num_segments
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.policy_cache = policy_cache
        self._scan_method = scan_method   # force the scoped-scan backend
        if delete_route is not None \
                and delete_route not in policy.DELETE_METHODS:
            raise ValueError(f"unknown delete_route {delete_route!r}; "
                             f"choose from {policy.DELETE_METHODS} or "
                             "None (policy-routed)")
        self._delete_route = delete_route  # force the delete-side route
        self.name = name
        self._dyn = None               # live dynamic state (lazy)
        self._labels = None            # cached static-solve labels
        # cached (method, ForestResult, label version at build): kept
        # while the version is unchanged — an absorb that merged
        # nothing leaves the partition intact, so the forest still
        # spans it (edges only got added)
        self._forest = None
        self._empty = None             # cached empty DeviceGraph
        self.last_method: str | None = None
        self.last_plan: ExecutionPlan | None = None
        self.stats = {"solves": 0, "inserts": 0, "deletes": 0,
                      "absorbs": 0, "scoped_deletes": 0,
                      "forest_deletes": 0, "rebuilds": 0}

    # -- session lifecycle ---------------------------------------------------

    @classmethod
    def open(cls, graph=None, num_nodes: int | None = None, *,
             lift_steps: int = 2, num_segments: int | None = None,
             mesh=None, axis_names=("data",),
             policy_cache: policy.AutotuneCache | None = None,
             scan_method: str | None = None,
             delete_route: str | None = None,
             name: str = "solver", device=None) -> "Solver":
        """Open a session.

        Args:
          graph: a ``DeviceGraph``, a host ``Graph``, or a raw [E, 2]
            edge array (then ``num_nodes`` is required) — or ``None``
            for an empty streaming session over ``num_nodes`` vertices.
          num_nodes: |V| for raw arrays / empty sessions.
          lift_steps: bounded root-chase depth (all engines).
          num_segments: override the s = 2|E|/|V| heuristic.
          mesh: a ``jax.sharding.Mesh`` — plans default to the
            ``distributed`` backend over ``axis_names``.
          policy_cache: autotune cache for ``method="auto"`` routing
            (None = the process-wide default cache).
          scan_method: force the dynamic engine's scoped-scan backend
            (``"jnp"`` | ``"pallas_fused"``; None = policy-routed).
          delete_route: force the delete-side route (a
            ``policy.DELETE_METHODS`` entry; None = policy-routed by
            the delete-rate + tree-edge-ratio features). Benchmarks
            use this to compare routes on identical streams.
          name: label for introspection.
          device: pin the session to ONE device: host payloads
            device_put there, dynamic state allocated there, static
            solves/rebuilds dispatched there. This is the fleet's
            per-device shell mode (``repro.fleet`` packs many pinned
            sessions across a mesh); None keeps the process default.
        """
        if graph is None:
            if num_nodes is None:
                raise ValueError("Solver.open() needs a graph or "
                                 "num_nodes")
            g, n = None, int(num_nodes)
        else:
            g = as_device_graph(graph, num_nodes,
                                num_segments=num_segments)
            n = g.num_nodes
        return cls(g, n, lift_steps=lift_steps, num_segments=num_segments,
                   mesh=mesh, axis_names=axis_names,
                   policy_cache=policy_cache, scan_method=scan_method,
                   delete_route=delete_route, name=name, device=device)

    def _device_scope(self):
        """``jax.default_device`` context for a pinned session (a
        no-op context when unpinned) — wraps every path that CREATES
        device state (dynamic-state init, static solves), so a fleet
        shard's arrays land on its own device without per-array puts."""
        if self._device is None:
            return contextlib.nullcontext()
        import jax
        return jax.default_device(self._device)

    def graph(self) -> DeviceGraph:
        """The CURRENT edge set as a DeviceGraph: the dynamic log's
        surviving (compacted) view once the session has mutated, else
        the opened snapshot (an empty graph for bare sessions)."""
        if self._dyn is not None and self._dyn.log.rows > 0:
            return self._dyn.graph()
        if self._dyn is None and self._graph is not None:
            return self._graph
        if self._empty is None:
            self._empty = DeviceGraph.from_edges(
                np.zeros((0, 2), np.int32), self.num_nodes,
                name=self.name)
        return self._empty

    @property
    def num_edges(self) -> int:
        """Host-known edge count (no sync): inserted-edge total for a
        mutated session (an upper bound under churn — the policy's size
        feature, same contract as the registry), else the opened
        graph's true count."""
        if self._dyn is not None:
            return self._dyn.num_edges_inserted
        return self._graph.num_edges if self._graph is not None else 0

    # -- planning ------------------------------------------------------------

    def plan(self, method: str = "auto", *, backend: str | None = None,
             num_segments: int | None = None, **opts) -> ExecutionPlan:
        """Build the ``ExecutionPlan`` a ``solve()`` with the same
        arguments would run — the adaptive decision, inspectable before
        any device work. ``backend=`` forces a registry entry verbatim;
        a non-"auto" ``method`` maps to its same-named backend; "auto"
        asks the policy (autotune cache, then heuristic). Passing BOTH
        a named method and a backend is a conflict and raises."""
        plan = self._build_plan(method, backend=backend,
                                num_segments=num_segments, **opts)
        self.last_plan = plan
        return plan

    def _build_plan(self, method: str = "auto", *,
                    backend: str | None = None,
                    num_segments: int | None = None,
                    **opts) -> ExecutionPlan:
        if backend is not None and method not in (None, "auto"):
            raise ValueError(
                f"pass method={method!r} OR backend={backend!r}, not "
                "both — a forced backend must not silently reroute a "
                "named method")
        unknown = set(opts) - _KNOWN_OPTS
        if unknown:
            raise TypeError(
                f"unknown option(s) {sorted(unknown)}; per-call backend "
                f"options are {sorted(_KNOWN_OPTS)}")
        g = self.graph()
        num_segments = self.num_segments if num_segments is None \
            else num_segments
        # policy features come from the HOST-tracked edge count (true
        # count for static sessions, inserted total for streaming ones
        # — the same feature every mutation-path policy call uses), NOT
        # from the log view's stored row count, which is pow2 capacity
        # padding once the session has mutated
        n, e = self.num_nodes, self.num_edges
        if backend is not None:
            caps = get_backend(backend).capabilities   # validates early
            if caps.batched:
                raise ValueError(
                    f"backend {backend!r} runs fleets, not single "
                    "graphs — use Solver.solve_batch(graphs)")
            if caps.sharded and self.mesh is None:
                raise ValueError(
                    f"backend {backend!r} needs a mesh — open the "
                    "session with Solver.open(graph, mesh=...)")
            chosen, reason = backend, "forced"
        elif method not in (None, "auto"):
            # an explicitly forced method wins over the mesh default —
            # a mesh session must not silently reroute (or accept) a
            # named method
            if method not in _PLANNABLE:
                raise ValueError(f"unknown method {method!r}; choose "
                                 f"from {('auto',) + _PLANNABLE} or "
                                 "force a backend= from "
                                 "repro.api.BACKENDS")
            chosen, reason = method, "forced"
        elif self.mesh is not None:
            chosen, reason = "distributed", "sharded"
        else:
            # the skew feature rides the graph's static pytree metadata
            # (measured once at host ingest; None for device-resident
            # edge arrays) — it routes kron/soc-style graphs to the
            # sampled engine at scale and costs road-like graphs nothing
            chosen, reason = policy.select_static_explained(
                n, e, degree_skew=g.degree_skew,
                cache=self.policy_cache)
        seg = g.plan if num_segments is None else plan_segmentation(
            int(g.edges.shape[0]), n, num_segments)
        predicted = {"hook_ops_per_round": e,
                     "jump_ops_per_sweep": n,
                     "segments": seg.num_segments}
        if g.degree_skew is not None:
            predicted["degree_skew"] = round(float(g.degree_skew), 3)
        plan = ExecutionPlan(
            backend=chosen, reason=reason, num_nodes=n, num_edges=e,
            bucket=bucket_shape(n, e), segmentation=seg,
            lift_steps=self.lift_steps, num_segments=num_segments,
            graph=g,
            opts={"mesh": self.mesh, "axis_names": self.axis_names,
                  **opts},
            predicted=predicted)
        return plan

    # -- static solve --------------------------------------------------------

    def solve(self, method: str = "auto", *, backend: str | None = None,
              num_segments: int | None = None, **opts) -> CCResult:
        """Solve the current edge set; returns ``CCResult(labels,
        work)`` with canonical min-id labels. Routing == ``plan()``."""
        plan = self.plan(method, backend=backend,
                         num_segments=num_segments, **opts)
        with self._device_scope():
            if obs.enabled():
                with obs.span("solver.solve", tenant=self.name,
                              **plan.trace_tags()):
                    res = plan.run()
            else:
                res = plan.run()
        self.stats["solves"] += 1
        self.last_method = plan.backend
        self._labels = res.labels
        return res

    def spanning_forest(self, method: str | None = None):
        """Labels PLUS the spanning forest the hook rounds record —
        ``ForestResult(labels, parents, work)`` where ``parents`` is
        int32 [V, 2]: row r holds the original graph edge whose hook
        retired root r, (-1, -1) for the one root per component (the
        component minimum). Exactly |V| - C rows are recorded and they
        form a spanning forest whose partition equals ``labels``
        (property-tested; ``connectivity.queries.spanning_forest_stats``
        validates one on device).

        ``method=None`` asks the policy and falls back to ``adaptive``
        when the chosen backend does not record a forest (capability
        ``spanning_forest``); forcing a non-recording method raises.

        The result is cached per method, keyed on the label VERSION at
        build time: an ``insert()`` whose absorb provably merged
        nothing (version unchanged) leaves the partition intact, and a
        spanning forest of the old edge set still spans the new one —
        the cache survives. ``delete()`` always invalidates: a deleted
        tree edge with a surviving replacement keeps the version
        unticked yet kills a cached forest edge."""
        from repro.core import cc as cc_mod
        if method is None:
            g = self.graph()
            chosen, _ = policy.select_static_explained(
                self.num_nodes, self.num_edges,
                degree_skew=g.degree_skew, cache=self.policy_cache)
            method = chosen if chosen in cc_mod.FOREST_METHODS \
                else "adaptive"
        if self._forest is not None and self._forest[0] == method \
                and self._forest[2] == self.version:
            return self._forest[1]
        with obs.span("solver.spanning_forest", tenant=self.name,
                      method=method):
            res = cc_mod.solve_forest(self.graph(), method=method,
                                      num_segments=self.num_segments,
                                      lift_steps=self.lift_steps)
        self._forest = (method, res, self.version)
        return res

    @classmethod
    def solve_batch(cls, graphs: Sequence, *,
                    num_segments: int | None = None,
                    lift_steps: int = 2) -> list[CCResult]:
        """Fleet solve through the ``batched`` backend: one device
        program per pow2 shape bucket, one ``CCResult`` per graph in
        input order, bit-identical to per-graph solves."""
        graphs = list(graphs)
        sizes = [(g.num_nodes, g.num_edges)
                 if hasattr(g, "num_nodes")
                 else (int(g[1]), int(np.asarray(g[0]).reshape(-1, 2)
                                      .shape[0]))
                 for g in graphs]
        n = max((s[0] for s in sizes), default=0)
        e = sum(s[1] for s in sizes)
        plan = ExecutionPlan(
            backend="batched", reason="forced", num_nodes=n, num_edges=e,
            bucket=bucket_shape(n, e), segmentation=None,
            lift_steps=lift_steps, num_segments=num_segments,
            graphs=graphs, predicted={"n_graphs": len(graphs)})
        return plan.run()

    # -- streaming mutation (policy-routed, transfer-free steady state) ------

    def _coerce(self, edges) -> DeviceGraph:
        """Host arrays are validated + device_put; DeviceGraphs pass
        through untouched (no sync — the caller owns bounds there)."""
        if isinstance(edges, DeviceGraph):
            if edges.num_nodes != self.num_nodes:
                raise ValueError(f"delta num_nodes {edges.num_nodes} != "
                                 f"{self.num_nodes}")
            return edges
        arr = np.asarray(edges, np.int32).reshape(-1, 2)
        validate_edge_bounds(arr, self.num_nodes)
        return DeviceGraph.from_edges(arr, self.num_nodes,
                                      name=self.name,
                                      device=self._device)

    @property
    def state(self):
        """The live dynamic engine (``DynamicCC``), created on first
        use via the ``dynamic`` backend's ``make_state`` — opening a
        session with edges routes that snapshot through the policy as
        its first (bulk) insert."""
        return self._ensure_dyn()

    def _ensure_dyn(self):
        if self._dyn is None:
            # pinned sessions allocate the dynamic state (labels, edge
            # log, forest) under their device scope: the init jits run
            # there, so the state commits to the shard's device and
            # every later mutation jit follows it — no per-tick puts
            with self._device_scope():
                self._dyn = get_backend("dynamic").make_state(
                    self.num_nodes, lift_steps=self.lift_steps,
                    scan_method=self._scan_method)
            if obs.enabled():
                # span tracing on => carry the on-device Metrics pytree
                # through every mutation jit (still transfer-free; host
                # materialization only at metrics_summary())
                self._dyn.enable_metrics()
            seed, self._graph = self._graph, None
            if seed is not None and seed.num_edges:
                # the opened snapshot routes through the policy as the
                # session's first (bulk) insert — counted as one, so
                # inserts == absorbs + insert-side rebuilds stays true
                self.stats["inserts"] += 1
                self._route_insert(seed)
        return self._dyn

    def _rebuild(self, method: str) -> CCResult:
        """Static rebuild over the current (staged) edge set via the
        policy-chosen backend — the bulk-mutation route."""
        plan = self.plan(method)
        plan.reason = "policy"
        self.last_plan = plan
        with self._device_scope():
            return plan.run()

    def _route_insert(self, delta: DeviceGraph) -> None:
        dyn = self._dyn
        method = policy.select_for(self.num_nodes, self.num_edges, delta,
                                   cache=self.policy_cache)
        self.last_method = method
        if method == policy.INCREMENTAL_ABSORB:
            dyn.insert_graph(delta)
            self.stats["absorbs"] += 1
        else:
            # bulk load: the accumulated set is mostly this batch — the
            # chosen static engine (segmentation and all) beats hooking
            # a huge unsegmented delta through the absorb loop
            dyn.stage(delta)
            res = self._rebuild(method)
            dyn.adopt(res.labels, work=res.work,
                      num_edges=delta.num_edges)
            self.stats["rebuilds"] += 1

    def insert(self, edges):
        """Insert an edge batch (DeviceGraph or host array); returns
        the label version as a DEVICE scalar — the steady-state path
        never syncs (``int(...)`` it to observe). Routed by
        ``policy.select_for``: small delta → incremental absorb, bulk
        load → static rebuild + adopt."""
        delta = self._coerce(edges)
        self._ensure_dyn()
        self.stats["inserts"] += 1
        # the spanning-forest cache is NOT cleared here: it is keyed on
        # the label version, and an absorb that merged nothing leaves
        # the cached forest valid (see spanning_forest())
        with obs.span("solver.insert", tenant=self.name,
                      edges=delta.num_edges) as sp:
            self._route_insert(delta)
            sp.tag(route=self.last_method)
        return self._dyn.version_device

    def delete(self, edges):
        """Delete an edge batch (each row retires every alive copy of
        that undirected edge; absent rows are no-ops); returns the
        label version as a DEVICE scalar (never syncs). Routed by the
        delete-rate policy: small batch → tombstone + scoped recompute
        in ONE device program (version ticks iff a component actually
        split), bulk drop → static rebuild over the survivors."""
        delta = self._coerce(edges)
        dyn = self._ensure_dyn()
        self.stats["deletes"] += 1
        self._forest = None            # edge set changed: forest stale
        with obs.span("solver.delete", tenant=self.name,
                      edges=delta.num_edges) as sp:
            method = self._delete_route if self._delete_route is not None \
                else policy.select_for(self.num_nodes, self.num_edges,
                                       delta, delete=True,
                                       cache=self.policy_cache)
            self.last_method = method
            sp.tag(route=method)
            if method == policy.DYNAMIC_DELETE_FOREST:
                # tree-aware route (DESIGN.md §14): classify against
                # the maintained forest, short-circuit all-non-tree
                # batches, scope reconnection to split components
                dyn.delete_graph_forest(delta)
                self.stats["forest_deletes"] += 1
                self.stats["scoped_deletes"] += 1
            elif method in policy.DELETE_METHODS:
                if self._scan_method is None:
                    dyn.scan_method = "pallas_fused" \
                        if method == policy.DYNAMIC_DELETE_FUSED else "jnp"
                dyn.delete_graph(delta)
                self.stats["scoped_deletes"] += 1
            else:
                obs.count("dynamic.deletes.rebuild")
                dyn.tombstone_graph(delta)
                res = self._rebuild(method)
                dyn.adopt(res.labels, work=res.work)
                self.stats["rebuilds"] += 1
        return dyn.version_device

    # -- live state views ----------------------------------------------------

    @property
    def labels(self):
        """Canonical min-id labels for the current edge set (device).
        Mutated sessions read the live dynamic state; static sessions
        solve lazily (``method="auto"``) on first access — WITHOUT
        touching ``stats``/``last_method``/``last_plan`` (a property
        read must not look like a routing decision to introspection)."""
        if self._dyn is not None:
            return self._dyn.labels
        if self._labels is None:
            with self._device_scope():
                self._labels = self._build_plan().run().labels
        return self._labels

    @property
    def version(self) -> int:
        """Label version as a host int (syncs). Ticks exactly when a
        mutation changed the partition (merge or split)."""
        return self._dyn.version if self._dyn is not None else 0

    @property
    def version_device(self):
        """Label version as a device scalar (no sync)."""
        if self._dyn is not None:
            return self._dyn.version_device
        import jax.numpy as jnp
        return jnp.zeros((), jnp.int32)

    @property
    def work(self) -> dict:
        """Accumulated mutation work counters (host ints; syncs).
        Zeroed — not empty — before the first mutation, so counter
        reads never KeyError on a fresh session."""
        if self._dyn is not None:
            return self._dyn.work
        from repro.core.rounds import WorkCounters
        return {k: 0 for k in WorkCounters._fields}

    def enable_metrics(self) -> None:
        """Attach the on-device ``repro.obs`` Metrics accumulators to
        the dynamic engine (automatic when tracing was enabled before
        the first mutation; call this to opt in later). Device-only
        until ``metrics_summary()``."""
        self._ensure_dyn().enable_metrics()

    @property
    def metrics(self):
        """The live on-device ``Metrics`` pytree (None unless
        attached). Reading never syncs."""
        return self._dyn.metrics if self._dyn is not None else None

    def metrics_summary(self) -> dict | None:
        """Materialize the accumulators on the host (the one explicit
        sync, via the audited ``queries.to_host`` sink); None when no
        metrics are attached."""
        m = self.metrics
        if m is None:
            return None
        from repro.obs import metrics as obs_metrics
        return obs_metrics.flush(m)

    # -- queries (on-device kernels over the live labels) --------------------

    def _check_vertices(self, batch: np.ndarray) -> None:
        if batch.size and (batch.min() < 0
                           or batch.max() >= self.num_nodes):
            raise ValueError(
                f"vertex out of range [0, {self.num_nodes})")

    def same_component(self, pairs) -> np.ndarray:
        """bool [Q] for an int [Q, 2] pair batch (pow2-padded so every
        same-shape batch shares one jit cache entry)."""
        pairs = np.asarray(pairs, np.int32).reshape(-1, 2)
        self._check_vertices(pairs)
        q = pairs.shape[0]
        with obs.span("solver.query.same_component", tenant=self.name,
                      rows=q):
            return queries.to_host(queries.same_component(
                self.labels, pad_rows_pow2(pairs)))[:q]

    def connected(self, u: int, v: int) -> bool:
        """Scalar convenience over ``same_component``."""
        return bool(self.same_component([[u, v]])[0])

    def component_size(self, vertices) -> np.ndarray:
        """int32 [Q] component sizes for a vertex batch."""
        vertices = np.asarray(vertices, np.int32).reshape(-1)
        self._check_vertices(vertices)
        q = vertices.shape[0]
        with obs.span("solver.query.component_size", tenant=self.name,
                      rows=q):
            return queries.to_host(queries.component_size(
                self.labels, pad_rows_pow2(vertices)))[:q]

    def component_sizes(self):
        """int32 [V] size of every vertex's component (device)."""
        return queries.component_sizes(self.labels)

    def num_components(self) -> int:
        """Distinct-component count (one on-device sort/segment
        kernel — the single counting implementation every layer
        delegates to)."""
        with obs.span("solver.query.num_components", tenant=self.name):
            return int(queries.count_components(self.labels))

    def component_histogram(self) -> np.ndarray:
        """Components per power-of-two size bin."""
        with obs.span("solver.query.component_histogram",
                      tenant=self.name):
            return queries.to_host(
                queries.component_histogram(self.labels))

    def __repr__(self) -> str:
        mode = "dynamic" if self._dyn is not None else "static"
        return (f"Solver(name={self.name!r}, |V|={self.num_nodes}, "
                f"|E|~{self.num_edges}, mode={mode})")


def solve(graph, num_nodes: int | None = None, method: str = "auto", *,
          backend: str | None = None, num_segments: int | None = None,
          lift_steps: int = 2, mesh=None, axis_names=("data",),
          policy_cache: policy.AutotuneCache | None = None,
          **opts) -> CCResult:
    """One-shot facade solve: ``Solver.open(...).solve(...)``."""
    return Solver.open(graph, num_nodes, lift_steps=lift_steps,
                       num_segments=num_segments, mesh=mesh,
                       axis_names=axis_names,
                       policy_cache=policy_cache).solve(
        method, backend=backend, **opts)
