"""Deterministic synthetic data pipeline with bounded prefetch.

One ``*_batches`` generator per model family; every batch is a dict of
numpy arrays matching the model's ``batch_spec``. Determinism: batch
``i`` of stream ``seed`` is a pure function of ``(seed, i)`` — restart
after a failure resumes the exact stream from the checkpointed step
(fault tolerance depends on this; tested).

``Prefetcher`` runs the generator in a daemon thread ahead of the device
step through a bounded queue — host-side batch construction overlaps the
device step (straggler mitigation lever #1: the device never waits on
the host unless the host is > ``depth`` batches behind).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence((seed, step)))


# ==========================================================================
# Family generators
# ==========================================================================

def lm_batch(seed: int, step: int, batch: int, seq: int,
             vocab: int) -> dict:
    """Zipf-ish token stream: [B, S+1] (inputs + shifted labels)."""
    rng = _rng_for(seed, step)
    z = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    return {"tokens": np.minimum(z, vocab - 1).astype(np.int32)}


def lm_batches(seed: int, batch: int, seq: int, vocab: int,
               start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(seed, step, batch, seq, vocab)
        step += 1


def recsys_batch(seed: int, step: int, batch: int, n_dense: int,
                 table_sizes: tuple) -> dict:
    rng = _rng_for(seed, step)
    idx = np.stack(
        [rng.integers(0, s, batch) for s in table_sizes], axis=1)
    dense = rng.standard_normal((batch, n_dense)).astype(np.float32)
    # click probability correlated with features so training can learn
    score = dense[:, 0] + 0.1 * (idx[:, 0] % 7 - 3)
    label = (score + rng.standard_normal(batch) > 0).astype(np.int32)
    return {"dense": dense, "sparse_idx": idx.astype(np.int32),
            "label": label}


def recsys_batches(seed: int, batch: int, n_dense: int, table_sizes: tuple,
                   start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield recsys_batch(seed, step, batch, n_dense, table_sizes)
        step += 1


def graph_node_batch(seed: int, step: int, num_nodes: int, num_edges: int,
                     d_feat: int, n_classes: int) -> dict:
    """Full-graph node classification batch (fixed graph per seed; the
    per-step RNG only reshuffles the train mask, as real epochs do)."""
    g_rng = _rng_for(seed, 0)
    edges = g_rng.integers(0, num_nodes, size=(num_edges, 2))
    x = g_rng.standard_normal((num_nodes, d_feat)).astype(np.float32)
    y = g_rng.integers(0, n_classes, num_nodes).astype(np.int32)
    rng = _rng_for(seed, step)
    mask = (rng.random(num_nodes) < 0.5).astype(np.float32)
    sym = np.concatenate([edges, edges[:, ::-1]], axis=0)
    return {"x": x, "src": sym[:, 0].astype(np.int32),
            "dst": sym[:, 1].astype(np.int32), "y": y,
            "node_mask": mask}


def molecule_energy_batch(seed: int, step: int, num_graphs: int,
                          nodes_per: int, edges_per: int,
                          n_species: int = 8) -> dict:
    """Block-diagonal molecule batch for NequIP (positions + energies)."""
    rng = _rng_for(seed, step)
    V = num_graphs * nodes_per
    pos = rng.standard_normal((V, 3)).astype(np.float32) * 1.5
    species = rng.integers(0, n_species, V).astype(np.int32)
    blocks = []
    for g in range(num_graphs):
        base = g * nodes_per
        idx = np.arange(nodes_per - 1)
        chain = np.stack([idx, idx + 1], 1)
        extra = rng.integers(0, nodes_per,
                             size=(max(edges_per - len(chain), 0), 2))
        blocks.append(np.concatenate([chain, extra], 0) + base)
    e = np.concatenate(blocks, 0)
    sym = np.concatenate([e, e[:, ::-1]], axis=0)
    graph_ids = np.repeat(np.arange(num_graphs), nodes_per).astype(np.int32)
    # synthetic target: pairwise LJ-ish energy (invariant by construction)
    d = np.linalg.norm(pos[sym[:, 0]] - pos[sym[:, 1]], axis=-1) + 0.5
    e_edge = 1.0 / d ** 2 - 1.0 / d
    energy = np.zeros(num_graphs, np.float32)
    np.add.at(energy, graph_ids[sym[:, 0]], e_edge.astype(np.float32))
    return {"positions": pos, "species": species,
            "src": sym[:, 0].astype(np.int32),
            "dst": sym[:, 1].astype(np.int32),
            "graph_ids": graph_ids, "energy": energy}


# ==========================================================================
# Prefetcher
# ==========================================================================

class Prefetcher:
    """Bounded-queue background prefetch around any batch iterator.

    ``depth`` bounds host memory and gives back-pressure; a sentinel
    propagates generator exhaustion; exceptions re-raise in the consumer
    (so a data failure aborts the step loop, where the fault-tolerance
    wrapper can restart from the last checkpoint).
    """

    _SENTINEL = object()

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: list[BaseException] = []

        def worker():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:   # noqa: BLE001 — re-raised below
                self._err.append(e)
            finally:
                self._q.put(self._SENTINEL)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err:
                raise self._err[0]
            raise StopIteration
        return item


def make_stream(factory: Callable[..., Iterator[dict]], *args,
                prefetch: int = 2, **kw) -> Iterator[dict]:
    """Wrap a generator factory with prefetching."""
    return Prefetcher(factory(*args, **kw), depth=prefetch)
