from repro.kernels.multi_jump.ops import multi_jump, full_compress
