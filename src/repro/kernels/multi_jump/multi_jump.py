"""Multi-Jump Pallas kernel — the paper's fused Compress phase on TPU.

The GPU Multi-Jump gives each thread a divergent ``while`` loop chasing
``pi(v) <- pi(pi(v))`` with (i) *continuous write-back* so concurrent
threads observe partially-compressed paths, and (ii) *partial-order
scheduling* (top-of-tree / low vertex ids first).

TPU mapping: the parent workspace π lives VMEM-resident across a
sequential 1-D grid over vertex tiles (ascending tile index == the
paper's low-ids-first partial order). Each grid step chases its tile
``rounds`` times against the *current* workspace — including writes made
by earlier tiles in the same sweep (continuous write-back), then stores
the compressed tile in place via input/output aliasing.

VMEM budget: π is int32[V]; tiles plus workspace must fit VMEM
(≈128 MiB on v5e ⇒ V ≲ 24M per core before an HBM-resident π + DMA
variant is needed; the multi-device path in ``repro.core.distributed``
shards edges long before that).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _multi_jump_kernel(pi_in_ref, pi_ref, *, tile: int, rounds: int):
    """Grid step i compresses vertices [i*tile, (i+1)*tile).

    ``pi_ref`` is the in/out-aliased workspace: at step 0 it holds the
    input π, and later steps observe earlier tiles' writes (the paper's
    continuous write-back + low-ids-first partial order).
    """
    del pi_in_ref                          # aliased with pi_ref
    i = pl.program_id(0)
    start = i * tile
    pi = pi_ref[...]                       # snapshot incl. earlier tiles' writes
    t = jax.lax.dynamic_slice(pi, (start,), (tile,))
    for _ in range(rounds):                # unrolled pointer doubling
        t = jnp.take(pi, t, axis=0)
        # continuous write-back *within* the tile snapshot as well:
        pi = jax.lax.dynamic_update_slice(pi, t, (start,))
    pi_ref[...] = pi


def multi_jump_pallas(pi: jnp.ndarray, *, tile: int = 512,
                      rounds: int = 2, interpret: bool = True
                      ) -> jnp.ndarray:
    """One blocked Multi-Jump sweep (each tile chased ``rounds`` levels)."""
    v = pi.shape[0]
    assert v % tile == 0, f"|V|={v} must be a multiple of tile={tile}"
    grid = (v // tile,)
    kernel = functools.partial(_multi_jump_kernel, tile=tile, rounds=rounds)
    return pl.pallas_call(
        kernel,
        grid=grid,
        # π stays whole-array VMEM-resident across all grid steps
        in_specs=[pl.BlockSpec((v,), lambda i: (0,))],
        out_specs=pl.BlockSpec((v,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((v,), pi.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pi)
