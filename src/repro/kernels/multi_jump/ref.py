"""Pure-jnp oracles for the Multi-Jump kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ref_full_compress(pi: jnp.ndarray) -> jnp.ndarray:
    """Fixed point of pointer jumping: every vertex points at its root."""

    def cond(state):
        p, changed = state
        return changed

    def body(state):
        p, _ = state
        nxt = p[p]
        return nxt, jnp.any(nxt != p)

    pi, _ = jax.lax.while_loop(cond, body, (pi, jnp.asarray(True)))
    return pi


def ref_multi_jump_sweep(pi: jnp.ndarray, tile: int, rounds: int
                         ) -> jnp.ndarray:
    """Bit-exact oracle of ONE blocked sweep, reproducing the kernel's
    sequential tile order + continuous write-back semantics."""
    pi = np.asarray(pi).copy()
    v = pi.shape[0]
    for start in range(0, v, tile):
        t = pi[start:start + tile].copy()
        for _ in range(rounds):
            t = pi[t]
            pi[start:start + tile] = t
    return jnp.asarray(pi)


def ref_roots(pi: np.ndarray) -> np.ndarray:
    """Host pointer-chase to root (for property tests)."""
    pi = np.asarray(pi)
    out = np.empty_like(pi)
    for v in range(pi.shape[0]):
        r = v
        while pi[r] != r:
            r = pi[r]
        out[v] = r
    return out
