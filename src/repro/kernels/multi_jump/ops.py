"""Jit'd wrappers around the Multi-Jump kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.multi_jump.multi_jump import multi_jump_pallas

_MAX_SWEEPS = 64


def _pad_to(pi: jnp.ndarray, tile: int) -> tuple[jnp.ndarray, int]:
    v = pi.shape[0]
    target = ((v + tile - 1) // tile) * tile
    if target != v:
        # padded entries are self-roots: chase no-ops
        pad = jnp.arange(v, target, dtype=pi.dtype)
        pi = jnp.concatenate([pi, pad])
    return pi, v


@functools.partial(jax.jit, static_argnames=("tile", "rounds", "interpret"))
def multi_jump(pi: jnp.ndarray, *, tile: int = 512, rounds: int = 2,
               interpret: bool | None = None) -> jnp.ndarray:
    """One blocked Multi-Jump sweep (kernel-accelerated)."""
    interpret = default_interpret() if interpret is None else interpret
    padded, v = _pad_to(pi, tile)
    out = multi_jump_pallas(padded, tile=tile, rounds=rounds,
                            interpret=interpret)
    return out[:v]


@functools.partial(jax.jit, static_argnames=("tile", "rounds", "interpret"))
def full_compress(pi: jnp.ndarray, *, tile: int = 512, rounds: int = 2,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Compress to stars: repeat kernel sweeps until fixed point, entirely
    on device (lax.while_loop around the pallas sweep)."""
    interpret = default_interpret() if interpret is None else interpret
    padded, v = _pad_to(pi, tile)

    def cond(state):
        _, changed, sweeps = state
        return jnp.logical_and(changed, sweeps < _MAX_SWEEPS)

    def body(state):
        p, _, sweeps = state
        nxt = multi_jump_pallas(p, tile=tile, rounds=rounds,
                                interpret=interpret)
        return nxt, jnp.any(nxt != p), sweeps + 1

    padded, _, _ = jax.lax.while_loop(
        cond, body, (padded, jnp.asarray(True), jnp.zeros((), jnp.int32)))
    return padded[:v]
