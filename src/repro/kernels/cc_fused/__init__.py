from repro.kernels.cc_fused.ops import fused_segment_scan
