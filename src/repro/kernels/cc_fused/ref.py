"""Pure-jnp oracle for the fused segment-scan kernel: the shared
per-round composition (``rounds.segment_scan`` with jnp ops), which the
fused kernel must match bit-for-bit — labels AND sweep counts."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import rounds


def ref_segment_scan(pi: jnp.ndarray, segments: jnp.ndarray,
                     true_counts: jnp.ndarray, *, lift_steps: int = 2
                     ) -> tuple[jnp.ndarray, rounds.WorkCounters]:
    ops = rounds.jnp_round_ops(lift_steps)
    return rounds.segment_scan(pi, segments, ops,
                               rounds.WorkCounters.zeros(),
                               true_counts=jnp.asarray(true_counts,
                                                       jnp.int32))
