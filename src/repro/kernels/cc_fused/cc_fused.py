"""Fused segment-scan Pallas kernel — the WHOLE Fig. 4 inner pipeline
in ONE ``pallas_call``.

The per-round backend (``kernels.hook`` + ``kernels.multi_jump``) pays
one kernel launch per segment hook plus one per compress sweep:
``num_segments + jump_sweeps`` launches per segment scan. Sutton et
al.'s 6.8× comes precisely from eliminating that per-round scheduling
overhead; this kernel removes it on TPU by running every hook round and
every compress sweep inside a single sequential 1-D grid over segments:

  * grid step i processes segment i: gather both endpoint parents,
    bounded vectorized root chase (``lift_steps``, the Atomic-Hook
    analogue), high-to-low rule, deterministic scatter-min into the
    VMEM-resident parent workspace;
  * then the fused Multi-Jump compress runs to its fixed point in the
    SAME grid step (``fori`` over the provably sufficient
    ceil(log2 V) + 2 pointer-doubling fuel, masked after convergence),
    counting actual sweeps exactly like ``rounds.compress`` so work
    billing stays bit-compatible with the jnp backend;
  * π persists in the output buffer across grid steps (revisited whole-
    array block — the standard accumulation idiom), so later segments
    observe earlier segments' hooks: the same memory-visibility order
    as the sequential ``lax.scan`` it replaces, hence bit-identical
    labels.

Per-segment TRUE edge counts arrive as a scalar-prefetched operand
(``pltpu.PrefetchScalarGridSpec``): available in SMEM before the grid
body runs, they mask padded edge slots to (0, 0) no-ops — work counters
bill true edges only, and the schedule never depends on pad content.
Callers must uphold the prefix invariant (real edges first within the
flattened segment array — what ``rounds.pad_and_segment`` and
``DeviceGraph`` guarantee).

Outputs: (π', per-segment sweep counts int32 [S]) — the sweep counts
feed ``jump_ops``/``jump_sweeps`` billing outside the kernel.

VMEM budget matches ``kernels.multi_jump``: π is int32[V] resident
across the grid (V ≲ 24M per core on v5e before an HBM+DMA variant is
needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cc_fused_kernel(counts_ref, segs_ref, pi_init_ref, pi_ref,
                     sweeps_ref, *, lift_steps: int, fuel: int,
                     segment_size: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():                                   # seed the workspace once
        pi_ref[...] = pi_init_ref[...]

    pi = pi_ref[...]                           # incl. earlier segments' hooks
    seg = segs_ref[...].reshape(segment_size, 2)
    # scalar-prefetched true count: mask padded slots to (0,0) no-ops
    mask = jax.lax.iota(jnp.int32, segment_size) < counts_ref[i]
    u = jnp.where(mask, seg[:, 0], 0)
    v = jnp.where(mask, seg[:, 1], 0)

    # Atomic-Hook analogue: bounded root chase + high-low scatter-min
    pu = jnp.take(pi, u, axis=0)
    pv = jnp.take(pi, v, axis=0)
    for _ in range(lift_steps):
        pu = jnp.take(pi, pu, axis=0)
        pv = jnp.take(pi, pv, axis=0)
    hi = jnp.maximum(pu, pv)
    lo = jnp.minimum(pu, pv)
    pi = pi.at[hi].min(lo)

    # fused Multi-Jump compress to the fixed point, counting sweeps
    # exactly like rounds.compress (each executed sweep bills once,
    # including the final no-change sweep that detects convergence)
    def body(_, carry):
        p, changed, n = carry
        nxt = jnp.where(changed, jnp.take(p, p, axis=0), p)
        n = n + changed.astype(jnp.int32)
        changed = jnp.logical_and(changed, jnp.any(nxt != p))
        return nxt, changed, n

    pi, _, nsweeps = jax.lax.fori_loop(
        0, fuel, body,
        (pi, jnp.asarray(True), jnp.zeros((), jnp.int32)))

    pi_ref[...] = pi
    sweeps_ref[...] = jnp.full((1,), nsweeps, jnp.int32)


def cc_fused_pallas(pi: jnp.ndarray, segments: jnp.ndarray,
                    true_counts: jnp.ndarray, *, lift_steps: int = 2,
                    fuel: int = 34, interpret: bool = True
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused segment scan: ``segments`` [S, seg, 2] hooked and
    compressed against π in a single ``pallas_call``.

    Returns (π', sweeps [S]) where ``sweeps[i]`` is the number of
    compress sweeps segment i's grid step executed.
    """
    num_segments, segment_size, _ = segments.shape
    v = pi.shape[0]
    kernel = functools.partial(_cc_fused_kernel, lift_steps=lift_steps,
                               fuel=fuel, segment_size=segment_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # true_counts -> SMEM
        grid=(num_segments,),
        in_specs=[
            pl.BlockSpec((1, segment_size, 2), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((v,), lambda i, c: (0,)),
        ],
        out_specs=[
            # π: whole-array block revisited every step (persistent)
            pl.BlockSpec((v,), lambda i, c: (0,)),
            pl.BlockSpec((1,), lambda i, c: (i,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((v,), pi.dtype),
            jax.ShapeDtypeStruct((num_segments,), jnp.int32),
        ],
        interpret=interpret,
    )(true_counts, segments, pi)
