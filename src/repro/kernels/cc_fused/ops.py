"""Jit'd wrapper for the fused segment-scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.cc_fused.cc_fused import cc_fused_pallas


@functools.partial(jax.jit,
                   static_argnames=("lift_steps", "fuel", "interpret"))
def fused_segment_scan(pi: jnp.ndarray, segments: jnp.ndarray,
                       true_counts: jnp.ndarray, *, lift_steps: int = 2,
                       fuel: int | None = None,
                       interpret: bool | None = None
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full Fig. 4 segment scan in ONE kernel launch.

    Args:
      pi: int32 [V] parent workspace.
      segments: int32 [S, seg, 2] edge segments (pad tail with (0, 0)).
      true_counts: int32 [S] per-segment true edge counts
        (scalar-prefetched; padded slots are masked to no-ops).
      fuel: compress fuel per segment; None derives the provably
        sufficient ceil(log2 V) + 2 (``rounds.compress_fuel``).

    Returns:
      (labels, sweeps [S]) — sweeps feed jump billing outside.
    """
    from repro.core.rounds import compress_fuel
    interpret = default_interpret() if interpret is None else interpret
    if fuel is None:
        fuel = compress_fuel(pi.shape[0])
    return cc_fused_pallas(pi, segments,
                           jnp.asarray(true_counts, jnp.int32),
                           lift_steps=lift_steps, fuel=fuel,
                           interpret=interpret)
