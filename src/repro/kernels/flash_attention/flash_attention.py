"""Blocked online-softmax attention (FlashAttention-style) for TPU.

Grid: (batch*heads, q_blocks, kv_blocks) — the kv dimension is the
innermost (sequential) axis, so per-(bh, q-block) running statistics
(m, l, acc) persist in VMEM scratch across kv steps and the output tile
is emitted on the last step. MXU alignment: block sizes are multiples of
128 on the matmul dims.

Variants needed by the assigned architectures:
  * ``causal``   — LM training/prefill masking,
  * ``window``   — gemma2's local (sliding-window) layers,
  * ``softcap``  — gemma2's logit soft-capping ``cap*tanh(s/cap)``.

GQA is handled in ops.py by folding the q-head group into the batch dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, sm_scale: float,
                  causal: bool, window: int, softcap: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)

    # block-level skip: fully-masked kv blocks do no work
    relevant = jnp.asarray(True)
    if causal:
        relevant = (ik * block_k) <= (iq * block_q + block_q - 1)
    if window > 0:
        # q attends to k in (q - window, q]
        first_q = iq * block_q
        last_k = ik * block_k + block_k - 1
        relevant = jnp.logical_and(relevant, last_k > first_q - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [Bq, d]
        k = k_ref[0].astype(jnp.float32)            # [Bk, d]
        v = v_ref[0].astype(jnp.float32)            # [Bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # renormalize previous accumulator
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _emit():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)             # fully-masked rows -> 0
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, sm_scale: float, causal: bool = False,
                           window: int = 0, softcap: float = 0.0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q, k, v: [BH, S, d] -> [BH, S, d]."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    grid = (bh, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, sm_scale=sm_scale,
        causal=causal, window=window, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
