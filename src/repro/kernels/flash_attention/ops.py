"""Jit'd wrapper: GQA folding + padding + dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas,
)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                              "block_k", "interpret", "sm_scale"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    sm_scale: float | None = None, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Multi-head attention with GQA.

    q: [B, Sq, Hq, d]; k, v: [B, Sk, Hkv, d]; Hq % Hkv == 0.
    Returns [B, Sq, Hq, d].
    """
    interpret = default_interpret() if interpret is None else interpret
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else d ** -0.5

    # fold (B, Hkv, group) into the BH grid dim; kv repeats per group
    qg = q.reshape(b, sq, hkv, group, d)
    qg = jnp.moveaxis(qg, (2, 3), (1, 2)).reshape(b * hkv * group, sq, d)
    kg = jnp.repeat(jnp.moveaxis(k, 2, 1), group, axis=1)
    kg = kg.reshape(b * hkv * group, sk, d)
    vg = jnp.repeat(jnp.moveaxis(v, 2, 1), group, axis=1)
    vg = vg.reshape(b * hkv * group, sk, d)

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded kv positions are masked out by the causal/window mask only
        # if they exceed every q position; mask explicitly via window? --
        # simplest safe route: pad k with -inf-producing zeros and rely on
        # q_pos >= k_pos failing only for causal. For non-causal we forbid
        # padding instead.
        assert causal, "non-causal flash path requires Sk % block_k == 0"
        kg = jnp.pad(kg, ((0, 0), (0, pad_k), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_pallas(
        qg, kg, vg, sm_scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, interpret=interpret)
    out = out[:, :sq]
    out = out.reshape(b, hkv, group, sq, d)
    out = jnp.moveaxis(out, (1, 2), (2, 3)).reshape(b, sq, hq, d)
    return out
