"""Pure-jnp oracle: dense softmax attention with the same variants."""
from __future__ import annotations

import jax.numpy as jnp


def ref_attention(q, k, v, *, sm_scale: float, causal: bool = False,
                  window: int = 0, softcap: float = 0.0):
    """q, k, v: [BH, Sq, d] / [BH, Sk, d] -> [BH, Sq, d]."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * sm_scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    sq, sk = s.shape[-2], s.shape[-1]
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0, 1.0, denom)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
