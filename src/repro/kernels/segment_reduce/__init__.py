from repro.kernels.segment_reduce.ops import segment_reduce
