"""Pure-jnp oracle for segment_reduce."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_segment_reduce(data, segment_ids, num_segments: int,
                       op: str = "sum"):
    if op == "sum":
        return jax.ops.segment_sum(data, segment_ids,
                                   num_segments=num_segments)
    if op == "min":
        return jax.ops.segment_min(data, segment_ids,
                                   num_segments=num_segments)
    if op == "max":
        return jax.ops.segment_max(data, segment_ids,
                                   num_segments=num_segments)
    raise ValueError(op)
