"""Segment-reduce Pallas kernel (sum / min / max) over int32 segment ids.

This is the shared primitive behind GNN message passing (scatter of edge
messages into destination nodes), the recsys embedding bag, and the
sorted-edge fast path of the hook reduction. JAX has no CSR SpMM on TPU;
``gather -> segment_reduce`` IS the message-passing implementation in
this framework (see DESIGN.md §3).

Tiling: 1-D sequential grid over message tiles; the (S, D) output
accumulator stays VMEM-resident across grid steps (initialized at step 0,
functional scatter-reduce per tile). S·D·4 bytes must fit VMEM alongside
one (T, D) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def reduce_identity(op: str, dtype) -> jnp.ndarray:
    """Identity element for the reduction, dtype-aware (int or float)."""
    if op == "sum":
        return jnp.zeros((), dtype)
    big = (jnp.asarray(jnp.inf, dtype)
           if jnp.issubdtype(dtype, jnp.floating)
           else jnp.asarray(jnp.iinfo(dtype).max, dtype))
    return big if op == "min" else -big


def _segment_reduce_kernel(data_ref, ids_ref, out_ref, op: str):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(
            out_ref[...], reduce_identity(op, out_ref.dtype))

    vals = data_ref[...]
    ids = ids_ref[...]
    acc = out_ref[...]
    if op == "sum":
        acc = acc.at[ids].add(vals)
    elif op == "min":
        acc = acc.at[ids].min(vals)
    else:
        acc = acc.at[ids].max(vals)
    out_ref[...] = acc


def segment_reduce_pallas(data: jnp.ndarray, segment_ids: jnp.ndarray,
                          num_segments: int, *, op: str = "sum",
                          tile: int = 1024, interpret: bool = True
                          ) -> jnp.ndarray:
    """data: [N, D]; segment_ids: [N] int32 (< num_segments); -> [S, D]."""
    n, d = data.shape
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    assert op in ("sum", "min", "max"), op
    grid = (n // tile,)
    kernel = functools.partial(_segment_reduce_kernel, op=op)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), data.dtype),
        interpret=interpret,
    )(data, segment_ids)
