"""Jit'd wrapper for segment_reduce (pads, masks, dispatches)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.segment_reduce.segment_reduce import (
    reduce_identity,
    segment_reduce_pallas,
)


@functools.partial(
    jax.jit, static_argnames=("num_segments", "op", "tile", "interpret"))
def segment_reduce(data: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int, *, op: str = "sum", tile: int = 1024,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed segment reduce. ``data`` [N, D] or [N]; ids [N]."""
    interpret = default_interpret() if interpret is None else interpret
    squeeze = data.ndim == 1
    if squeeze:
        data = data[:, None]
    n = data.shape[0]
    target = ((n + tile - 1) // tile) * tile
    if target != n:
        # pad with identity elements routed to segment 0
        pad_val = jnp.full((target - n, data.shape[1]),
                           reduce_identity(op, data.dtype))
        data = jnp.concatenate([data, pad_val], axis=0)
        segment_ids = jnp.concatenate(
            [segment_ids,
             jnp.zeros((target - n,), segment_ids.dtype)], axis=0)
    out = segment_reduce_pallas(data, segment_ids, num_segments, op=op,
                                tile=tile, interpret=interpret)
    if squeeze:
        out = out[:, 0]
    return out
