"""Pallas TPU kernels for the framework's compute hot spots.

Each subpackage ships three modules:
  * ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
    VMEM tiling (TPU is the target; validated under ``interpret=True``
    on CPU);
  * ``ops.py``    — the jit'd public wrapper;
  * ``ref.py``    — the pure-jnp oracle the kernel is tested against.

Kernel inventory (see DESIGN.md §2 for why these are the hot spots):
  * cc_fused        — the WHOLE Fig. 4 segment scan (every hook round +
                      every compress sweep) in ONE pallas_call with
                      scalar-prefetched segment boundaries (DESIGN.md
                      §8; replaces num_segments + jump_sweeps launches).
  * multi_jump      — fused Compress: blocked pointer jumping with
                      continuous write-back (the paper's Multi-Jump).
  * hook            — deterministic Atomic-Hook analogue: edge-tile
                      gather + high-low rule + scatter-min into the
                      VMEM-resident parent workspace.
  * segment_reduce  — segment sum/min/max over sorted ids (GNN message
                      passing + the hook reduction share this primitive).
  * embedding_bag   — gather + segment-sum (recsys hot path).
  * flash_attention — blocked online-softmax attention with causal /
                      sliding-window / logit-softcap variants (LM hot path).
"""


def default_interpret() -> bool:
    """Pallas kernels run compiled on TPU, interpreted elsewhere."""
    import jax
    return jax.default_backend() != "tpu"
