from repro.kernels.hook.ops import hook_edges_pallas
