"""Atomic-Hook Pallas kernel — deterministic TPU analogue of the paper's
CAS root-chase hook.

GPU version: per edge, walk up from H = max(pi(u), pi(v)) until a root is
acquired with ``CAS(pi(H), H, L)``; failed CAS retries with (pi(H), L).

TPU mapping (DESIGN.md §2): per *edge tile*, gather both endpoint parents,
perform a bounded vectorized lift (the root chase), apply the high-to-low
rule, and merge candidates into the VMEM-resident parent workspace with a
functional scatter-min — the race-free winner selection CAS provides
nondeterministically. The 1-D grid over edge tiles runs sequentially, so
later tiles observe earlier tiles' hooks (the same memory-visibility
benefit the GPU kernel gets from global-memory atomics).

On real TPU hardware Mosaic lowers the 1-D ``.at[].min`` scatter via a
sort+segment-reduce; the sorted-edge fast path (pre-sorting edge tiles by
H at partition time) is exposed through ``repro.kernels.segment_reduce``
and evaluated in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hook_kernel(edges_ref, pi_in_ref, pi_ref, *, lift_steps: int):
    del pi_in_ref                          # aliased with pi_ref
    pi = pi_ref[...]
    u = edges_ref[:, 0]
    v = edges_ref[:, 1]
    pu = jnp.take(pi, u, axis=0)
    pv = jnp.take(pi, v, axis=0)
    for _ in range(lift_steps):            # bounded vectorized root chase
        pu = jnp.take(pi, pu, axis=0)
        pv = jnp.take(pi, pv, axis=0)
    hi = jnp.maximum(pu, pv)
    lo = jnp.minimum(pu, pv)
    pi_ref[...] = pi.at[hi].min(lo)        # deterministic CAS analogue


def hook_pallas(pi: jnp.ndarray, edges: jnp.ndarray, *,
                edge_tile: int = 1024, lift_steps: int = 2,
                interpret: bool = True) -> jnp.ndarray:
    """Hook every edge into π (edge-tiled; π VMEM-resident throughout)."""
    e = edges.shape[0]
    v = pi.shape[0]
    assert e % edge_tile == 0, f"|E|={e} must be a multiple of {edge_tile}"
    grid = (e // edge_tile,)
    kernel = functools.partial(_hook_kernel, lift_steps=lift_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((edge_tile, 2), lambda i: (i, 0)),
            pl.BlockSpec((v,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((v,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((v,), pi.dtype),
        input_output_aliases={1: 0},       # π is read-modify-write
        interpret=interpret,
    )(edges, pi)
