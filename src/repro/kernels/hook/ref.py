"""Pure-jnp oracle for the hook kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ref_hook_round(pi: jnp.ndarray, edges: jnp.ndarray,
                   lift_steps: int = 0) -> jnp.ndarray:
    """One functional hook round (all edges see the same π snapshot)."""
    u, v = edges[:, 0], edges[:, 1]
    pu, pv = pi[u], pi[v]
    for _ in range(lift_steps):
        pu, pv = pi[pu], pi[pv]
    hi = jnp.maximum(pu, pv)
    lo = jnp.minimum(pu, pv)
    return pi.at[hi].min(lo)


def ref_hook_tiled(pi, edges, edge_tile: int, lift_steps: int = 0
                   ) -> jnp.ndarray:
    """Bit-exact oracle of the kernel's *sequential-tile* semantics:
    tile t observes the hooks of tiles < t."""
    pi = np.asarray(pi).copy()
    edges = np.asarray(edges)
    for start in range(0, edges.shape[0], edge_tile):
        tile = edges[start:start + edge_tile]
        pu = pi[tile[:, 0]]
        pv = pi[tile[:, 1]]
        for _ in range(lift_steps):
            pu, pv = pi[pu], pi[pv]
        hi = np.maximum(pu, pv)
        lo = np.minimum(pu, pv)
        np.minimum.at(pi, hi, lo)
    return jnp.asarray(pi)
