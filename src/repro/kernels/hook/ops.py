"""Jit'd wrapper for the hook kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.hook.hook import hook_pallas


@functools.partial(jax.jit,
                   static_argnames=("edge_tile", "lift_steps", "interpret"))
def hook_edges_pallas(pi: jnp.ndarray, edges: jnp.ndarray, *,
                      edge_tile: int = 1024, lift_steps: int = 2,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Hook all ``edges`` into π (pads the edge list with (0,0) no-ops)."""
    interpret = default_interpret() if interpret is None else interpret
    e = edges.shape[0]
    target = ((e + edge_tile - 1) // edge_tile) * edge_tile
    if target != e:
        edges = jnp.concatenate(
            [edges, jnp.zeros((target - e, 2), edges.dtype)], axis=0)
    return hook_pallas(pi, edges, edge_tile=edge_tile,
                       lift_steps=lift_steps, interpret=interpret)
