"""EmbeddingBag Pallas kernel — the recsys lookup hot path.

JAX has no native ``nn.EmbeddingBag``; this framework implements it as
gather + segment reduction (DESIGN.md §3). The kernel tiles over *bags*:
each grid step gathers the rows for a tile of bags and reduces them
(sum / mean) into the output tile.

Tiling: grid is 1-D over bag tiles. The table is passed whole (VMEM) —
appropriate for the *per-shard* table slice after the 'model'-axis row
sharding in ``repro.models.recsys`` (a 2^20-row table row-sharded 16
ways is 4 MiB/shard at dim 16). An HBM+DMA variant is the documented
path for unsharded 10^8-row tables.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _embedding_bag_kernel(table_ref, idx_ref, out_ref, combine: str):
    table = table_ref[...]                       # [Vocab, D]
    idx = idx_ref[...]                           # [Tb, bag]
    rows = jnp.take(table, idx.reshape(-1), axis=0)
    rows = rows.reshape(idx.shape[0], idx.shape[1], -1)
    agg = rows.sum(axis=1)
    if combine == "mean":
        agg = agg / idx.shape[1]
    out_ref[...] = agg.astype(out_ref.dtype)


def embedding_bag_pallas(table: jnp.ndarray, indices: jnp.ndarray, *,
                         combine: str = "sum", bag_tile: int = 256,
                         interpret: bool = True) -> jnp.ndarray:
    """table: [Vocab, D]; indices: [B, bag] int32 -> [B, D]."""
    b, bag = indices.shape
    vocab, d = table.shape
    assert b % bag_tile == 0, f"B={b} must be a multiple of {bag_tile}"
    assert combine in ("sum", "mean"), combine
    grid = (b // bag_tile,)
    kernel = functools.partial(_embedding_bag_kernel, combine=combine)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((vocab, d), lambda i: (0, 0)),
            pl.BlockSpec((bag_tile, bag), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bag_tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(table, indices)
