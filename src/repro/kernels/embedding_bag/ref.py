"""Pure-jnp oracle for embedding_bag."""
from __future__ import annotations

import jax.numpy as jnp


def ref_embedding_bag(table, indices, combine: str = "sum"):
    rows = jnp.take(table, indices, axis=0)      # [B, bag, D]
    agg = rows.sum(axis=1)
    if combine == "mean":
        agg = agg / indices.shape[1]
    return agg
