"""Jit'd wrapper for embedding_bag."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.embedding_bag.embedding_bag import embedding_bag_pallas


@functools.partial(jax.jit,
                   static_argnames=("combine", "bag_tile", "interpret"))
def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray, *,
                  combine: str = "sum", bag_tile: int = 256,
                  interpret: bool | None = None) -> jnp.ndarray:
    """EmbeddingBag lookup: [Vocab,D] table, [B,bag] indices -> [B,D]."""
    interpret = default_interpret() if interpret is None else interpret
    b = indices.shape[0]
    tile = min(bag_tile, b)
    target = ((b + tile - 1) // tile) * tile
    padded = indices
    if target != b:
        padded = jnp.concatenate(
            [indices, jnp.zeros((target - b, indices.shape[1]),
                                indices.dtype)], axis=0)
    out = embedding_bag_pallas(table, padded, combine=combine,
                               bag_tile=tile, interpret=interpret)
    return out[:b]
