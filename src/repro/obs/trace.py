"""Zero-dependency host-side span tracer (DESIGN.md §12).

The runtime twin of ``ExecutionPlan.explain()``: *what actually ran*,
span by span, with the plan provenance (backend, shape bucket,
forced/autotune/heuristic) and tenant id attached to every event —
so "what did tick 4 of tenant B do, under which plan, and at what
latency?" is answerable from a trace file instead of a debugger.

Design constraints, in priority order:

1. **Disabled mode is (nearly) free.** ``span(...)`` checks ONE
   module-level flag and returns a shared stateless null context
   manager — no allocation, no clock read, no try/except. The ``api``
   benchmark gates this: disabled-mode tracing must cost <= 5% of a
   facade dispatch.
2. **Bounded memory.** Finished spans land in a fixed-capacity ring
   buffer (``EventLog``); a long-lived service overwrites its oldest
   events instead of growing without bound. ``dropped`` says how many
   fell off.
3. **Zero dependencies.** Pure stdlib. The optional
   ``jax.profiler.TraceAnnotation`` / ``StepTraceAnnotation`` bridge
   (``enable(jax_annotations=True)``) is imported lazily so device
   profiles line up with host spans when a profiler session is active,
   and costs nothing otherwise.

Host **counters** (``count(name)``) are always on — they are plain
dict increments used for process-wide facts that must not depend on
when ``enable()`` was called: autotune cache hits/misses
(``connectivity.policy``) and legacy deprecation-shim traffic
(``repro._deprecation``). They surface in
``ConnectivityService.obs_summary()`` and the JSONL export.

Exports: ``export_jsonl`` writes one JSON object per span (plus a
trailing ``counters`` record); ``export_chrome_trace`` writes the
Chrome ``trace_event`` format (complete "X" events, µs timebase) —
loadable directly in Perfetto / chrome://tracing. ``python -m
repro.obs`` renders either.
"""
from __future__ import annotations

import json
import time
from typing import Optional

_ENABLED = False        # THE module-level fast-path flag (see enable())


class _NullSpan:
    """Shared stateless no-op span — what ``span()`` returns while
    tracing is disabled. One instance serves every call site."""

    __slots__ = ()
    enabled = False

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def tag(self, **tags) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class EventLog:
    """Fixed-capacity ring buffer of finished-span records.

    ``append`` is O(1) and never allocates past ``capacity``; once
    full, the oldest event is overwritten (``dropped`` counts how many
    fell off). ``events()`` returns the retained records oldest-first.
    """

    __slots__ = ("capacity", "_buf", "_n")

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be > 0, got {capacity}")
        self.capacity = capacity
        self._buf: list = [None] * capacity
        self._n = 0

    def append(self, event: dict) -> None:
        self._buf[self._n % self.capacity] = event
        self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def total(self) -> int:
        """Events ever appended (retained + dropped)."""
        return self._n

    @property
    def dropped(self) -> int:
        """Events overwritten by wraparound."""
        return max(0, self._n - self.capacity)

    def events(self) -> list:
        """Retained events, oldest first (wraparound-corrected)."""
        if self._n <= self.capacity:
            return list(self._buf[: self._n])
        i = self._n % self.capacity
        return self._buf[i:] + self._buf[:i]

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._n = 0


class Span:
    """One live span. Use via ``with span("name", tenant=..., **tags)``;
    ``tag(...)`` attaches facts learned mid-span (the policy route, the
    retired-request count) before it closes."""

    __slots__ = ("name", "tenant", "step", "tags", "depth",
                 "_tracer", "_t0_ns", "_annotation")
    enabled = True

    def __init__(self, tracer: "Tracer", name: str,
                 tenant: Optional[str], step: Optional[int],
                 tags: dict):
        self.name = name
        self.tenant = tenant
        self.step = step
        self.tags = tags
        self.depth = 0
        self._tracer = tracer
        self._t0_ns = 0
        self._annotation = None

    def tag(self, **tags) -> "Span":
        self.tags.update(tags)
        return self

    def __enter__(self) -> "Span":
        t = self._tracer
        self.depth = len(t._stack)
        t._stack.append(self)
        ann = t._annotation_for(self.name, self.step)
        if ann is not None:
            ann.__enter__()
            self._annotation = ann
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur_ns = time.perf_counter_ns() - self._t0_ns
        t = self._tracer
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
        if t._stack and t._stack[-1] is self:
            t._stack.pop()
        rec = {"name": self.name,
               "ts_us": round((self._t0_ns - t._epoch_ns) / 1e3, 3),
               "dur_us": round(dur_ns / 1e3, 3),
               "depth": self.depth}
        if self.tenant is not None:
            rec["tenant"] = self.tenant
        if self.step is not None:
            rec["step"] = self.step
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        if self.tags:
            rec["tags"] = self.tags
        t.log.append(rec)
        return False


class Tracer:
    """Span factory + event log + host counters for one process."""

    def __init__(self, capacity: int = 4096):
        self.log = EventLog(capacity)
        self.counters: dict[str, int] = {}
        self._stack: list = []
        self._epoch_ns = time.perf_counter_ns()
        self._annotate = False
        self._trace_annotation = None      # jax.profiler classes, lazy
        self._step_annotation = None

    # -- span / counter entry points ----------------------------------------

    def span(self, name: str, tenant: Optional[str] = None,
             step: Optional[int] = None, **tags) -> Span:
        return Span(self, name, tenant, step, tags)

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def _annotation_for(self, name: str, step: Optional[int]):
        """The jax.profiler bridge: host spans double as device-profile
        annotations when opted in, so Perfetto device tracks line up
        with the host span tree. ``step`` spans map to
        ``StepTraceAnnotation`` (the profiler's step marker)."""
        if not self._annotate:
            return None
        if step is not None and self._step_annotation is not None:
            return self._step_annotation(name, step_num=step)
        if self._trace_annotation is not None:
            return self._trace_annotation(name)
        return None

    def enable_jax_annotations(self) -> None:
        from jax.profiler import StepTraceAnnotation, TraceAnnotation
        self._trace_annotation = TraceAnnotation
        self._step_annotation = StepTraceAnnotation
        self._annotate = True

    def reset(self) -> None:
        """Forget events, counters, and the open-span stack; restart
        the trace epoch (test/benchmark hook)."""
        self.log.clear()
        self.counters.clear()
        self._stack.clear()
        self._epoch_ns = time.perf_counter_ns()

    # -- exporters ----------------------------------------------------------

    def export_jsonl(self, path: str) -> None:
        """JSON-lines: one ``{"type": "span", ...}`` object per event,
        plus one trailing ``{"type": "counters", ...}`` record carrying
        the host counters and the ring-buffer drop count."""
        with open(path, "w") as fh:
            for ev in self.log.events():
                fh.write(json.dumps({"type": "span", **ev}) + "\n")
            fh.write(json.dumps({"type": "counters",
                                 "counters": dict(self.counters),
                                 "dropped": self.log.dropped,
                                 "total_spans": self.log.total}) + "\n")

    def export_chrome_trace(self, path: str) -> None:
        """Chrome ``trace_event`` JSON (Perfetto-loadable): complete
        "X" events on one thread track — nesting comes from ts/dur
        containment, tags ride in ``args``."""
        with open(path, "w") as fh:
            json.dump(chrome_trace_events(self.log.events()), fh)

    def summary(self) -> dict:
        """Per-span-name aggregates over the retained events:
        ``{name: {count, total_ms, p50_us, p99_us}}`` (percentiles are
        exact over the retained window — the ring buffer bounds it)."""
        return span_summary(self.log.events())


# ---------------------------------------------------------------------------
# Pure helpers shared with the CLI (which reads exported JSONL files)
# ---------------------------------------------------------------------------

def chrome_trace_events(events: list) -> dict:
    out = []
    for ev in events:
        args = dict(ev.get("tags", {}))
        if ev.get("tenant") is not None:
            args["tenant"] = ev["tenant"]
        if ev.get("step") is not None:
            args["step"] = ev["step"]
        out.append({"ph": "X", "name": ev["name"],
                    "cat": ev.get("tenant") or "repro",
                    "ts": ev["ts_us"], "dur": ev["dur_us"],
                    "pid": 0, "tid": 0, "args": args})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def span_summary(events: list) -> dict:
    by_name: dict[str, list] = {}
    for ev in events:
        by_name.setdefault(ev["name"], []).append(ev["dur_us"])
    out = {}
    for name in sorted(by_name):
        durs = sorted(by_name[name])
        n = len(durs)
        pct = lambda q: durs[min(n - 1, int(q * (n - 1) + 0.5))]  # noqa: E731
        out[name] = {"count": n,
                     "total_ms": round(sum(durs) / 1e3, 3),
                     "p50_us": round(pct(0.50), 1),
                     "p99_us": round(pct(0.99), 1)}
    return out


# ---------------------------------------------------------------------------
# The module-level API (what every instrumented site calls)
# ---------------------------------------------------------------------------

_TRACER = Tracer()


def tracer() -> Tracer:
    """The process-wide tracer."""
    return _TRACER


def enabled() -> bool:
    return _ENABLED


def enable(*, capacity: int | None = None,
           jax_annotations: bool = False) -> Tracer:
    """Turn span tracing on. ``capacity`` resizes the ring buffer
    (clearing it); ``jax_annotations=True`` additionally mirrors every
    span into ``jax.profiler`` annotations so device profiles line up
    with host spans. Host counters are unaffected (always on)."""
    global _ENABLED
    if capacity is not None and capacity != _TRACER.log.capacity:
        _TRACER.log = EventLog(capacity)
    if jax_annotations:
        _TRACER.enable_jax_annotations()
    _ENABLED = True
    return _TRACER


def disable() -> None:
    """Turn span tracing off (the default). Already-recorded events and
    counters are kept — export or ``tracer().reset()`` as needed."""
    global _ENABLED
    _ENABLED = False
    _TRACER._annotate = False


def span(name: str, tenant: Optional[str] = None,
         step: Optional[int] = None, **tags):
    """A span context manager — or the shared no-op when disabled.

    The disabled path is ONE global flag check + returning a shared
    stateless object; the ``api`` benchmark holds it to <= 5% of a
    facade dispatch."""
    if not _ENABLED:
        return _NULL_SPAN
    return _TRACER.span(name, tenant, step, **tags)


def count(name: str, n: int = 1) -> None:
    """Bump a host counter (always on — independent of ``enable()``)."""
    _TRACER.count(name, n)
