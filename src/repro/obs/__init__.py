"""``repro.obs`` — runtime telemetry for the Solver facade and the
connectivity service (DESIGN.md §12).

Three cooperating layers, all opt-in and all bounded:

* **Span tracing** (``obs.trace``): host-side ``span(...)`` context
  managers around every facade/service operation, tagged with plan
  provenance (backend, bucket, forced/autotune/heuristic) and tenant
  id; a fixed-capacity ring buffer of finished spans; JSON-lines and
  Chrome ``trace_event`` (Perfetto) exporters; an opt-in
  ``jax.profiler`` annotation bridge. Disabled (the default) it costs
  one flag check per call site.
* **On-device metrics** (``obs.metrics``): a ``Metrics`` pytree of
  int32 counters + fixed-bucket histograms threaded through the
  absorb/delete jits like ``WorkCounters`` — the instrumented
  steady-state tick stays transfer-free; host materialization only at
  ``metrics.flush()`` via the audited ``queries.to_host`` sink.
* **Latency SLOs** (``obs.slo``): per-tenant and global p50/p90/p99
  request-latency histograms on the shared ``HistogramSpec`` bucket
  math, emitted into ``BENCH_service.json``.

``python -m repro.obs summary <trace.jsonl>`` renders a trace;
``python -m repro.obs perfetto <trace.jsonl> <out.json>`` converts one
for the Perfetto UI.
"""
from repro.obs.metrics import (COUNTERS, HIST_KINDS, WORK_SPEC,
                               HistogramSpec, Metrics, flush,
                               record_mutation, record_rebuild)
from repro.obs.slo import (DEFAULT_LATENCY_SPEC, LatencyHistogram,
                           SLORecorder, merge_recorders)
from repro.obs.trace import (EventLog, Span, Tracer, count, disable,
                             enable, enabled, span, tracer)

__all__ = [
    # trace
    "span", "count", "enable", "disable", "enabled", "tracer",
    "Tracer", "Span", "EventLog",
    # metrics
    "Metrics", "HistogramSpec", "WORK_SPEC", "COUNTERS", "HIST_KINDS",
    "record_mutation", "record_rebuild", "flush",
    # slo
    "SLORecorder", "LatencyHistogram", "DEFAULT_LATENCY_SPEC",
    "merge_recorders",
]
