"""On-device metric accumulators (DESIGN.md §12).

A small ``Metrics`` pytree — named int32 counters plus fixed-size
int32 histogram bucket arrays — carried through the absorb/delete jits
exactly like ``core.rounds.WorkCounters``: updated by device programs,
merged associatively, and materialized on the host ONLY at an explicit
``flush()`` through the audited ``queries.to_host()`` sink. That keeps
the steady-state service tick transfer-free with instrumentation ON —
pinned by the ``obs.tick.*`` TraceEntries under the analysis
``transfer`` pass and by a ``jax.transfer_guard`` test.

Pytree rules (what keeps the analysis passes green and the jit caches
warm — follow these when adding a metric):

* every leaf is a fixed-shape int32 array whose leading dim is a
  power of two (the ``retrace`` pass rejects non-pow2 bucketed
  inputs); named slots index into a padded array rather than adding
  a leaf per name;
* updates are pure ``(Metrics, device scalars) -> Metrics`` functions
  with any data-dependent choice expressed as arithmetic/scatter —
  no host branching on device values;
* ``merge`` is elementwise ``+`` — associative and commutative, so
  per-tenant accumulators fold in any order into fleet totals;
* counters saturate nowhere: they are int32 adds, so flush well
  before 2^31 events (the service flushes per ``obs_summary()``).

``HistogramSpec`` is shared by the device histograms here and the
host-side latency SLO layer (``obs.slo``): log-spaced fixed bucket
edges, so a quantile read off bucket counts is exact to within one
bucket (ratio error bounded by the edge ratio — tested against an
``np.percentile`` oracle).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class HistogramSpec:
    """Fixed log-spaced bucket layout shared by device (jnp) and host
    (np) accumulators.

    ``num_bins`` buckets over ``num_bins - 1`` inner edges
    (geometrically spaced from ``lo`` to ``hi``): bucket 0 is the
    underflow ``(-inf, lo)``, bucket ``num_bins - 1`` the overflow
    ``[hi, inf)``. A quantile estimated from bucket counts is the
    geometric midpoint of the crossing bucket — off from the true
    sample quantile by at most one edge ratio (``resolution()``).
    """

    lo: float
    hi: float
    num_bins: int

    def __post_init__(self):
        if not (0 < self.lo < self.hi):
            raise ValueError(f"need 0 < lo < hi, got {self.lo}, {self.hi}")
        if self.num_bins < 4:
            raise ValueError(f"need >= 4 bins, got {self.num_bins}")

    @functools.cached_property
    def edges(self) -> np.ndarray:
        """Inner edges, float64 [num_bins - 1], log-spaced lo..hi."""
        return np.geomspace(self.lo, self.hi, self.num_bins - 1)

    def resolution(self) -> float:
        """Adjacent-edge ratio — the worst-case multiplicative error of
        ``quantile`` against the true sample quantile."""
        return float((self.hi / self.lo) ** (1.0 / (self.num_bins - 2)))

    # -- bucketing ----------------------------------------------------------

    def bucket(self, values) -> np.ndarray:
        """Host bucket index/indices for value(s)."""
        return np.searchsorted(self.edges, values, side="right")

    def bucket_device(self, value: jnp.ndarray) -> jnp.ndarray:
        """Device bucket index for a scalar (stages into the caller's
        jit; the edges array is a tiny captured const)."""
        edges = jnp.asarray(self.edges, jnp.float32)
        return jnp.searchsorted(edges, value.astype(jnp.float32),
                                side="right").astype(jnp.int32)

    def observe(self, counts: np.ndarray, value: float) -> None:
        """Host in-place increment (the SLO layer's hot path)."""
        counts[int(np.searchsorted(self.edges, value, side="right"))] += 1

    # -- reading ------------------------------------------------------------

    def quantile(self, counts: np.ndarray, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]) from bucket counts:
        the geometric midpoint of the bucket where the cumulative count
        crosses ``q * total`` (underflow reads as ``lo``, overflow as
        ``hi``). NaN when empty."""
        counts = np.asarray(counts)
        total = int(counts.sum())
        if total == 0:
            return float("nan")
        rank = max(q * total, 1e-9)
        b = int(np.searchsorted(np.cumsum(counts), rank, side="left"))
        if b <= 0:
            return float(self.lo)
        if b >= self.num_bins - 1:
            return float(self.hi)
        return float(np.sqrt(self.edges[b - 1] * self.edges[b]))


# Device work histograms: batch sizes and per-batch hook work span
# 1 .. ~1e9 over 32 bins (pow2 for the retrace pass; ratio ~2x/bucket).
WORK_SPEC = HistogramSpec(lo=1.0, hi=2.0**30, num_bins=32)

# Named counter slots. The backing array is padded to _NUM_SLOTS so the
# pytree leaf keeps a pow2 leading dim; add names here (order is ABI
# for flushed dicts only, not for device programs).
COUNTERS = (
    "absorbs",          # incremental-path insert batches
    "deletes",          # scoped-delete batches
    "rebuilds",         # mutations routed through a static engine
    "merges",           # absorbs that changed the partition (version tick)
    "splits",           # deletes that changed the partition (version tick)
    "edges_absorbed",   # true (unpadded) rows across absorb batches
    "edges_retired",    # true (unpadded) rows across delete batches
    "hook_ops",         # per-batch hook work folded from WorkCounters
    "jump_sweeps",      # pointer-jumping sweeps folded from WorkCounters
)
_NUM_SLOTS = 16
assert len(COUNTERS) <= _NUM_SLOTS

HIST_KINDS = (
    "absorb_edges",     # true batch size per absorb
    "delete_edges",     # true batch size per delete
    "absorb_hook_ops",  # hook work per absorb batch
    "delete_hook_ops",  # hook work per delete batch
)

_C = {name: i for i, name in enumerate(COUNTERS)}
_H = {name: i for i, name in enumerate(HIST_KINDS)}


class Metrics(NamedTuple):
    """The accumulator pytree: ``counts`` int32 [16] (named slots via
    ``COUNTERS``), ``hist`` int32 [4, 32] (``HIST_KINDS`` x
    ``WORK_SPEC`` buckets). NamedTuple => automatic pytree."""

    counts: jnp.ndarray
    hist: jnp.ndarray

    @staticmethod
    def zeros() -> "Metrics":
        return Metrics(
            counts=jnp.zeros((_NUM_SLOTS,), jnp.int32),
            hist=jnp.zeros((len(HIST_KINDS), WORK_SPEC.num_bins),
                           jnp.int32))

    def merge(self, other: "Metrics") -> "Metrics":
        """Elementwise sum — associative/commutative, so per-tenant and
        per-tick accumulators fold in any order."""
        return Metrics(self.counts + other.counts, self.hist + other.hist)


def _observe(hist: jnp.ndarray, row: int, value: jnp.ndarray) -> jnp.ndarray:
    return hist.at[row, WORK_SPEC.bucket_device(value)].add(1)


@functools.partial(jax.jit, static_argnames=("kind",))
def record_mutation(metrics: Metrics, batch_work, true_count,
                    version_before, version_after, *, kind: str) -> Metrics:
    """Fold one mutation batch into the accumulators — all operands are
    device scalars (or the ``WorkCounters`` delta), so this composes
    into the tick without a transfer. ``kind`` is static
    ("insert"/"delete"); the partition-change bit is
    ``version_after != version_before`` computed on device."""
    if kind == "insert":
        tick, edge_slot, change_slot = "absorbs", "edges_absorbed", "merges"
        h_edges, h_hook = "absorb_edges", "absorb_hook_ops"
    elif kind == "delete":
        tick, edge_slot, change_slot = "deletes", "edges_retired", "splits"
        h_edges, h_hook = "delete_edges", "delete_hook_ops"
    else:
        raise ValueError(f"kind must be insert|delete, got {kind!r}")
    true_count = jnp.asarray(true_count).astype(jnp.int32)
    hook_ops = jnp.asarray(batch_work.hook_ops).astype(jnp.int32)
    sweeps = jnp.asarray(batch_work.jump_sweeps).astype(jnp.int32)
    changed = (jnp.asarray(version_after)
               != jnp.asarray(version_before)).astype(jnp.int32)
    counts = (metrics.counts
              .at[_C[tick]].add(1)
              .at[_C[edge_slot]].add(true_count)
              .at[_C[change_slot]].add(changed)
              .at[_C["hook_ops"]].add(hook_ops)
              .at[_C["jump_sweeps"]].add(sweeps))
    hist = _observe(metrics.hist, _H[h_edges], true_count)
    hist = _observe(hist, _H[h_hook], hook_ops)
    return Metrics(counts, hist)


@jax.jit
def record_rebuild(metrics: Metrics) -> Metrics:
    """Count a static-rebuild adoption (bulk insert/drop routed through
    a static engine). Rebuild work is already billed through the
    engine's own ``WorkCounters``; the accumulator just counts the
    route."""
    return Metrics(metrics.counts.at[_C["rebuilds"]].add(1), metrics.hist)


def flush(metrics: Metrics) -> dict:
    """Materialize the accumulators on the host — the ONE device->host
    crossing, routed through the audited ``queries.to_host`` sink (so
    it cannot run under a tracer or inside a transfer-guarded tick).
    Returns ``{"counters": {name: int}, "histograms": {kind: {count,
    p50, p99}}}``."""
    from repro.connectivity.queries import to_host
    counts = to_host(metrics.counts)
    hist = to_host(metrics.hist)
    out = {"counters": {name: int(counts[i]) for name, i in _C.items()},
           "histograms": {}}
    for kind, row in _H.items():
        c = np.asarray(hist[row], np.int64)
        n = int(c.sum())
        entry = {"count": n}
        if n:
            entry["p50"] = round(WORK_SPEC.quantile(c, 0.50), 3)
            entry["p99"] = round(WORK_SPEC.quantile(c, 0.99), 3)
        out["histograms"][kind] = entry
    return out
