"""Latency SLO histograms (DESIGN.md §12): per-tenant and global
p50/p90/p99 for service requests.

Host-side by construction — request latency is a wall-clock fact that
only exists on the host — but built on the SAME fixed log-bucket
layout as the device accumulators (``metrics.HistogramSpec``), so the
quantile math, its one-bucket error bound, and the associative-merge
property are shared and tested once. Recording is an O(log bins)
``searchsorted`` + one int add per request; a recorder never grows
past ``tenants x kinds x num_bins`` int64 cells no matter how many
requests it sees.

Global percentiles are computed by MERGING the per-(tenant, kind)
bucket counts — exact (bucket merge is associative), not an average
of percentiles (which would be wrong).
"""
from __future__ import annotations

import numpy as np

from repro.obs.metrics import HistogramSpec

# 1µs .. 10s over 64 bins: ~1.3x per bucket across 7 decades — finer
# than any SLO threshold anyone sets, coarse enough to stay tiny.
DEFAULT_LATENCY_SPEC = HistogramSpec(lo=1e-6, hi=10.0, num_bins=64)

_PERCENTILES = (0.50, 0.90, 0.99)


class LatencyHistogram:
    """Bucket counts for one (tenant, kind) stream."""

    __slots__ = ("spec", "counts")

    def __init__(self, spec: HistogramSpec = DEFAULT_LATENCY_SPEC):
        self.spec = spec
        self.counts = np.zeros(spec.num_bins, np.int64)

    def record(self, seconds: float) -> None:
        self.spec.observe(self.counts, seconds)

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        if other.spec != self.spec:
            raise ValueError("cannot merge histograms with different specs")
        out = LatencyHistogram(self.spec)
        out.counts = self.counts + other.counts
        return out

    def quantile(self, q: float) -> float:
        """q in [0, 1]; seconds; NaN when empty."""
        return self.spec.quantile(self.counts, q)


class SLORecorder:
    """Per-(tenant, kind) latency histograms + exact merged reads.

    ``kind`` is the service request kind ("insert", "delete",
    "same_component", ...). ``percentile(q, tenant=..., kinds=...)``
    merges every matching histogram before reading — pass
    ``tenant=None`` for the global view.
    """

    def __init__(self, spec: HistogramSpec = DEFAULT_LATENCY_SPEC):
        self.spec = spec
        self._hists: dict[tuple[str, str], LatencyHistogram] = {}

    def record(self, tenant: str, kind: str, seconds: float) -> None:
        key = (tenant, kind)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = LatencyHistogram(self.spec)
        h.record(seconds)

    def hist(self, tenant: str, kind: str) -> LatencyHistogram | None:
        return self._hists.get((tenant, kind))

    def tenants(self) -> list[str]:
        return sorted({t for t, _ in self._hists})

    def kinds(self, tenant: str | None = None) -> list[str]:
        return sorted({k for t, k in self._hists
                       if tenant is None or t == tenant})

    def merged(self, tenant: str | None = None,
               kinds=None) -> LatencyHistogram:
        """One histogram over every matching (tenant, kind) stream.

        Bucket-edge compatibility is asserted per stream: summing raw
        ``counts`` across histograms is only exact when every stream
        shares the recorder's bucket layout, and a recorder whose
        ``_hists`` were populated externally (the fleet's per-device
        merge path) could otherwise silently mix layouts — the merged
        percentiles would read from the wrong edges."""
        out = LatencyHistogram(self.spec)
        for (t, k), h in self._hists.items():
            if tenant is not None and t != tenant:
                continue
            if kinds is not None and k not in kinds:
                continue
            if h.spec != self.spec:
                raise ValueError(
                    f"histogram for {(t, k)!r} has spec {h.spec}, "
                    f"recorder has {self.spec}: bucket counts are not "
                    "mergeable across different edge layouts")
            out.counts += h.counts
        return out

    def percentile(self, q: float, tenant: str | None = None,
                   kinds=None) -> float:
        """q in [0, 1]; seconds; NaN when nothing matched."""
        return self.merged(tenant, kinds).quantile(q)

    def summary(self) -> dict:
        """``{"global": {kind: {...}}, "tenants": {tenant: {kind:
        {count, p50_ms, p90_ms, p99_ms}}}}`` — milliseconds, exact
        merged global rows."""

        def row(h: LatencyHistogram) -> dict:
            out = {"count": h.count}
            for q in _PERCENTILES:
                out[f"p{int(q * 100)}_ms"] = round(h.quantile(q) * 1e3, 4)
            return out

        tenants: dict[str, dict] = {}
        for (t, k), h in sorted(self._hists.items()):
            tenants.setdefault(t, {})[k] = row(h)
        return {"global": {k: row(self.merged(kinds=(k,)))
                           for k in self.kinds()},
                "tenants": tenants}


def merge_recorders(recorders) -> SLORecorder:
    """Fold several recorders into one — the fleet's global view over
    per-device ``SLORecorder``s. Exact by the same argument as
    ``merged()``: bucket counts add associatively, so the global
    percentiles equal those of one recorder that saw every request.
    Edge compatibility is asserted across ALL inputs (recorder specs
    and each per-stream histogram) before any counts are summed."""
    recorders = list(recorders)
    spec = recorders[0].spec if recorders else DEFAULT_LATENCY_SPEC
    out = SLORecorder(spec)
    for rec in recorders:
        if rec.spec != spec:
            raise ValueError(
                f"cannot merge recorders with specs {rec.spec} != {spec}"
                ": bucket counts are not mergeable across different "
                "edge layouts")
        for (t, k), h in rec._hists.items():
            if h.spec != spec:
                raise ValueError(
                    f"histogram for {(t, k)!r} has spec {h.spec}, "
                    f"merge target has {spec}")
            tgt = out._hists.get((t, k))
            if tgt is None:
                tgt = out._hists[(t, k)] = LatencyHistogram(spec)
            tgt.counts = tgt.counts + h.counts
    return out
