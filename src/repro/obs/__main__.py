"""CLI for trace files: ``python -m repro.obs summary <trace.jsonl>``
renders per-span-name latency aggregates + counters from a JSON-lines
export; ``python -m repro.obs perfetto <trace.jsonl> <out.json>``
converts one to the Chrome ``trace_event`` format for the Perfetto UI.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.trace import chrome_trace_events, span_summary


def _load(path: str) -> tuple[list, dict, int]:
    """Parse a JSON-lines export -> (span events, counters, dropped)."""
    spans, counters, dropped = [], {}, 0
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("type") == "span":
                spans.append(rec)
            elif rec.get("type") == "counters":
                counters.update(rec.get("counters", {}))
                dropped = rec.get("dropped", 0)
    return spans, counters, dropped


def cmd_summary(path: str) -> int:
    spans, counters, dropped = _load(path)
    summ = span_summary(spans)
    if not summ:
        print(f"{path}: no spans")
    else:
        name_w = max(len(n) for n in summ) + 2
        print(f"{'span':<{name_w}}{'count':>8}{'total_ms':>12}"
              f"{'p50_us':>10}{'p99_us':>12}")
        for name, row in summ.items():
            print(f"{name:<{name_w}}{row['count']:>8}"
                  f"{row['total_ms']:>12.3f}{row['p50_us']:>10.1f}"
                  f"{row['p99_us']:>12.1f}")
    if dropped:
        print(f"\n({dropped} oldest spans dropped by the ring buffer)")
    if counters:
        print("\ncounters:")
        for name in sorted(counters):
            print(f"  {name} = {counters[name]}")
    return 0


def cmd_perfetto(path: str, out: str) -> int:
    spans, _, _ = _load(path)
    with open(out, "w") as fh:
        json.dump(chrome_trace_events(spans), fh)
    print(f"wrote {len(spans)} events to {out} "
          f"(load in https://ui.perfetto.dev or chrome://tracing)")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.obs",
                                description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summary", help="render span/counter aggregates")
    ps.add_argument("trace", help="JSON-lines trace file")
    pp = sub.add_parser("perfetto",
                        help="convert a JSONL trace to Chrome trace_event")
    pp.add_argument("trace", help="JSON-lines trace file")
    pp.add_argument("out", help="output .json path")
    args = p.parse_args(argv)
    if args.cmd == "summary":
        return cmd_summary(args.trace)
    return cmd_perfetto(args.trace, args.out)


if __name__ == "__main__":
    sys.exit(main())
