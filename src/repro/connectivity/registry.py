"""Multi-tenant graph registry: N named live graphs, versioned labels,
query-result caching with merge-precise invalidation.

Each tenant is a named vertex set with a live canonical label array
backed by ``IncrementalCC``. Inserts are routed by the adaptive policy
(``policy.select_method``): a small delta is absorbed incrementally
(hook only the new edges), a bulk load is rebuilt through the chosen
static engine and adopted. Queries run through the on-device kernels
(``queries``), with query batches padded to the power-of-two buckets of
``repro.core.batch`` so same-shape batches share one jit cache entry
across tenants.

**Version / invalidation protocol** (DESIGN.md §7): a tenant's label
*version* is ``IncrementalCC.version`` — it ticks only when an insert
batch actually merges components (the absorb jit reports ``any(labels
!= old)`` in the same device call). Cached query results are stamped
with the version they were computed at and served only while the
version is unchanged; an insert that lands entirely inside existing
components keeps every cached answer warm. Stale answers are therefore
impossible by construction: connectivity under insert-only workloads
changes exactly when labels change.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.connectivity import policy, queries
from repro.core.batch import pad_rows_pow2
from repro.core.incremental import IncrementalCC

_MAX_CACHED_RESULTS = 1024      # per tenant; FIFO-evicted


@dataclasses.dataclass
class TenantStats:
    inserts: int = 0
    absorbs: int = 0            # inserts routed through the incremental path
    rebuilds: int = 0           # inserts routed through a static engine
    merges: int = 0             # inserts that changed labels (version ticks)
    queries: int = 0
    cache_hits: int = 0


class TenantGraph:
    """One live graph: IncrementalCC state + accumulated edge log."""

    def __init__(self, name: str, num_nodes: int, *, lift_steps: int = 2,
                 policy_cache: policy.AutotuneCache | None = None):
        self.name = name
        self.num_nodes = num_nodes
        self.inc = IncrementalCC(num_nodes, lift_steps=lift_steps)
        self.policy_cache = policy_cache
        self._edge_log: list[np.ndarray] = []   # for the bulk-rebuild path
        self.stats = TenantStats()
        self.last_method = None                  # last policy decision

    @property
    def version(self) -> int:
        return self.inc.version

    @property
    def labels(self):
        return self.inc.labels

    @property
    def num_edges(self) -> int:
        return self.inc.num_edges_inserted

    def edges(self) -> np.ndarray:
        if not self._edge_log:
            return np.zeros((0, 2), np.int32)
        return np.concatenate(self._edge_log, axis=0)

    def insert(self, new_edges) -> bool:
        """Insert an edge batch; returns True iff components merged
        (the label version ticked)."""
        new_edges = np.asarray(new_edges, np.int32).reshape(-1, 2)
        if (new_edges.size and
                (new_edges.min() < 0 or new_edges.max() >= self.num_nodes)):
            raise ValueError("edge endpoint out of range "
                             f"[0, {self.num_nodes})")
        before = self.inc.version
        method = policy.select_method(
            self.num_nodes, self.num_edges,
            delta_edges=new_edges.shape[0], cache=self.policy_cache)
        self.last_method = method
        if new_edges.shape[0]:
            self._edge_log.append(new_edges)
        if method == policy.INCREMENTAL_ABSORB:
            self.inc.insert(new_edges)
            self.stats.absorbs += 1
        else:
            # bulk load: the accumulated set is mostly this batch — the
            # chosen static engine (segmentation and all) beats hooking
            # a huge unsegmented delta through the absorb loop
            from repro.core.cc import connected_components
            res = connected_components(self.edges(), self.num_nodes,
                                       method=method)
            self.inc.adopt(res.labels, work=res.work,
                           num_edges=new_edges.shape[0])
            self.stats.rebuilds += 1
        self.stats.inserts += 1
        merged = self.inc.version != before
        self.stats.merges += int(merged)
        return merged


class GraphRegistry:
    """Registry of named live graphs with version-stamped query caching."""

    def __init__(self, *, lift_steps: int = 2,
                 policy_cache: policy.AutotuneCache | None = None):
        self.lift_steps = lift_steps
        self.policy_cache = policy_cache
        self._tenants: dict[str, TenantGraph] = {}
        # per-tenant result cache: key -> (version, result); entries are
        # dropped wholesale when the tenant's version ticks (a merge)
        self._qcache: dict[str, dict] = {}

    # -- tenant lifecycle --------------------------------------------------

    def create(self, name: str, num_nodes: int) -> TenantGraph:
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        t = TenantGraph(name, num_nodes, lift_steps=self.lift_steps,
                        policy_cache=self.policy_cache)
        self._tenants[name] = t
        self._qcache[name] = {}
        return t

    def get(self, name: str) -> TenantGraph:
        if name not in self._tenants:
            raise KeyError(f"unknown tenant {name!r}; "
                           f"have {sorted(self._tenants)}")
        return self._tenants[name]

    def drop(self, name: str) -> None:
        self.get(name)
        del self._tenants[name]
        del self._qcache[name]

    def names(self) -> list[str]:
        return sorted(self._tenants)

    def __len__(self) -> int:
        return len(self._tenants)

    # -- mutation ----------------------------------------------------------

    def insert(self, name: str, edges) -> int:
        """Insert an edge batch; returns the tenant's label version.
        Cached query results are invalidated ONLY when the batch merged
        components."""
        t = self.get(name)
        if t.insert(edges):
            self._qcache[name].clear()
        return t.version

    # -- queries (cached, on-device kernels) -------------------------------

    def _cached(self, name: str, key, compute):
        t = self.get(name)
        cache = self._qcache[name]
        t.stats.queries += 1
        hit = cache.get(key)
        if hit is not None and hit[0] == t.version:
            t.stats.cache_hits += 1
            return hit[1]
        result = compute(t)
        if len(cache) >= _MAX_CACHED_RESULTS:
            cache.pop(next(iter(cache)))
        cache[key] = (t.version, result)
        return result

    def _batched_query(self, name: str, kind: str, batch: np.ndarray,
                       shape: tuple) -> np.ndarray:
        """Shared validate/pad/cache path for vertex-batch queries:
        bounds-check, pad to the power-of-two buckets (so every
        same-shape batch — across all tenants of one |V| — hits one jit
        cache entry), run the kernel, slice off the padding; cached by
        content + label version."""
        batch = np.asarray(batch, np.int32).reshape(shape)
        t = self.get(name)
        if batch.size and (batch.min() < 0 or batch.max() >= t.num_nodes):
            raise ValueError(f"vertex out of range [0, {t.num_nodes})")
        q = batch.shape[0]
        kernel = getattr(queries, kind)
        # digest, not raw bytes: keys stay O(1) even for huge batches
        digest = hashlib.blake2b(batch.tobytes(), digest_size=16).digest()
        return self._cached(
            name, (kind, batch.shape, digest),
            lambda t: np.asarray(kernel(t.labels,
                                        pad_rows_pow2(batch)))[:q])

    def same_component(self, name: str, pairs) -> np.ndarray:
        """bool [Q] for an int [Q, 2] pair batch."""
        return self._batched_query(name, "same_component", pairs, (-1, 2))

    def component_size(self, name: str, vertices) -> np.ndarray:
        """int32 [Q] component sizes for a vertex batch."""
        return self._batched_query(name, "component_size", vertices,
                                   (-1,))

    def count_components(self, name: str) -> int:
        return int(self._cached(
            name, ("count_components",),
            lambda t: queries.count_components(t.labels)))

    def component_histogram(self, name: str) -> np.ndarray:
        return np.asarray(self._cached(
            name, ("component_histogram",),
            lambda t: queries.component_histogram(t.labels)))

    # -- introspection -----------------------------------------------------

    def version(self, name: str) -> int:
        return self.get(name).version

    def stats(self) -> dict:
        out = {}
        for name, t in self._tenants.items():
            out[name] = {**dataclasses.asdict(t.stats),
                         "version": t.version,
                         "num_nodes": t.num_nodes,
                         "num_edges": t.num_edges,
                         "hook_ops": t.inc.work["hook_ops"]}
        return out
