"""Multi-tenant graph registry: N named live graphs, versioned labels,
query-result caching with partition-precise invalidation.

Each tenant is a named vertex set with a live canonical label array
backed by a ``repro.api.Solver`` session (DESIGN.md §10) — the facade
owns the policy routing and the fully-dynamic state (labels +
device-resident tombstone edge log), the tenant layer adds naming,
stats, and query caching. Inserts are routed by the adaptive policy
(``policy.select_for``): a small delta is absorbed incrementally
(hook only the new edges), a bulk load is rebuilt through the chosen
static backend and adopted. Deletes are routed by the delete-rate twin
(DESIGN.md §9): a small batch tombstones + scoped-recomputes only the
affected components, a bulk drop rebuilds the survivors statically.
Queries run through the on-device kernels (``queries``), with query
batches padded to the power-of-two buckets of ``repro.core.batch`` so
same-shape batches share one jit cache entry across tenants.

**Version / invalidation protocol** (DESIGN.md §7, §9): a tenant's
label *version* is ``DynamicCC``'s device-resident version counter —
it ticks only when a mutation actually changes the partition: the
absorb jit detects a MERGE, the delete jit detects a SPLIT, both via
``any(labels != old)`` IN the same device program (neither path syncs
it to the host). Cached query results are stamped with the version
they were computed at and served only while the version is unchanged —
validation happens lazily at query time (one scalar sync on a path
that syncs anyway to return the answer), so an insert landing inside
existing components or a non-bridge delete keeps every cached answer
warm, and stale answers are impossible by construction: connectivity
changes exactly when canonical labels change. Superseded entries age
out via FIFO eviction.

**DeviceGraph substrate** (DESIGN.md §8): insert batches are
``DeviceGraph``s (host arrays go through the ``from_edges`` shim with
bounds validation); the edge log is a list of DeviceGraphs whose bulk
rebuilds concatenate ON DEVICE; policy features (density, update rate)
come from static DeviceGraph metadata. The steady-state insert path —
coalescing, feature extraction, absorb, version tick — performs zero
host transfers (tested under ``jax.transfer_guard("disallow")``).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.connectivity import policy, queries
from repro.graphs.device import DeviceGraph

_MAX_CACHED_RESULTS = 1024      # per tenant; FIFO-evicted


@dataclasses.dataclass
class TenantStats:
    # partition-change counts are NOT tracked here: the device-resident
    # version counter ticks exactly on merging inserts AND splitting
    # deletes, so registry.stats() reports it as "partition_changes" (a
    # host field would force a sync per mutation)
    inserts: int = 0
    deletes: int = 0            # delete requests
    absorbs: int = 0            # inserts routed through the incremental path
    scoped_deletes: int = 0     # deletes routed through the scoped recompute
    rebuilds: int = 0           # mutations routed through a static engine
    queries: int = 0
    cache_hits: int = 0


class TenantGraph:
    """One live graph: a ``repro.api.Solver`` session (the facade owns
    the policy routing, the dynamic state, and the transfer-free
    steady-state contract; the tenant layer adds naming + stats)."""

    def __init__(self, name: str, num_nodes: int, *, lift_steps: int = 2,
                 policy_cache: policy.AutotuneCache | None = None,
                 device=None):
        from repro.api import Solver       # lazy: the api chain imports us
        self.name = name
        self.num_nodes = num_nodes
        self.solver = Solver.open(num_nodes=num_nodes,
                                  lift_steps=lift_steps,
                                  policy_cache=policy_cache, name=name,
                                  device=device)
        self.policy_cache = policy_cache
        self.device = device
        self.stats = TenantStats()

    @property
    def inc(self):
        """The live dynamic engine (``DynamicCC``) behind the facade."""
        return self.solver.state

    @property
    def last_method(self):
        """Last policy decision (the facade records it)."""
        return self.solver.last_method

    @property
    def version(self) -> int:
        """Label version as a host int (syncs; query-path use)."""
        return self.solver.version

    @property
    def version_device(self):
        """Label version as a device scalar (no sync; insert-path use)."""
        return self.solver.version_device

    @property
    def labels(self):
        return self.solver.labels

    @property
    def num_edges(self) -> int:
        """Inserted-edge total (host-known, no sync) — the policy's
        size feature. Under churn this is an upper bound on the alive
        count (the exact count lives on device; syncing it per
        mutation would defeat the transfer-free tick)."""
        return self.solver.num_edges

    def graph(self) -> DeviceGraph:
        """The SURVIVING edge set as ONE compacted DeviceGraph (the
        tombstone log's alive view — no host ``np.concatenate``)."""
        return self.solver.graph()

    def edges(self) -> np.ndarray:
        """Host view of the surviving edges (syncs; introspection)."""
        g = self.graph()
        t = g.true_edges_static
        return queries.to_host(g.edges)[: int(g.true_edges) if t is None
                                        else t]

    def _routed(self, call, arg) -> None:
        """Run a facade mutation and fold the solver's OWN route
        counters (taken at the decision point) into the tenant stats —
        no re-derivation from ``last_method`` strings that could drift
        from the solver's actual classification."""
        before = dict(self.solver.stats)
        call(arg)
        after = self.solver.stats
        for field in ("inserts", "deletes", "absorbs", "scoped_deletes",
                      "rebuilds"):
            setattr(self.stats, field,
                    getattr(self.stats, field)
                    + after[field] - before[field])

    def insert(self, new_edges) -> None:
        """Insert an edge batch (DeviceGraph or host array) through the
        facade. The merge decision (version tick) happens ON DEVICE
        inside the absorb — this path never syncs; read
        ``version``/``version_device`` to observe it."""
        self._routed(self.solver.insert, new_edges)

    def delete(self, dels) -> None:
        """Delete an edge batch (DeviceGraph or host array; each row
        retires every alive copy of that undirected edge, absent rows
        are no-ops) through the facade: small batch → tombstone +
        scoped recompute (version ticks iff a component actually
        split), bulk drop → static rebuild over survivors. Never
        syncs."""
        self._routed(self.solver.delete, dels)


class GraphRegistry:
    """Registry of named live graphs with version-stamped query caching."""

    def __init__(self, *, lift_steps: int = 2,
                 policy_cache: policy.AutotuneCache | None = None,
                 device=None):
        self.lift_steps = lift_steps
        self.policy_cache = policy_cache
        # pin every tenant session to one device (the fleet's per-device
        # shell mode); None keeps the process default
        self.device = device
        self._tenants: dict[str, TenantGraph] = {}
        # per-tenant result cache: key -> (version, result); entries are
        # dropped wholesale when the tenant's version ticks (a merge)
        self._qcache: dict[str, dict] = {}

    # -- tenant lifecycle --------------------------------------------------

    def create(self, name: str, num_nodes: int) -> TenantGraph:
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        t = TenantGraph(name, num_nodes, lift_steps=self.lift_steps,
                        policy_cache=self.policy_cache,
                        device=self.device)
        self._tenants[name] = t
        self._qcache[name] = {}
        return t

    def get(self, name: str) -> TenantGraph:
        if name not in self._tenants:
            raise KeyError(f"unknown tenant {name!r}; "
                           f"have {sorted(self._tenants)}")
        return self._tenants[name]

    def drop(self, name: str) -> None:
        self.get(name)
        del self._tenants[name]
        del self._qcache[name]

    def names(self) -> list[str]:
        return sorted(self._tenants)

    def __contains__(self, name: str) -> bool:
        return name in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    # -- mutation ----------------------------------------------------------

    def insert(self, name: str, edges):
        """Insert an edge batch (DeviceGraph or host array); returns the
        tenant's label version as a DEVICE scalar (the insert path never
        syncs — ``int(...)`` it to observe). Cached query results are
        invalidated ONLY when the batch merged components: entries are
        version-stamped and validated lazily at query time, so no eager
        host-side merge check is needed here."""
        t = self.get(name)
        t.insert(edges)
        return t.version_device

    def delete(self, name: str, edges):
        """Delete an edge batch (DeviceGraph or host array); returns
        the tenant's label version as a DEVICE scalar (the delete path
        never syncs). Cached query results are invalidated ONLY when
        the batch actually SPLIT a component: the version tick happens
        on device inside the delete program (a non-bridge deletion
        reproduces the identical canonical partition), so the same
        lazy version-stamped validation that keeps insert-path answers
        stale-free extends across splits unchanged."""
        t = self.get(name)
        t.delete(edges)
        return t.version_device

    # -- queries (cached, on-device kernels) -------------------------------

    def _cached(self, name: str, key, compute):
        t = self.get(name)
        cache = self._qcache[name]
        t.stats.queries += 1
        hit = cache.get(key)
        if hit is not None and hit[0] == t.version:
            t.stats.cache_hits += 1
            return hit[1]
        result = compute(t)
        if len(cache) >= _MAX_CACHED_RESULTS:
            cache.pop(next(iter(cache)))
        cache[key] = (t.version, result)
        return result

    def _batched_query(self, name: str, kind: str, batch: np.ndarray,
                       shape: tuple) -> np.ndarray:
        """Version-stamped cache over the facade's batch-query path —
        the ONE validate/pad/slice implementation lives on ``Solver``
        (bounds check, pow2 bucket padding so every same-shape batch
        across all tenants of one |V| hits one jit cache entry); this
        layer only adds content-digest caching."""
        batch = np.asarray(batch, np.int32).reshape(shape)
        # digest, not raw bytes: keys stay O(1) even for huge batches
        digest = hashlib.blake2b(batch.tobytes(), digest_size=16).digest()
        return self._cached(
            name, (kind, batch.shape, digest),
            lambda t: getattr(t.solver, kind)(batch))

    def same_component(self, name: str, pairs) -> np.ndarray:
        """bool [Q] for an int [Q, 2] pair batch."""
        return self._batched_query(name, "same_component", pairs, (-1, 2))

    def component_size(self, name: str, vertices) -> np.ndarray:
        """int32 [Q] component sizes for a vertex batch."""
        return self._batched_query(name, "component_size", vertices,
                                   (-1,))

    def count_components(self, name: str) -> int:
        return int(self._cached(
            name, ("count_components",),
            lambda t: t.solver.num_components()))

    def component_histogram(self, name: str) -> np.ndarray:
        return queries.to_host(self._cached(
            name, ("component_histogram",),
            lambda t: t.solver.component_histogram()))

    # -- introspection -----------------------------------------------------

    def version(self, name: str) -> int:
        return self.get(name).version

    def stats(self) -> dict:
        out = {}
        for name, t in self._tenants.items():
            version = t.version            # introspection path: sync OK
            out[name] = {**dataclasses.asdict(t.stats),
                         # the version ticks exactly on merging inserts
                         # and splitting deletes, so it IS the
                         # partition-change count (tracked on device)
                         "partition_changes": version,
                         "version": version,
                         "num_nodes": t.num_nodes,
                         "num_edges": t.num_edges,
                         "num_edges_deleted": t.inc.num_edges_deleted,
                         "hook_ops": t.inc.work["hook_ops"]}
        return out
