"""Multi-tenant graph registry: N named live graphs, versioned labels,
query-result caching with partition-precise invalidation.

Each tenant is a named vertex set with a live canonical label array
backed by the fully-dynamic ``DynamicCC`` (labels + device-resident
tombstone edge log). Inserts are routed by the adaptive policy
(``policy.select_method``): a small delta is absorbed incrementally
(hook only the new edges), a bulk load is rebuilt through the chosen
static engine and adopted. Deletes are routed by the delete-rate twin
(DESIGN.md §9): a small batch tombstones + scoped-recomputes only the
affected components, a bulk drop rebuilds the survivors statically.
Queries run through the on-device kernels (``queries``), with query
batches padded to the power-of-two buckets of ``repro.core.batch`` so
same-shape batches share one jit cache entry across tenants.

**Version / invalidation protocol** (DESIGN.md §7, §9): a tenant's
label *version* is ``DynamicCC``'s device-resident version counter —
it ticks only when a mutation actually changes the partition: the
absorb jit detects a MERGE, the delete jit detects a SPLIT, both via
``any(labels != old)`` IN the same device program (neither path syncs
it to the host). Cached query results are stamped with the version
they were computed at and served only while the version is unchanged —
validation happens lazily at query time (one scalar sync on a path
that syncs anyway to return the answer), so an insert landing inside
existing components or a non-bridge delete keeps every cached answer
warm, and stale answers are impossible by construction: connectivity
changes exactly when canonical labels change. Superseded entries age
out via FIFO eviction.

**DeviceGraph substrate** (DESIGN.md §8): insert batches are
``DeviceGraph``s (host arrays go through the ``from_edges`` shim with
bounds validation); the edge log is a list of DeviceGraphs whose bulk
rebuilds concatenate ON DEVICE; policy features (density, update rate)
come from static DeviceGraph metadata. The steady-state insert path —
coalescing, feature extraction, absorb, version tick — performs zero
host transfers (tested under ``jax.transfer_guard("disallow")``).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.connectivity import policy, queries
from repro.core.batch import pad_rows_pow2
from repro.core.incremental import DynamicCC
from repro.graphs.device import DeviceGraph, validate_edge_bounds

_MAX_CACHED_RESULTS = 1024      # per tenant; FIFO-evicted


@dataclasses.dataclass
class TenantStats:
    # partition-change counts are NOT tracked here: the device-resident
    # version counter ticks exactly on merging inserts AND splitting
    # deletes, so registry.stats() reports it as "partition_changes" (a
    # host field would force a sync per mutation)
    inserts: int = 0
    deletes: int = 0            # delete requests
    absorbs: int = 0            # inserts routed through the incremental path
    scoped_deletes: int = 0     # deletes routed through the scoped recompute
    rebuilds: int = 0           # mutations routed through a static engine
    queries: int = 0
    cache_hits: int = 0


class TenantGraph:
    """One live graph: fully-dynamic ``DynamicCC`` state (labels +
    device-resident tombstone edge log)."""

    def __init__(self, name: str, num_nodes: int, *, lift_steps: int = 2,
                 policy_cache: policy.AutotuneCache | None = None):
        self.name = name
        self.num_nodes = num_nodes
        self.inc = DynamicCC(num_nodes, lift_steps=lift_steps)
        self.policy_cache = policy_cache
        self.stats = TenantStats()
        self.last_method = None                  # last policy decision

    @property
    def version(self) -> int:
        """Label version as a host int (syncs; query-path use)."""
        return self.inc.version

    @property
    def version_device(self):
        """Label version as a device scalar (no sync; insert-path use)."""
        return self.inc.version_device

    @property
    def labels(self):
        return self.inc.labels

    @property
    def num_edges(self) -> int:
        """Inserted-edge total (host-known, no sync) — the policy's
        size feature. Under churn this is an upper bound on the alive
        count (the exact count lives on device; syncing it per
        mutation would defeat the transfer-free tick)."""
        return self.inc.num_edges_inserted

    def graph(self) -> DeviceGraph:
        """The SURVIVING edge set as ONE compacted DeviceGraph (the
        tombstone log's alive view — no host ``np.concatenate``)."""
        if self.inc.log.rows == 0:
            return DeviceGraph.from_edges(
                np.zeros((0, 2), np.int32), self.num_nodes,
                name=self.name)
        return self.inc.graph()

    def edges(self) -> np.ndarray:
        """Host view of the surviving edges (syncs; introspection)."""
        g = self.graph()
        t = g.true_edges_static
        return np.asarray(g.edges)[: int(g.true_edges) if t is None
                                   else t]

    def _coerce(self, new_edges) -> DeviceGraph:
        """Host arrays are validated + device_put; DeviceGraphs pass
        through untouched (no sync — the caller owns bounds there)."""
        if isinstance(new_edges, DeviceGraph):
            if new_edges.num_nodes != self.num_nodes:
                raise ValueError(
                    f"delta num_nodes {new_edges.num_nodes} != "
                    f"{self.num_nodes}")
            return new_edges
        arr = np.asarray(new_edges, np.int32).reshape(-1, 2)
        validate_edge_bounds(arr, self.num_nodes)
        return DeviceGraph.from_edges(arr, self.num_nodes,
                                      name=self.name)

    def insert(self, new_edges) -> None:
        """Insert an edge batch (DeviceGraph or host array). The merge
        decision (version tick) happens ON DEVICE inside the absorb —
        this path never syncs; read ``version``/``version_device`` to
        observe it."""
        delta = self._coerce(new_edges)
        method = policy.select_for(self.num_nodes, self.num_edges,
                                   delta, cache=self.policy_cache)
        self.last_method = method
        if method == policy.INCREMENTAL_ABSORB:
            self.inc.insert_graph(delta)     # logs + absorbs
            self.stats.absorbs += 1
        else:
            # bulk load: the accumulated set is mostly this batch — the
            # chosen static engine (segmentation and all) beats hooking
            # a huge unsegmented delta through the absorb loop
            from repro.core.cc import connected_components
            self.inc.stage(delta)            # log only; adopt accounts
            res = connected_components(self.graph(), method=method)
            self.inc.adopt(res.labels, work=res.work,
                           num_edges=delta.num_edges)
            self.stats.rebuilds += 1
        self.stats.inserts += 1

    def delete(self, dels) -> None:
        """Delete an edge batch (DeviceGraph or host array; each row
        retires every alive copy of that undirected edge, absent rows
        are no-ops). Routed by the delete-rate policy: a small batch
        tombstones + scoped-recomputes in ONE device program
        (``DynamicCC.delete_graph`` — the version ticks iff a
        component actually split, mirroring the insert path's merge
        tick); a bulk drop tombstones and rebuilds the survivors
        through a static engine. Never syncs."""
        batch = self._coerce(dels)
        method = policy.select_for(self.num_nodes, self.num_edges,
                                   batch, delete=True,
                                   cache=self.policy_cache)
        self.last_method = method
        if method in policy.DELETE_METHODS:
            self.inc.scan_method = \
                "pallas_fused" if method == policy.DYNAMIC_DELETE_FUSED \
                else "jnp"
            self.inc.delete_graph(batch)
            self.stats.scoped_deletes += 1
        else:
            from repro.core.cc import connected_components
            self.inc.tombstone_graph(batch)
            res = connected_components(self.graph(), method=method)
            self.inc.adopt(res.labels, work=res.work)
            self.stats.rebuilds += 1
        self.stats.deletes += 1


class GraphRegistry:
    """Registry of named live graphs with version-stamped query caching."""

    def __init__(self, *, lift_steps: int = 2,
                 policy_cache: policy.AutotuneCache | None = None):
        self.lift_steps = lift_steps
        self.policy_cache = policy_cache
        self._tenants: dict[str, TenantGraph] = {}
        # per-tenant result cache: key -> (version, result); entries are
        # dropped wholesale when the tenant's version ticks (a merge)
        self._qcache: dict[str, dict] = {}

    # -- tenant lifecycle --------------------------------------------------

    def create(self, name: str, num_nodes: int) -> TenantGraph:
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        t = TenantGraph(name, num_nodes, lift_steps=self.lift_steps,
                        policy_cache=self.policy_cache)
        self._tenants[name] = t
        self._qcache[name] = {}
        return t

    def get(self, name: str) -> TenantGraph:
        if name not in self._tenants:
            raise KeyError(f"unknown tenant {name!r}; "
                           f"have {sorted(self._tenants)}")
        return self._tenants[name]

    def drop(self, name: str) -> None:
        self.get(name)
        del self._tenants[name]
        del self._qcache[name]

    def names(self) -> list[str]:
        return sorted(self._tenants)

    def __contains__(self, name: str) -> bool:
        return name in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    # -- mutation ----------------------------------------------------------

    def insert(self, name: str, edges):
        """Insert an edge batch (DeviceGraph or host array); returns the
        tenant's label version as a DEVICE scalar (the insert path never
        syncs — ``int(...)`` it to observe). Cached query results are
        invalidated ONLY when the batch merged components: entries are
        version-stamped and validated lazily at query time, so no eager
        host-side merge check is needed here."""
        t = self.get(name)
        t.insert(edges)
        return t.version_device

    def delete(self, name: str, edges):
        """Delete an edge batch (DeviceGraph or host array); returns
        the tenant's label version as a DEVICE scalar (the delete path
        never syncs). Cached query results are invalidated ONLY when
        the batch actually SPLIT a component: the version tick happens
        on device inside the delete program (a non-bridge deletion
        reproduces the identical canonical partition), so the same
        lazy version-stamped validation that keeps insert-path answers
        stale-free extends across splits unchanged."""
        t = self.get(name)
        t.delete(edges)
        return t.version_device

    # -- queries (cached, on-device kernels) -------------------------------

    def _cached(self, name: str, key, compute):
        t = self.get(name)
        cache = self._qcache[name]
        t.stats.queries += 1
        hit = cache.get(key)
        if hit is not None and hit[0] == t.version:
            t.stats.cache_hits += 1
            return hit[1]
        result = compute(t)
        if len(cache) >= _MAX_CACHED_RESULTS:
            cache.pop(next(iter(cache)))
        cache[key] = (t.version, result)
        return result

    def _batched_query(self, name: str, kind: str, batch: np.ndarray,
                       shape: tuple) -> np.ndarray:
        """Shared validate/pad/cache path for vertex-batch queries:
        bounds-check, pad to the power-of-two buckets (so every
        same-shape batch — across all tenants of one |V| — hits one jit
        cache entry), run the kernel, slice off the padding; cached by
        content + label version."""
        batch = np.asarray(batch, np.int32).reshape(shape)
        t = self.get(name)
        if batch.size and (batch.min() < 0 or batch.max() >= t.num_nodes):
            raise ValueError(f"vertex out of range [0, {t.num_nodes})")
        q = batch.shape[0]
        kernel = getattr(queries, kind)
        # digest, not raw bytes: keys stay O(1) even for huge batches
        digest = hashlib.blake2b(batch.tobytes(), digest_size=16).digest()
        return self._cached(
            name, (kind, batch.shape, digest),
            lambda t: np.asarray(kernel(t.labels,
                                        pad_rows_pow2(batch)))[:q])

    def same_component(self, name: str, pairs) -> np.ndarray:
        """bool [Q] for an int [Q, 2] pair batch."""
        return self._batched_query(name, "same_component", pairs, (-1, 2))

    def component_size(self, name: str, vertices) -> np.ndarray:
        """int32 [Q] component sizes for a vertex batch."""
        return self._batched_query(name, "component_size", vertices,
                                   (-1,))

    def count_components(self, name: str) -> int:
        return int(self._cached(
            name, ("count_components",),
            lambda t: queries.count_components(t.labels)))

    def component_histogram(self, name: str) -> np.ndarray:
        return np.asarray(self._cached(
            name, ("component_histogram",),
            lambda t: queries.component_histogram(t.labels)))

    # -- introspection -----------------------------------------------------

    def version(self, name: str) -> int:
        return self.get(name).version

    def stats(self) -> dict:
        out = {}
        for name, t in self._tenants.items():
            version = t.version            # introspection path: sync OK
            out[name] = {**dataclasses.asdict(t.stats),
                         # the version ticks exactly on merging inserts
                         # and splitting deletes, so it IS the
                         # partition-change count (tracked on device)
                         "partition_changes": version,
                         "version": version,
                         "num_nodes": t.num_nodes,
                         "num_edges": t.num_edges,
                         "num_edges_deleted": t.inc.num_edges_deleted,
                         "hook_ops": t.inc.work["hook_ops"]}
        return out
