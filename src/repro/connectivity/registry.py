"""Multi-tenant graph registry: N named live graphs, versioned labels,
query-result caching with merge-precise invalidation.

Each tenant is a named vertex set with a live canonical label array
backed by ``IncrementalCC``. Inserts are routed by the adaptive policy
(``policy.select_method``): a small delta is absorbed incrementally
(hook only the new edges), a bulk load is rebuilt through the chosen
static engine and adopted. Queries run through the on-device kernels
(``queries``), with query batches padded to the power-of-two buckets of
``repro.core.batch`` so same-shape batches share one jit cache entry
across tenants.

**Version / invalidation protocol** (DESIGN.md §7): a tenant's label
*version* is ``IncrementalCC``'s device-resident version counter — it
ticks only when an insert batch actually merges components (the absorb
jit detects ``any(labels != old)`` and ticks IN the same device
program; the insert path never syncs it to the host). Cached query
results are stamped with the version they were computed at and served
only while the version is unchanged — validation happens lazily at
query time (one scalar sync on a path that syncs anyway to return the
answer), so an insert that lands entirely inside existing components
keeps every cached answer warm and stale answers are impossible by
construction: connectivity under insert-only workloads changes exactly
when labels change. Superseded entries age out via FIFO eviction.

**DeviceGraph substrate** (DESIGN.md §8): insert batches are
``DeviceGraph``s (host arrays go through the ``from_edges`` shim with
bounds validation); the edge log is a list of DeviceGraphs whose bulk
rebuilds concatenate ON DEVICE; policy features (density, update rate)
come from static DeviceGraph metadata. The steady-state insert path —
coalescing, feature extraction, absorb, version tick — performs zero
host transfers (tested under ``jax.transfer_guard("disallow")``).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.connectivity import policy, queries
from repro.core.batch import pad_rows_pow2
from repro.core.incremental import IncrementalCC
from repro.graphs.device import DeviceGraph, validate_edge_bounds

_MAX_CACHED_RESULTS = 1024      # per tenant; FIFO-evicted


@dataclasses.dataclass
class TenantStats:
    # merge counts are NOT tracked here: the device-resident version
    # counter ticks exactly on merging inserts, so registry.stats()
    # reports it as "merges" (a host field would force a sync per insert)
    inserts: int = 0
    absorbs: int = 0            # inserts routed through the incremental path
    rebuilds: int = 0           # inserts routed through a static engine
    queries: int = 0
    cache_hits: int = 0


class TenantGraph:
    """One live graph: IncrementalCC state + accumulated DeviceGraph
    edge log."""

    def __init__(self, name: str, num_nodes: int, *, lift_steps: int = 2,
                 policy_cache: policy.AutotuneCache | None = None):
        self.name = name
        self.num_nodes = num_nodes
        self.inc = IncrementalCC(num_nodes, lift_steps=lift_steps)
        self.policy_cache = policy_cache
        self._edge_log: list[DeviceGraph] = []  # for the bulk-rebuild path
        self.stats = TenantStats()
        self.last_method = None                  # last policy decision

    @property
    def version(self) -> int:
        """Label version as a host int (syncs; query-path use)."""
        return self.inc.version

    @property
    def version_device(self):
        """Label version as a device scalar (no sync; insert-path use)."""
        return self.inc.version_device

    @property
    def labels(self):
        return self.inc.labels

    @property
    def num_edges(self) -> int:
        return self.inc.num_edges_inserted

    def graph(self) -> DeviceGraph:
        """The accumulated edge set as ONE DeviceGraph (device-side
        concat of the insert log — no host ``np.concatenate``)."""
        if not self._edge_log:
            return DeviceGraph.from_edges(
                np.zeros((0, 2), np.int32), self.num_nodes,
                name=self.name)
        return DeviceGraph.concat(self._edge_log, name=self.name)

    def edges(self) -> np.ndarray:
        """Host view of the accumulated edges (syncs; introspection)."""
        g = self.graph()
        t = g.true_edges_static
        return np.asarray(g.edges)[: g.edges.shape[0] if t is None else t]

    def _coerce(self, new_edges) -> DeviceGraph:
        """Host arrays are validated + device_put; DeviceGraphs pass
        through untouched (no sync — the caller owns bounds there)."""
        if isinstance(new_edges, DeviceGraph):
            if new_edges.num_nodes != self.num_nodes:
                raise ValueError(
                    f"delta num_nodes {new_edges.num_nodes} != "
                    f"{self.num_nodes}")
            return new_edges
        arr = np.asarray(new_edges, np.int32).reshape(-1, 2)
        validate_edge_bounds(arr, self.num_nodes)
        return DeviceGraph.from_edges(arr, self.num_nodes,
                                      name=self.name)

    def insert(self, new_edges) -> None:
        """Insert an edge batch (DeviceGraph or host array). The merge
        decision (version tick) happens ON DEVICE inside the absorb —
        this path never syncs; read ``version``/``version_device`` to
        observe it."""
        delta = self._coerce(new_edges)
        method = policy.select_for(self.num_nodes, self.num_edges,
                                   delta, cache=self.policy_cache)
        self.last_method = method
        if delta.num_edges:
            self._edge_log.append(delta)
        if method == policy.INCREMENTAL_ABSORB:
            self.inc.insert_graph(delta)
            self.stats.absorbs += 1
        else:
            # bulk load: the accumulated set is mostly this batch — the
            # chosen static engine (segmentation and all) beats hooking
            # a huge unsegmented delta through the absorb loop
            from repro.core.cc import connected_components
            res = connected_components(self.graph(), method=method)
            self.inc.adopt(res.labels, work=res.work,
                           num_edges=delta.num_edges)
            self.stats.rebuilds += 1
        self.stats.inserts += 1


class GraphRegistry:
    """Registry of named live graphs with version-stamped query caching."""

    def __init__(self, *, lift_steps: int = 2,
                 policy_cache: policy.AutotuneCache | None = None):
        self.lift_steps = lift_steps
        self.policy_cache = policy_cache
        self._tenants: dict[str, TenantGraph] = {}
        # per-tenant result cache: key -> (version, result); entries are
        # dropped wholesale when the tenant's version ticks (a merge)
        self._qcache: dict[str, dict] = {}

    # -- tenant lifecycle --------------------------------------------------

    def create(self, name: str, num_nodes: int) -> TenantGraph:
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        t = TenantGraph(name, num_nodes, lift_steps=self.lift_steps,
                        policy_cache=self.policy_cache)
        self._tenants[name] = t
        self._qcache[name] = {}
        return t

    def get(self, name: str) -> TenantGraph:
        if name not in self._tenants:
            raise KeyError(f"unknown tenant {name!r}; "
                           f"have {sorted(self._tenants)}")
        return self._tenants[name]

    def drop(self, name: str) -> None:
        self.get(name)
        del self._tenants[name]
        del self._qcache[name]

    def names(self) -> list[str]:
        return sorted(self._tenants)

    def __contains__(self, name: str) -> bool:
        return name in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    # -- mutation ----------------------------------------------------------

    def insert(self, name: str, edges):
        """Insert an edge batch (DeviceGraph or host array); returns the
        tenant's label version as a DEVICE scalar (the insert path never
        syncs — ``int(...)`` it to observe). Cached query results are
        invalidated ONLY when the batch merged components: entries are
        version-stamped and validated lazily at query time, so no eager
        host-side merge check is needed here."""
        t = self.get(name)
        t.insert(edges)
        return t.version_device

    # -- queries (cached, on-device kernels) -------------------------------

    def _cached(self, name: str, key, compute):
        t = self.get(name)
        cache = self._qcache[name]
        t.stats.queries += 1
        hit = cache.get(key)
        if hit is not None and hit[0] == t.version:
            t.stats.cache_hits += 1
            return hit[1]
        result = compute(t)
        if len(cache) >= _MAX_CACHED_RESULTS:
            cache.pop(next(iter(cache)))
        cache[key] = (t.version, result)
        return result

    def _batched_query(self, name: str, kind: str, batch: np.ndarray,
                       shape: tuple) -> np.ndarray:
        """Shared validate/pad/cache path for vertex-batch queries:
        bounds-check, pad to the power-of-two buckets (so every
        same-shape batch — across all tenants of one |V| — hits one jit
        cache entry), run the kernel, slice off the padding; cached by
        content + label version."""
        batch = np.asarray(batch, np.int32).reshape(shape)
        t = self.get(name)
        if batch.size and (batch.min() < 0 or batch.max() >= t.num_nodes):
            raise ValueError(f"vertex out of range [0, {t.num_nodes})")
        q = batch.shape[0]
        kernel = getattr(queries, kind)
        # digest, not raw bytes: keys stay O(1) even for huge batches
        digest = hashlib.blake2b(batch.tobytes(), digest_size=16).digest()
        return self._cached(
            name, (kind, batch.shape, digest),
            lambda t: np.asarray(kernel(t.labels,
                                        pad_rows_pow2(batch)))[:q])

    def same_component(self, name: str, pairs) -> np.ndarray:
        """bool [Q] for an int [Q, 2] pair batch."""
        return self._batched_query(name, "same_component", pairs, (-1, 2))

    def component_size(self, name: str, vertices) -> np.ndarray:
        """int32 [Q] component sizes for a vertex batch."""
        return self._batched_query(name, "component_size", vertices,
                                   (-1,))

    def count_components(self, name: str) -> int:
        return int(self._cached(
            name, ("count_components",),
            lambda t: queries.count_components(t.labels)))

    def component_histogram(self, name: str) -> np.ndarray:
        return np.asarray(self._cached(
            name, ("component_histogram",),
            lambda t: queries.component_histogram(t.labels)))

    # -- introspection -----------------------------------------------------

    def version(self, name: str) -> int:
        return self.get(name).version

    def stats(self) -> dict:
        out = {}
        for name, t in self._tenants.items():
            version = t.version            # introspection path: sync OK
            out[name] = {**dataclasses.asdict(t.stats),
                         # the version ticks exactly on merging inserts,
                         # so it IS the merge count (tracked on device)
                         "merges": version,
                         "version": version,
                         "num_nodes": t.num_nodes,
                         "num_edges": t.num_edges,
                         "hook_ops": t.inc.work["hook_ops"]}
        return out
