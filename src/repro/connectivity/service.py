"""Slot-based connectivity query engine: microbatched interleaved
insert/query traffic over the multi-tenant registry.

Every tenant behind the registry is a ``repro.api.Solver`` session
(DESIGN.md §10), so the service inherits the facade's policy routing
and transfer-free steady-state mutation contract by construction.

Mirrors the admit/step/retire idiom of ``repro.serving.engine``: a
bounded number of request slots per tick; each tick admits queued
requests, executes them in two phases, and retires them with results.

Per tick:

  * **inserts coalesce per tenant** — all admitted insert batches for
    one tenant concatenate into ONE absorb/rebuild call (one device
    dispatch instead of one per request). Payloads are device_put at
    submit time and coalesced with ``DeviceGraph.concat`` ON DEVICE
    (DESIGN.md §8): the steady-state tick performs zero host transfers
    — no ``np.concatenate``, no host-side merge check — which the
    transfer-guard test pins down;
  * **deletes coalesce per tenant** the same way (DESIGN.md §9) — one
    tombstone + scoped-recompute program per tenant per tick, so k
    simultaneous splits ride one stacked scan; the steady-state
    tombstone tick is transfer-free under the same guard;
  * **queries microbatch per (tenant, kind)** — all admitted
    ``same_component`` pairs (resp. ``component_size`` vertices) for a
    tenant concatenate into one batch, padded to the power-of-two
    buckets of ``repro.core.batch``, so every same-shape batch across
    all tenants of one |V| routes through one jit cache entry.

Consistency model: within a tick, inserts apply first, then deletes,
then queries — a query observes every mutation admitted in its tick
(and all earlier ticks), and a delete admitted alongside an insert of
the same edge wins (read-fresh, delete-after-insert semantics).

Every query is served from the live label array — zero label
recomputes. ``stats["recomputes_avoided"]`` counts the full CC runs a
recompute-per-query design would have paid; the ``service`` benchmark
(``benchmarks/run.py --only service``) prices that counterfactual in
hook_ops.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.connectivity.registry import GraphRegistry
from repro.graphs.device import DeviceGraph, validate_edge_bounds
from repro.obs import trace as obs
from repro.obs.slo import SLORecorder

QUERY_KINDS = ("same_component", "component_size", "count_components",
               "component_histogram")
MUTATION_KINDS = ("insert", "delete")
KINDS = MUTATION_KINDS + QUERY_KINDS


@dataclasses.dataclass
class Request:
    uid: int
    tenant: str
    kind: str                       # one of KINDS
    # np array for query kinds; a DeviceGraph for inserts/deletes
    # (device-put at admission so the tick stays transfer-free)
    payload: Optional[Any] = None
    result: Any = None
    done: bool = False
    error: Optional[str] = None
    # wall-clock admission stamp — the fleet's pipelined collect phase
    # records END-TO-END latency (collect minus submit), which needs
    # the submit time to ride on the request
    t_submit: float = 0.0


class ConnectivityService:
    """Continuous-microbatching engine over a ``GraphRegistry``.

    ``device=`` pins the shard: admission device_puts every payload to
    that device and the registry's sessions allocate their dynamic
    state there — this is what lets ``repro.fleet`` run one service
    per mesh device as a thin per-device shell (DESIGN.md §15)."""

    def __init__(self, registry: GraphRegistry | None = None, *,
                 slots: int = 32, device=None):
        if registry is None:
            registry = GraphRegistry(device=device)
        self.registry = registry
        self.device = device
        self.slots = slots
        self.queue: list[Request] = []
        self._uid = 0
        # per-(tenant, kind) latency SLO histograms — a fixed-size
        # bucket table (never grows with traffic); recorded only while
        # repro.obs tracing is enabled. Query latencies are end-to-end
        # (the query path syncs to return answers); mutation latencies
        # are dispatch-side (blocking the async tick to time it would
        # serialize the pipeline the service exists to keep full).
        self.slo = SLORecorder()
        self.stats = {
            "ticks": 0,
            "inserts_absorbed": 0,        # insert requests completed
            "insert_calls": 0,            # coalesced device-side inserts
            "deletes_absorbed": 0,        # delete requests completed
            "delete_calls": 0,            # coalesced device-side deletes
            "queries_served": 0,          # query requests completed
            "query_calls": 0,             # microbatched kernel dispatches
            "pairs_answered": 0,
            "recomputes_avoided": 0,      # vs a recompute-per-query design
            "errors": 0,
        }

    # -- submission --------------------------------------------------------

    def submit(self, tenant: str, kind: str, payload=None) -> int:
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r}; choose from {KINDS}")
        if kind in MUTATION_KINDS:
            payload = self._ingest_edges(tenant, kind, payload)
        elif kind in ("same_component", "component_size"):
            if payload is None:
                raise ValueError(f"kind {kind!r} requires a payload")
            # admission is the front door's per-request hot path (a
            # fleet tick admits thousands): skip the asarray copy
            # machinery when the caller already hands well-typed rows
            if not (isinstance(payload, np.ndarray)
                    and payload.dtype == np.int32):
                payload = np.asarray(payload, np.int32)
            payload = payload.reshape(-1) if kind == "component_size" \
                else payload.reshape(-1, 2)
        else:
            payload = None
        self._uid += 1
        if obs.enabled():
            with obs.span("service.admit", tenant=tenant, kind=kind):
                self.queue.append(Request(self._uid, tenant, kind,
                                          payload,
                                          t_submit=time.perf_counter()))
        else:
            self.queue.append(Request(self._uid, tenant, kind, payload,
                                      t_submit=time.perf_counter()))
        return self._uid

    def _ingest_edges(self, tenant: str, kind: str, payload
                      ) -> DeviceGraph:
        """Admission-time ingress (inserts AND deletes): validate on
        host (while the data IS host data), then explicit device_put —
        the tick itself then touches device arrays only. DeviceGraph
        payloads pass through."""
        if payload is None:
            raise ValueError(f"kind {kind!r} requires a payload")
        if isinstance(payload, DeviceGraph):
            return payload
        num_nodes = self.registry.get(tenant).num_nodes \
            if tenant in self.registry else None
        if isinstance(payload, jax.Array):
            edges = payload.astype("int32").reshape(-1, 2)
            # admission-time ingress may sync: bounds-check the host
            # view so an out-of-range endpoint errors here instead of
            # silently clamping inside the absorb (DeviceGraph payloads
            # are the no-sync fast lane — the caller owns bounds there)
            if num_nodes is not None:
                validate_edge_bounds(np.asarray(edges), num_nodes)
            if self.device is not None:
                edges = jax.device_put(edges, self.device)
        else:
            arr = np.asarray(payload, np.int32).reshape(-1, 2)
            if num_nodes is not None:
                validate_edge_bounds(arr, num_nodes)
            edges = jax.device_put(arr, self.device)
        if num_nodes is None:
            # unknown tenant: the tick's failure path will reject the
            # group; a zero-|V| DeviceGraph just carries the payload
            num_nodes = 0
        return DeviceGraph.from_edges(edges, num_nodes)

    def submit_insert(self, tenant: str, edges) -> int:
        return self.submit(tenant, "insert", edges)

    def submit_delete(self, tenant: str, edges) -> int:
        return self.submit(tenant, "delete", edges)

    def submit_query(self, tenant: str, kind: str, payload=None) -> int:
        if kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; "
                             f"choose from {QUERY_KINDS}")
        return self.submit(tenant, kind, payload)

    # -- the engine tick ---------------------------------------------------

    def _fail(self, req: Request, err: Exception) -> None:
        req.error = f"{type(err).__name__}: {err}"
        req.done = True
        self.stats["errors"] += 1

    @staticmethod
    def _rebind(payload: DeviceGraph, num_nodes: int) -> DeviceGraph:
        """Bind a pre-create payload (|V|=0 marker) to the tenant's
        |V|, running the bounds validation it skipped at admission.
        This is the rare tenant-created-after-submit path, so the
        device->host sync for the check is acceptable."""
        validate_edge_bounds(np.asarray(payload.edges), num_nodes)
        return DeviceGraph.from_edges(payload.edges, num_nodes)

    def _run_mutations(self, kind: str, reqs_in: list[Request]) -> None:
        """Coalesced mutation phase for one kind ('insert'/'delete')."""
        by_tenant: dict[str, list[Request]] = {}
        for r in reqs_in:
            by_tenant.setdefault(r.tenant, []).append(r)
        registry_call = getattr(self.registry, kind)
        record = obs.enabled()
        for tenant, reqs in by_tenant.items():
            with obs.span(f"service.{kind}", tenant=tenant,
                          requests=len(reqs)) as sp:
                t0 = time.perf_counter()
                try:
                    # device-side coalescing: one concat + ONE
                    # absorb/tombstone per tenant per tick, zero host
                    # transfers. Only payloads submitted before the
                    # tenant existed (|V|=0 marker) re-bind to its |V| —
                    # with the bounds check they skipped at admission; a
                    # real |V| mismatch must fall through to the
                    # registry's error, not be papered over.
                    n = self.registry.get(tenant).num_nodes
                    batch = DeviceGraph.concat(
                        [self._rebind(r.payload, n) if
                         r.payload.num_nodes == 0 and n != 0 else r.payload
                         for r in reqs])
                    version = registry_call(tenant, batch)
                except Exception as err:  # fail the group, not the tick
                    for r in reqs:
                        self._fail(r, err)
                    sp.tag(failed=len(reqs))
                    continue
                sp.tag(route=self.registry.get(tenant).last_method)
                dt = time.perf_counter() - t0
            if record:
                # dispatch latency, shared by the coalesced group (one
                # device call served all of them)
                for _ in reqs:
                    self.slo.record(tenant, kind, dt)
            self.stats[f"{kind}_calls"] += 1
            for r in reqs:
                # the version rides as a device scalar; int(...) it to
                # observe (the tick itself must not sync)
                r.result = version
                r.done = True
                self.stats[f"{kind}s_absorbed"] += 1

    def _run_query_group(self, tenant: str, kind: str,
                         reqs: list[Request]) -> None:
        with obs.span(f"service.query.{kind}", tenant=tenant,
                      requests=len(reqs)) as sp:
            t0 = time.perf_counter()
            try:
                if kind in ("same_component", "component_size"):
                    parts = [r.payload for r in reqs]
                    flat = np.concatenate(parts, axis=0)
                    answers = getattr(self.registry, kind)(tenant, flat)
                    self.stats["query_calls"] += 1
                    self.stats["pairs_answered"] += int(flat.shape[0])
                    sp.tag(rows=int(flat.shape[0]))
                    off = 0
                    for r, part in zip(reqs, parts):
                        r.result = answers[off:off + part.shape[0]]
                        off += part.shape[0]
                else:               # scalar/histogram: one call serves all
                    answer = getattr(self.registry, kind)(tenant)
                    self.stats["query_calls"] += 1
                    for r in reqs:
                        r.result = answer
            except Exception as err:     # fail the group, not the tick
                for r in reqs:
                    self._fail(r, err)
                sp.tag(failed=len(reqs))
                return
            dt = time.perf_counter() - t0
        if obs.enabled():
            # end-to-end: the query path syncs to return host answers,
            # so the wall time IS the request latency
            for _ in reqs:
                self.slo.record(tenant, kind, dt)
        for r in reqs:
            r.done = True
            self.stats["queries_served"] += 1
            self.stats["recomputes_avoided"] += 1

    def _pop_admitted(self) -> list[Request]:
        """Atomically snapshot and remove this tick's admitted slice.

        The snapshot is taken ONCE and exactly that many entries are
        deleted from the head — a ``submit()`` landing between the read
        and the delete (a query callback enqueueing follow-up work
        mid-tick) appends past the snapshot and survives to the next
        tick. The old ``self.queue = self.queue[self.slots:]`` reslice
        re-read the list: with fewer queued requests than slots, a
        mid-tick append landed below ``slots`` and the reslice silently
        dropped it — admitted by nobody, never retired."""
        admitted = self.queue[: self.slots]
        del self.queue[: len(admitted)]
        return admitted

    def step(self) -> list[Request]:
        """One tick: admit up to ``slots`` requests, coalesce inserts
        then deletes, microbatch queries, retire. Returns the retired
        requests."""
        admitted = self._pop_admitted()
        if not admitted:
            return []
        self.stats["ticks"] += 1

        # step= maps to jax.profiler.StepTraceAnnotation under the
        # opt-in profiler bridge, so device profiles step-align
        with obs.span("service.tick", step=self.stats["ticks"],
                      admitted=len(admitted)):
            for kind in MUTATION_KINDS:   # inserts apply before deletes
                self._run_mutations(
                    kind, [r for r in admitted if r.kind == kind])
            groups: dict[tuple[str, str], list[Request]] = {}
            for r in admitted:
                if r.kind not in MUTATION_KINDS:
                    groups.setdefault((r.tenant, r.kind), []).append(r)
            for (tenant, kind), reqs in groups.items():
                self._run_query_group(tenant, kind, reqs)
        return admitted

    def run(self) -> list[Request]:
        """Drain the queue; returns every retired request in admit order."""
        finished: list[Request] = []
        while self.queue:
            finished.extend(self.step())
        return finished

    # -- telemetry ---------------------------------------------------------

    def obs_summary(self) -> dict:
        """The tick summary: per-tenant/global latency SLOs, always-on
        host counters (autotune hit/miss, deprecation-shim traffic),
        and the fleet's on-device metrics — merged across tenants with
        ``Metrics.merge`` (associative, so fold order is irrelevant)
        and flushed ONCE through the audited ``queries.to_host`` sink.
        This is the one explicit sync point of the instrumented
        service; everything upstream of it stays on device."""
        from repro.obs import metrics as obs_metrics
        merged = None
        for name in self.registry.names():
            m = self.registry.get(name).solver.metrics
            if m is not None:
                merged = m if merged is None else merged.merge(m)
        return {
            "ticks": self.stats["ticks"],
            "latency": self.slo.summary(),
            "counters": dict(obs.tracer().counters),
            "device_metrics": (None if merged is None
                               else obs_metrics.flush(merged)),
        }
