"""Fully on-device connectivity query kernels over a label array.

Every engine in ``repro.core`` converges to canonical min-id labels
(``labels[v] == min vertex id of v's component``); once that array is
on the device, every connectivity question is a gather / scatter-add /
sort — no host round trip and no ``np.unique``:

  * ``same_component(labels, pairs)``   — vectorized [Q, 2] batch of
    "are u and v connected?" (one gather + compare);
  * ``component_size(labels, vertices)``— per-vertex component sizes
    via a scatter-add census over the label array;
  * ``count_components(labels)``        — distinct-label count via
    sort + boundary segment count (works for ANY representative
    labeling, canonical or not — the on-device replacement for the old
    host-side ``np.unique(...).size``);
  * ``component_histogram(labels)``     — number of components per
    power-of-two size bin (census + exact integer log2 via frexp).

All kernels are jitted; the jit cache is keyed on the (static) label
and query-batch shapes, so callers that pad query batches to shared
buckets (``repro.core.batch.pad_rows_pow2``; what the service layer
does) route every same-shape batch through one compiled program.
Results stay on device — callers choose when to sync.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def to_host(result) -> np.ndarray:
    """THE audited device->host sink for query results.

    Every result materialization in the stack routes through here, so
    the transfer-freedom story stays auditable: device programs are
    statically transfer-free (``repro.analysis`` transfer pass), and
    the one place answers legally cross to the host is this function —
    called *after* a query kernel returns, never inside anything
    traced. Calling it on a tracer is a bug by definition and raises
    under ``jax.make_jaxpr`` (which the analyzer reports as a
    ``trace-host-sync`` finding on the offending entry).
    """
    if isinstance(result, jax.core.Tracer):     # fail loud, not silent
        raise TypeError(
            "to_host() called on a tracer — a device->host sync leaked "
            "into a traced program; keep results on device until after "
            "the kernel returns")
    return np.asarray(result)


@jax.jit
def same_component(labels: jnp.ndarray, pairs: jnp.ndarray) -> jnp.ndarray:
    """bool [Q]: ``labels[u] == labels[v]`` for every pair (u, v).

    ``pairs`` is an int [Q, 2] array of vertex ids. Out-of-range ids are
    clamped by JAX gather semantics — validate at the API boundary
    (the registry does).
    """
    pairs = jnp.asarray(pairs, jnp.int32).reshape(-1, 2)
    return labels[pairs[:, 0]] == labels[pairs[:, 1]]


@jax.jit
def component_census(labels: jnp.ndarray) -> jnp.ndarray:
    """int32 [V]: ``census[r]`` = size of the component whose
    representative is ``r`` (0 for non-representative ids). One
    scatter-add over the label array."""
    v = labels.shape[0]
    return jnp.zeros((v,), jnp.int32).at[labels].add(1)


@jax.jit
def component_sizes(labels: jnp.ndarray) -> jnp.ndarray:
    """int32 [V]: size of every vertex's component (census gathered
    back through the labels)."""
    return component_census(labels)[labels]


@jax.jit
def component_size(labels: jnp.ndarray, vertices: jnp.ndarray
                   ) -> jnp.ndarray:
    """int32 [Q]: component size for each queried vertex."""
    vertices = jnp.asarray(vertices, jnp.int32).reshape(-1)
    return component_census(labels)[labels[vertices]]


@jax.jit
def _count_components(labels: jnp.ndarray) -> jnp.ndarray:
    s = jnp.sort(labels)
    return (jnp.sum(s[1:] != s[:-1]) + 1).astype(jnp.int32)


def count_components(labels: jnp.ndarray) -> jnp.ndarray:
    """int32 scalar: number of distinct labels (= components).

    Sort + segment-boundary count, so it is correct for any
    representative labeling, not just the canonical min-id fixed point.
    Stays on device; wrap in ``int(...)`` to sync.
    """
    labels = jnp.asarray(labels)
    if labels.shape[0] == 0:
        return jnp.zeros((), jnp.int32)
    return _count_components(labels)


@jax.jit
def _spanning_forest_stats(labels: jnp.ndarray, parents: jnp.ndarray):
    v = labels.shape[0]
    valid = parents[:, 0] >= 0
    n_edges = jnp.sum(valid).astype(jnp.int32)
    n_components = _count_components(labels)
    # every recorded edge must connect two vertices the solve labeled
    # as one component (roots' (-1, -1) rows are vacuously fine —
    # clamp the gather indices so they never read out of bounds)
    u = jnp.clip(parents[:, 0], 0, v - 1)
    w = jnp.clip(parents[:, 1], 0, v - 1)
    intra = jnp.all(jnp.where(valid, labels[u] == labels[w], True))
    return {"n_forest_edges": n_edges,
            "n_roots": (jnp.int32(v) - n_edges).astype(jnp.int32),
            "n_components": n_components,
            "edges_intra_component": intra,
            "count_consistent": n_edges + n_components == v}


def spanning_forest_stats(labels: jnp.ndarray, parents: jnp.ndarray
                          ) -> dict:
    """On-device validation scalars for a recorded spanning forest
    (``ForestResult.parents``: int32 [V, 2], row r = the graph edge
    whose hook retired root r, (-1, -1) for roots).

    Returns device scalars: ``n_forest_edges`` (rows recorded),
    ``n_roots`` (V - recorded), ``n_components`` (distinct labels),
    ``edges_intra_component`` (every recorded edge joins same-label
    endpoints), and ``count_consistent`` (recorded + components == V —
    with intra-component endpoints this pins the forest to exactly one
    tree per component; the full acyclicity property is re-proved
    host-side in the test suite's union-find check). One gather +
    masked reductions; stays on device."""
    labels = jnp.asarray(labels)
    parents = jnp.asarray(parents, jnp.int32).reshape(-1, 2)
    if labels.shape[0] == 0:
        z = jnp.zeros((), jnp.int32)
        return {"n_forest_edges": z, "n_roots": z, "n_components": z,
                "edges_intra_component": jnp.asarray(True),
                "count_consistent": jnp.asarray(True)}
    return _spanning_forest_stats(labels, parents)


def _floor_log2(n: jnp.ndarray) -> jnp.ndarray:
    """Exact floor(log2) for positive int32. frexp(x) = (m, e) with
    m in [0.5, 1) gives floor(log2 x) == e - 1, but only while the
    int->float32 cast is exact (< 2^24) — a component of size 2^25 - 1
    would round UP and land one bin high. Shift the high half down so
    every cast value fits in 16 bits."""
    hi = n >> 16
    val = jnp.where(hi > 0, hi, n).astype(jnp.float32)   # < 2^16: exact
    _, exp = jnp.frexp(val)
    return exp - 1 + jnp.where(hi > 0, 16, 0)


@jax.jit
def _component_histogram(labels: jnp.ndarray) -> jnp.ndarray:
    v = labels.shape[0]
    census = component_census(labels)
    nbins = max(int(v - 1).bit_length() + 1, 1)
    bins = jnp.where(census > 0, _floor_log2(jnp.maximum(census, 1)),
                     nbins)                           # empty -> dropped
    return jnp.zeros((nbins,), jnp.int32).at[bins].add(1, mode="drop")


def component_histogram(labels: jnp.ndarray) -> jnp.ndarray:
    """int32 [floor(log2 V) + 1]: ``hist[b]`` = number of components
    with size in [2^b, 2^(b+1)). Census + exact log2 binning, all on
    device."""
    labels = jnp.asarray(labels)
    if labels.shape[0] == 0:
        return jnp.zeros((1,), jnp.int32)
    return _component_histogram(labels)
