"""Connectivity query service (DESIGN.md §7): on-device query kernels
over canonical label arrays, the adaptive method-selection policy, a
multi-tenant registry with merge-precise invalidation, and a slot-based
microbatching engine."""
from repro.connectivity.policy import (AutotuneCache, GraphFeatures,
                                       select_method)
from repro.connectivity.queries import (component_histogram,
                                        component_size, component_sizes,
                                        count_components, same_component)
from repro.connectivity.registry import GraphRegistry, TenantGraph
from repro.connectivity.service import ConnectivityService, Request

__all__ = [
    "AutotuneCache", "GraphFeatures", "select_method",
    "component_histogram", "component_size", "component_sizes",
    "count_components", "same_component",
    "GraphRegistry", "TenantGraph",
    "ConnectivityService", "Request",
]
