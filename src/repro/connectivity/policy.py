"""Adaptive method selection — the paper's heuristic as a serving policy.

The paper's contribution is *adaptivity*: pick the work-efficient
schedule from the input's shape instead of hardcoding it. This module
is the brain behind ``connected_components(..., method="auto")`` and
the registry's insert path. Two layers:

1. **Heuristic** (`heuristic_method`) — the paper's segmentation rule
   on cheap, O(1) features (|V|, |E|, density 2|E|/|V|, update rate):

   * a pending insert batch that is small relative to the absorbed
     edge set (update rate <= ``UPDATE_RATE_ABSORB``) is an
     ``incremental-absorb`` (hook only the delta; Hong et al.) — a
     bulk load falls through to a static method on the accumulated set;
   * a pending DELETE batch that is small relative to the alive edge
     set (delete rate <= ``DELETE_RATE_SCOPED``) is a
     ``tombstone-delete`` (scoped recompute over the affected
     components only, DESIGN.md §9; the ``-fused`` variant when the
     autotune cache crowned ``pallas_fused`` for the surviving-graph
     bucket) — a bulk drop falls through to a static rebuild over the
     survivors;
   * density < ``MIN_SEGMENT_DENSITY``: s = 2|E|/|V| rounds to <= 1
     segment, so segmentation degenerates — run ``atomic_hook``
     (one segment, no scan overhead);
   * density >= ``LABELPROP_DENSITY_FRAC`` * |V| (near-clique regime,
     O(1) diameter): ``labelprop`` converges in a sweep or two and
     skips the hook/compress machinery;
   * otherwise: ``adaptive`` (the paper's default, Fig. 4).

2. **Autotune cache** (`AutotuneCache`) — measured truth beats
   modeling. Wall-clock winners are cached per *bucketed* shape (the
   power-of-two (V_pad, E_pad) bucket of ``repro.core.batch``, so one
   measurement covers a whole size regime), persisted as JSON
   (``{"version": 1, "entries": {"v1024_e4096": {"method": ...,
   "ms": ...}, ...}}``), and warm-started by the benchmark sweep
   (``benchmarks/run.py --only service`` calls `warm_start`).

Selection order in `select_method`: update-rate rule first (absorb vs
static is structural, not tunable), then autotune-cache hit, then the
heuristic. Set ``REPRO_AUTOTUNE_CACHE=/path.json`` to persist the
default process-wide cache across runs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

import numpy as np

from repro.obs import trace as obs

# the static engines the heuristic chooses between; the fused Pallas
# backend and the k-out sampling engine join the measured (autotune)
# candidate set below
STATIC_METHODS = ("adaptive", "atomic_hook", "labelprop")
AUTOTUNE_METHODS = STATIC_METHODS + ("pallas_fused", "sampled")
INCREMENTAL_ABSORB = "incremental-absorb"
# delete-path routes (DESIGN.md §9): tombstone + scoped recompute over
# the affected components only — the fused variant runs the scoped scan
# through the cc_fused kernel (one launch); a bulk delete falls through
# to a static rebuild over the surviving log instead
DYNAMIC_DELETE = "tombstone-delete"
DYNAMIC_DELETE_FUSED = "tombstone-delete-fused"
# the tree-aware route (DESIGN.md §14): classify the batch against the
# maintained spanning forest, short-circuit all-non-tree batches, and
# reconnect via the forest skeleton + replacement edges otherwise
DYNAMIC_DELETE_FOREST = "tombstone-delete-forest"
DELETE_METHODS = (DYNAMIC_DELETE, DYNAMIC_DELETE_FUSED,
                  DYNAMIC_DELETE_FOREST)

# heuristic thresholds (see module docstring)
UPDATE_RATE_ABSORB = 0.5       # delta/total above this is a bulk load
DELETE_RATE_SCOPED = 0.5       # deletes/alive above this is a bulk drop
# tree-hit-rate routing: min(|V|-1, |E|)/|E| bounds the fraction of
# alive edges that can be spanning-tree edges — i.e. the expected
# tree-hit rate of a uniform delete batch. Below the threshold most
# deletes are non-tree and the forest route's short-circuit/skeleton
# reconnection wins; near 1 (road-like |E| ~ |V|) nearly every delete
# IS a tree edge and the plain scoped recompute is already right-sized
FOREST_TREE_RATIO = 0.75
MIN_SEGMENT_DENSITY = 1.5      # below: s = round(2E/V) <= 1 segment
LABELPROP_DENSITY_FRAC = 0.25  # density >= frac*V: near-clique regime
# k-out sampling routing (Hong et al.): max_degree/mean_degree above
# SAMPLED_SKEW marks a power-law/kron-like graph where the sampling
# phase collapses the giant component cheaply; road-like graphs sit
# near 1 and skip it. The edge floor keeps tiny graphs (the whole test
# corpus) on the exact engines — sampling's two extra jit launches
# only pay for themselves at scale.
SAMPLED_SKEW = 8.0
SAMPLED_MIN_EDGES = 4096

CACHE_FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class GraphFeatures:
    """Cheap selection features — all O(1) from array shapes."""

    num_nodes: int
    num_edges: int              # edges already absorbed (static: total)
    delta_edges: int | None = None    # pending insert batch (None: static)
    delta_deletes: int | None = None  # pending delete batch (None: static)
    degree_skew: float | None = None  # max_deg/mean_deg (None: unmeasured)

    @property
    def total_edges(self) -> int:
        return self.num_edges + (self.delta_edges or 0)

    @property
    def remaining_edges(self) -> int:
        """Post-delete edge-count upper bound (a delete row retires at
        most every copy of one edge; absent rows retire nothing)."""
        return max(self.num_edges - (self.delta_deletes or 0), 0)

    @property
    def density(self) -> float:
        """The paper's segmentation key: 2|E|/|V| (average degree)."""
        return 2.0 * self.total_edges / max(self.num_nodes, 1)

    @property
    def update_rate(self) -> float:
        """|delta E| / |E total| — 0 for a static (no-delta) call."""
        if self.delta_edges is None:
            return 0.0
        return self.delta_edges / max(self.total_edges, 1)

    @property
    def delete_rate(self) -> float:
        """|delete batch| / |E alive| — the delete-side twin of
        ``update_rate``: a batch small relative to the surviving set is
        worth scoping, a bulk drop is worth a static rebuild."""
        if self.delta_deletes is None:
            return 0.0
        return self.delta_deletes / max(self.num_edges, 1)

    @property
    def tree_edge_ratio(self) -> float:
        """Upper bound on the fraction of alive edges that are
        spanning-tree edges: min(|V|-1, |E|)/|E| — the expected
        tree-hit rate of a uniform delete batch (the delete-route
        feature behind ``FOREST_TREE_RATIO``)."""
        if self.num_edges <= 0:
            return 1.0
        return min(self.num_nodes - 1, self.num_edges) / self.num_edges


def extract_features(num_nodes: int, num_edges: int,
                     delta_edges: int | None = None,
                     delta_deletes: int | None = None,
                     degree_skew: float | None = None) -> GraphFeatures:
    return GraphFeatures(num_nodes=int(num_nodes),
                         num_edges=int(num_edges),
                         delta_edges=None if delta_edges is None
                         else int(delta_edges),
                         delta_deletes=None if delta_deletes is None
                         else int(delta_deletes),
                         degree_skew=None if degree_skew is None
                         else float(degree_skew))




def heuristic_method(f: GraphFeatures) -> str:
    """The paper's segmentation heuristic as a method choice."""
    if f.delta_deletes is not None:
        if f.num_edges > 0 and f.delete_rate <= DELETE_RATE_SCOPED:
            if f.tree_edge_ratio <= FOREST_TREE_RATIO:
                # mostly-non-tree regime: the maintained-forest route
                # short-circuits the common all-non-tree batch
                return DYNAMIC_DELETE_FOREST
            return DYNAMIC_DELETE
        # bulk drop: a static engine over the surviving edge set beats
        # scoping (most components are affected anyway)
        return heuristic_method(GraphFeatures(f.num_nodes,
                                              f.remaining_edges))
    if (f.delta_edges is not None and f.num_edges > 0
            and f.update_rate <= UPDATE_RATE_ABSORB):
        return INCREMENTAL_ABSORB
    if f.num_nodes <= 1 or f.total_edges == 0:
        return "adaptive"              # trivial either way
    if (f.degree_skew is not None and f.degree_skew >= SAMPLED_SKEW
            and f.total_edges >= SAMPLED_MIN_EDGES
            and f.density >= MIN_SEGMENT_DENSITY):
        return "sampled"               # skewed at scale: sampling wins
    if f.density < MIN_SEGMENT_DENSITY:
        return "atomic_hook"
    if f.density >= LABELPROP_DENSITY_FRAC * f.num_nodes:
        return "labelprop"
    return "adaptive"


# ---------------------------------------------------------------------------
# Measured autotune cache
# ---------------------------------------------------------------------------

class AutotuneCache:
    """Measured best-method table keyed on the power-of-two shape bucket.

    JSON format (``CACHE_FORMAT_VERSION``)::

        {"version": 1,
         "entries": {"v1024_e4096": {"method": "adaptive", "ms": 1.93,
                                     "num_nodes": 1000, "num_edges": 3900},
                     ...}}

    A lookup for any graph landing in a recorded bucket returns the
    measured winner; ``measure`` times the static candidates and
    records one. ``path=None`` keeps the table in memory only.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self.entries: dict[str, dict] = {}
        if path and os.path.exists(path):
            self.load()

    @staticmethod
    def key(num_nodes: int, num_edges: int) -> str:
        from repro.core.batch import bucket_shape
        v_pad, e_pad = bucket_shape(num_nodes, num_edges)
        return f"v{v_pad}_e{e_pad}"

    def lookup(self, num_nodes: int, num_edges: int) -> str | None:
        ent = self.entries.get(self.key(num_nodes, num_edges))
        # always-on obs counters: cold-cache serving (miss-heavy
        # steady state) must be visible in the tick summary
        obs.count("autotune.hit" if ent else "autotune.miss")
        return ent["method"] if ent else None

    def record(self, num_nodes: int, num_edges: int, method: str,
               ms: float) -> None:
        self.entries[self.key(num_nodes, num_edges)] = {
            "method": method, "ms": round(float(ms), 4),
            "num_nodes": int(num_nodes), "num_edges": int(num_edges)}
        if self.path:
            self.save()

    def save(self) -> None:
        """Atomic write: a process-unique temp file in the target dir +
        an atomic rename (``os.replace`` — rename semantics with
        cross-platform overwrite) — two concurrent
        ``ConnectivityService`` processes can interleave saves without
        ever corrupting the JSON (a fixed ``.tmp`` name would let their
        writes interleave in the SAME temp file; last rename still
        wins, but both renames are atomic)."""
        payload = {"version": CACHE_FORMAT_VERSION, "entries": self.entries}
        target = os.path.abspath(self.path)
        os.makedirs(os.path.dirname(target), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(target),
                                   prefix=os.path.basename(target) + ".",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(payload, fh, indent=1, sort_keys=True)
            os.replace(tmp, target)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def load(self) -> None:
        with open(self.path) as fh:
            payload = json.load(fh)
        if payload.get("version") != CACHE_FORMAT_VERSION:
            return                      # stale format: start fresh
        self.entries = dict(payload.get("entries", {}))

    def measure(self, edges, num_nodes: int,
                methods: tuple[str, ...] | None = None,
                reps: int = 2) -> str:
        """Time each candidate (static engines; plus the fused Pallas
        backend when a real kernel backend is available — wall-clocking
        the Python-interpreted emulation off-TPU is slow and says
        nothing about the compiled kernel) on this graph, record and
        return the wall-clock winner for its shape bucket. Every rep
        drains in-flight async work with ``block_until_ready`` BEFORE
        starting its timer — otherwise asynchronously dispatched work
        from the previous candidate flatters whichever method is
        measured next. Candidates run THROUGH the facade
        (``repro.api.solve``) so the measurement prices exactly what a
        production ``method="auto"`` call will pay — backend dispatch
        included."""
        from repro.api import solve
        if methods is None:
            from repro.kernels import default_interpret
            methods = STATIC_METHODS if default_interpret() \
                else AUTOTUNE_METHODS
        edges = np.asarray(edges, np.int32).reshape(-1, 2)
        best_method, best_ms = None, float("inf")
        for method in methods:
            warm = solve(edges, num_nodes, method=method)
            warm.labels.block_until_ready()
            ts = []
            for _ in range(reps):
                warm.labels.block_until_ready()   # quiesce before t0
                t0 = time.perf_counter()
                warm = solve(edges, num_nodes, method=method)
                warm.labels.block_until_ready()
                ts.append(time.perf_counter() - t0)
            ms = float(np.median(ts)) * 1e3
            if ms < best_ms:
                best_method, best_ms = method, ms
        self.record(num_nodes, edges.shape[0], best_method, best_ms)
        return best_method


def warm_start(graphs, cache: AutotuneCache, reps: int = 2
               ) -> AutotuneCache:
    """Benchmark-sweep warm start: measure every graph's bucket once."""
    for g in graphs:
        if cache.lookup(g.num_nodes, g.num_edges) is None:
            cache.measure(g.edges, g.num_nodes, reps=reps)
    return cache


_default_cache: AutotuneCache | None = None


def default_cache() -> AutotuneCache:
    """Process-wide cache; persisted iff ``REPRO_AUTOTUNE_CACHE`` names
    a JSON path."""
    global _default_cache
    if _default_cache is None:
        _default_cache = AutotuneCache(
            os.environ.get("REPRO_AUTOTUNE_CACHE"))
    return _default_cache


# ---------------------------------------------------------------------------
# The selection entry point
# ---------------------------------------------------------------------------

def select_static_explained(num_nodes: int, num_edges: int, *,
                            degree_skew: float | None = None,
                            cache: AutotuneCache | None = None
                            ) -> tuple[str, str]:
    """Static-solve selection WITH its provenance: ``(method, reason)``
    where reason is ``"autotune"`` (measured cache hit for the shape
    bucket) or ``"heuristic"`` (the paper's density rule, including the
    degree-skew sampling rule when the caller measured skew at ingest).
    This is what ``repro.api`` plans report via
    ``ExecutionPlan.explain()`` — ``select_method`` routes through it
    so the facade's account of the decision can never drift from the
    decision itself."""
    f = extract_features(num_nodes, num_edges, degree_skew=degree_skew)
    cache = default_cache() if cache is None else cache
    with obs.span("policy.select", num_nodes=f.num_nodes,
                  num_edges=f.total_edges) as sp:
        hit = cache.lookup(f.num_nodes, f.total_edges)
        if hit is not None:
            sp.tag(method=hit, reason="autotune")
            return hit, "autotune"
        choice = heuristic_method(f)
        sp.tag(method=choice, reason="heuristic")
        return choice, "heuristic"


def select_method(num_nodes: int, num_edges: int, *,
                  delta_edges: int | None = None,
                  delta_deletes: int | None = None,
                  degree_skew: float | None = None,
                  cache: AutotuneCache | None = None) -> str:
    """Pick the execution method from graph features.

    Static callers (``connected_components(method="auto")``) pass sizes
    only and get a method from ``STATIC_METHODS``; the registry's
    insert path also passes ``delta_edges`` and may get
    ``"incremental-absorb"`` back; its delete path passes
    ``delta_deletes`` and may get a ``DELETE_METHODS`` route back — the
    fused variant when the autotune cache's measured winner for the
    surviving-graph bucket is ``pallas_fused`` (measured truth decides
    which kernel backend runs the scoped scan, same as it decides the
    static engine). Autotuned winners override the heuristic for the
    static choice.
    """
    if delta_edges is None and delta_deletes is None:
        # static call: one shared path with the facade's plan(), so
        # ExecutionPlan.explain() can never drift from the selection
        return select_static_explained(num_nodes, num_edges,
                                       degree_skew=degree_skew,
                                       cache=cache)[0]
    f = extract_features(num_nodes, num_edges, delta_edges, delta_deletes)
    choice = heuristic_method(f)
    if choice == INCREMENTAL_ABSORB:
        return choice
    if choice == DYNAMIC_DELETE_FOREST:
        # the tree-aware route has no fused variant: its hot path is
        # the short-circuit (no scan at all), and the scoped phases run
        # over packed skeleton/crossing sets the fused kernel's
        # segment-boundary prefetch does not model
        return choice
    cache = default_cache() if cache is None else cache
    if choice == DYNAMIC_DELETE:
        hit = cache.lookup(f.num_nodes, max(f.remaining_edges, 1))
        return DYNAMIC_DELETE_FUSED if hit == "pallas_fused" else choice
    lookup_edges = f.total_edges if f.delta_deletes is None \
        else max(f.remaining_edges, 1)
    hit = cache.lookup(f.num_nodes, lookup_edges)
    return hit if hit is not None else choice


def select_for(num_nodes: int, num_edges: int, delta=None, *,
               delete: bool = False,
               cache: AutotuneCache | None = None) -> str:
    """The registry's mutation-path selection over a pending
    ``DeviceGraph`` delta: the update/delete-rate feature comes from
    the delta's static pytree metadata (true edge count) — no device
    sync, no host round trip of edge data. ``delete=True`` routes the
    batch through the delete-side heuristic (scoped tombstone delete
    vs full static rebuild over the survivors)."""
    size = None if delta is None else delta.num_edges
    with obs.span("policy.select_for", num_edges=num_edges, delta=size,
                  delete=delete) as sp:
        method = select_method(
            num_nodes, num_edges,
            delta_edges=None if delete else size,
            delta_deletes=size if delete else None,
            cache=cache)
        sp.tag(method=method)
        return method
