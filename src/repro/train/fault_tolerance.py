"""Fault tolerance: restartable step loops + straggler watchdog.

``run_with_restarts`` is the crash boundary a 1000-node deployment needs:
the step function may raise (preemption, flaky host, injected test
failure) — the loop restores the last checkpoint, rebuilds the data
stream at the restored step (the pipeline is (seed, step)-deterministic),
and continues, up to ``max_restarts``.

``StepWatchdog`` tracks a robust step-time estimate (EMA + MAD) and
flags outlier steps — on a real multi-host deployment the flag feeds the
controller that triggers elastic re-sharding (see launch/elastic.py);
here it records the events for inspection/tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.train import checkpoint as ckpt_lib


class SimulatedFailure(RuntimeError):
    """Raised by tests / chaos hooks to exercise the restart path."""


@dataclasses.dataclass
class StepWatchdog:
    """Flags steps slower than ``threshold``× the EMA step time."""
    threshold: float = 3.0
    ema: Optional[float] = None
    alpha: float = 0.1
    slow_steps: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.threshold * self.ema
        if slow:
            self.slow_steps.append((step, dt, self.ema))
        # don't fold outliers into the estimate
        if not slow:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


@dataclasses.dataclass
class RunReport:
    final_state: object
    restarts: int
    steps_run: int
    slow_steps: list


def run_with_restarts(
    *,
    init_state_fn: Callable[[], object],
    step_fn: Callable[[object, dict], tuple],
    stream_fn: Callable[[int], object],
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    max_restarts: int = 3,
    keep: int = 3,
    watchdog: Optional[StepWatchdog] = None,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
) -> RunReport:
    """Crash-tolerant training driver.

    * ``init_state_fn()`` builds a fresh TrainState (used only when no
      checkpoint exists).
    * ``stream_fn(start_step)`` (re)builds the data iterator from a step —
      restarts resume the exact stream position.
    * ``step_fn(state, batch) -> (state, metrics)`` may raise; the loop
      restores from the newest checkpoint and replays.
    """
    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep)
    watchdog = watchdog or StepWatchdog()
    restarts = 0
    steps_run = 0

    def load_or_init():
        last = ckpt_lib.latest_step(ckpt_dir)
        state = init_state_fn()
        if last is not None:
            state = ckpt_lib.restore(ckpt_dir, like=state, step=last)
            return state, last
        return state, 0

    state, start = load_or_init()
    while True:
        stream = stream_fn(start)
        try:
            step = start
            while step < total_steps:
                batch = next(stream)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                dt = time.perf_counter() - t0
                step += 1
                steps_run += 1
                watchdog.observe(step, dt)
                if on_metrics is not None:
                    on_metrics(step, metrics)
                if step % ckpt_every == 0 or step == total_steps:
                    saver.save(state, step)
            saver.wait()
            return RunReport(final_state=state, restarts=restarts,
                             steps_run=steps_run,
                             slow_steps=watchdog.slow_steps)
        except (SimulatedFailure, RuntimeError) as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded max_restarts={max_restarts}") from e
            saver.wait()
            state, start = load_or_init()
