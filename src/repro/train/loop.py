"""Training loop assembly: model fns + optimizer + pipeline -> driver.

``fit`` is the single-process convenience loop used by the examples and
tests; the production entry point is ``repro.launch.train`` which jits
the same ``make_train_step`` product under mesh shardings and wraps it in
``fault_tolerance.run_with_restarts``.
"""
from __future__ import annotations

import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.train import train_state
from repro.train.optimizer import Optimizer


def fit(
    *,
    loss_fn: Callable,
    params,
    opt: Optimizer,
    stream: Iterator[dict],
    steps: int,
    log_every: int = 20,
    log_fn: Callable[[str], None] = print,
    jit: bool = True,
) -> tuple[dict, list[dict]]:
    """Train for ``steps`` steps; returns (state, history)."""
    # copy params: the jitted step donates its state argument, and
    # callers keep their reference for before/after comparisons
    import jax.numpy as jnp
    state = train_state.create(jax.tree.map(jnp.copy, params), opt)
    step_fn = train_state.make_train_step(loss_fn, opt)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch = next(stream)
        state, metrics = step_fn(state, batch)
        if (i + 1) % log_every == 0 or i == steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = i + 1
            m["wall_s"] = round(time.perf_counter() - t0, 3)
            history.append(m)
            log_fn(f"step {i + 1:5d}  loss {m['loss']:.4f}  "
                   f"gnorm {m['grad_norm']:.3f}  {m['wall_s']:.1f}s")
    return state, history
