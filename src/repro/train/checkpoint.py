"""Fault-tolerant checkpointing: atomic, async, elastic.

* **Atomic**: state is written to ``step_XXXX.tmp`` then ``os.rename``-d —
  a crash mid-save never corrupts the latest checkpoint.
* **Async**: ``save_async`` snapshots to host memory (device_get) on the
  caller's thread — cheap — then serializes on a background thread so the
  train loop keeps stepping during disk I/O.
* **Elastic / mesh-agnostic**: leaves are saved as *full* (unsharded)
  numpy arrays + a pytree manifest. ``restore`` device_puts them under
  ANY target sharding tree — a checkpoint taken on a 512-chip mesh
  restores onto 256 chips or 1 CPU (elastic rescale; tested).
* **Retention**: keep the newest ``keep`` checkpoints, delete older.

Format: ``<dir>/step_<N>/`` with ``manifest.json`` (tree structure,
shapes, dtypes) and ``arrays.npz``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


_SEP = "/"


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def name(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return _SEP.join(parts)

    return [(name(path), leaf) for path, leaf in flat]


def save(directory: str, state, step: int, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    host_state = jax.device_get(state)
    return _write(directory, host_state, step, keep)


class AsyncCheckpointer:
    """Device->host snapshot on the caller thread, disk I/O on a worker.

    ``wait()`` joins the in-flight save (call before shutdown / before
    restoring). A new save waits for the previous one (single-flight)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: list[BaseException] = []

    def save(self, state, step: int) -> None:
        self.wait()
        host_state = jax.device_get(state)   # snapshot NOW (consistent)

        def work():
            try:
                _write(self.directory, host_state, step, self.keep)
            except BaseException as e:       # noqa: BLE001
                self._err.append(e)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err:
            raise self._err[0]


def _write(directory: str, host_state, step: int, keep: int) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named = _flatten_with_paths(host_state)
    treedef = jax.tree_util.tree_structure(host_state)
    arrays = {}
    manifest = {"step": step, "leaves": [], "treedef": str(treedef)}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(leaf)
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"].append(
            {"name": name, "key": key, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)              # atomicity point
    _apply_retention(directory, keep)
    return final


def _apply_retention(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, old))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1])


def restore(directory: str, like, step: Optional[int] = None,
            sharding_tree=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``sharding_tree`` (same structure) places each
    leaf — pass the CURRENT mesh's shardings to elastically re-shard a
    checkpoint from any source mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    like_named = _flatten_with_paths(like)
    by_name = {l["name"]: l for l in manifest["leaves"]}
    leaves = []
    for name, leaf_like in like_named:
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        entry = by_name[name]
        arr = data[entry["key"]]
        want_shape = tuple(leaf_like.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {name!r}: checkpoint shape {arr.shape} != "
                f"model shape {want_shape}")
        leaves.append(arr.astype(leaf_like.dtype))

    treedef = jax.tree_util.tree_structure(like)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if sharding_tree is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, sharding_tree)
    return restored
