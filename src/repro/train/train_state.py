"""TrainState: the checkpointable unit (params + optimizer state + step).

A plain pytree (dict), so it flows through jit/shard_map/checkpoint
without special handling. ``sharding_tree`` mirrors the state structure
with NamedShardings so the launcher can place every leaf.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer, apply_updates


def create(params: Any, opt: Optimizer) -> dict:
    return {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(loss_fn: Callable, opt: Optimizer,
                    accum_steps: int = 1,
                    accum_dtype=None) -> Callable:
    """Build ``step(state, batch) -> (state, metrics)``.

    ``loss_fn(params, batch) -> scalar``. The returned function is NOT
    jitted here — the launcher jits it with in/out shardings; tests and
    examples jit it bare.

    ``accum_steps`` > 1 enables gradient accumulation: the global batch
    is split into microbatches on the leading dim and scanned, cutting
    live activation memory by the accumulation factor (grads accumulate
    in fp32; numerics equal the single-shot step up to fp summation
    order — tested). The production lever for fitting large
    (batch × seq) cells into 16 GB/chip HBM.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: dict, batch: dict):
        if accum_steps == 1:
            loss, grads = grads_of(state["params"], batch)
        else:
            def split(x):
                a = accum_steps
                assert x.shape[0] % a == 0, (x.shape, a)
                return x.reshape(a, x.shape[0] // a, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype or jnp.float32),
                state["params"])

            def body(acc, mb):
                g_acc, l_acc = acc
                l, g = grads_of(state["params"], mb)
                g_acc = jax.tree.map(
                    lambda a_, g_: a_ + g_.astype(a_.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            (g_sum, l_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro)
            loss = l_sum / accum_steps
            grads = jax.tree.map(
                lambda g_, p: (g_ / accum_steps).astype(p.dtype),
                g_sum, state["params"])
        updates, new_opt, gnorm = opt.update(
            grads, state["opt"], state["params"], state["step"])
        new_params = apply_updates(state["params"], updates)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_state, metrics

    return step


def state_sharding_tree(state_shapes, mesh, param_spec_tree,
                        replicated_spec):
    """NamedSharding tree for a TrainState: params and both Adam moments
    share ``param_spec_tree`` (ZeRO: optimizer state sharded exactly like
    its parameter); step is replicated."""
    from jax.sharding import NamedSharding

    def shard(spec):
        return NamedSharding(mesh, spec)

    params_sh = jax.tree.map(shard, param_spec_tree)
    opt_shapes = state_shapes["opt"]
    opt_sh = {}
    for key, sub in opt_shapes.items():
        # moments mirror the param tree structure
        opt_sh[key] = jax.tree.map(shard, param_spec_tree)
    return {"params": params_sh, "opt": opt_sh,
            "step": shard(replicated_spec)}


def param_count(state: dict) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(state["params"]))
