"""Gradient compression for the data-parallel all-reduce.

Int8 uniform quantization with **error feedback** (EF-SGD, Karimireddy
et al.): each worker quantizes (grad + residual), all-reduces the int8
payload (8.25 bits/element on the wire vs 32/16), dequantizes, and keeps
the quantization error as next step's residual — unbiased in the long
run, provably convergent for smooth objectives.

Two entry points:
  * ``compress``/``decompress`` — pure-pytree transform pair (tested for
    the EF contraction property);
  * ``compressed_psum`` — drop-in for ``jax.lax.psum`` inside
    ``shard_map``: quantize → psum(int32 accumulate) → dequant. Scales
    are psum-maxed first so all workers share one dequant scale (a tiny
    fp32 all-reduce).

Wire math on the 2-pod mesh: a grok-1 DP all-reduce moves ~2·P bytes/chip
in bf16; int8 cuts the DP-collective term ~2× at <1e-3 relative error
(measured in tests) — the knob for when the roofline says the collective
term dominates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """fp -> int8 with round-to-nearest; scale maps max|x| -> 127."""
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, 1e-30))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def compress(grads, residual):
    """(grads + residual) -> (int8 payload, scales, new_residual)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.max(jnp.abs(gf)) / 127.0
        q = _quantize(gf, scale)
        deq = q.astype(jnp.float32) * scale
        return q, scale, gf - deq   # error feedback residual

    out = jax.tree.map(one, grads, residual)
    is_triple = lambda x: isinstance(x, tuple)  # noqa: E731
    payload = jax.tree.map(lambda o: o[0], out, is_leaf=is_triple)
    scales = jax.tree.map(lambda o: o[1], out, is_leaf=is_triple)
    new_res = jax.tree.map(lambda o: o[2], out, is_leaf=is_triple)
    return payload, scales, new_res


def decompress(payload, scales, dtype_tree):
    return jax.tree.map(
        lambda q, s, d: (q.astype(jnp.float32) * s).astype(d.dtype),
        payload, scales, dtype_tree)


def zero_residual(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, residual, axis_name: str):
    """int8 EF all-reduce for use inside ``shard_map``.

    Returns (mean-reduced grads, new residual). Shared scale =
    pmax(local scale) so dequantization is identical on every worker.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.max(jnp.abs(gf)) / 127.0
        scale = jax.lax.pmax(scale, axis_name)          # shared scale
        q = _quantize(gf, scale)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
        new_r = gf - q.astype(jnp.float32) * scale      # local EF error
        return mean.astype(g.dtype), new_r

    out = jax.tree.map(one, grads, residual)
    is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
    reduced = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    new_res = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return reduced, new_res
