"""Hand-rolled optimizers (no optax): AdamW, SGD-momentum, global-norm
clipping, cosine/linear schedules.

Optimizers are (init, update) pairs over parameter pytrees. Moment dtype
is configurable — grok-1-scale configs keep m/v in bf16 so the optimizer
state fits the 16 GB/chip v5e HBM budget (see configs/grok_1_314b.py);
update math always runs in fp32 and casts back on store.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable      # params -> opt_state
    update: Callable    # (grads, opt_state, params, step) -> (updates, new_state)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), \
        norm


# ==========================================================================
# Schedules
# ==========================================================================

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(lr_val: float) -> Callable:
    return lambda step: jnp.asarray(lr_val, jnp.float32)


# ==========================================================================
# AdamW
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Optional[object] = None   # None = same as param


def adamw(cfg: AdamWConfig) -> Optimizer:
    lr_fn = cfg.lr if callable(cfg.lr) else constant_schedule(cfg.lr)

    def init(params):
        def zeros(p):
            dt = cfg.moment_dtype or p.dtype
            return jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if cfg.clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        else:
            gnorm = global_norm(grads)
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(step)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
            vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
            mh = mf / bc1
            vh = vf / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return ((-lr * delta).astype(p.dtype),
                    mf.astype(m.dtype), vf.astype(v.dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": new_m, "v": new_v}, gnorm

    return Optimizer(init=init, update=update)


# ==========================================================================
# SGD (momentum)
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: Callable | float = 1e-2
    momentum: float = 0.9
    clip_norm: float = 0.0


def sgd(cfg: SGDConfig) -> Optimizer:
    lr_fn = cfg.lr if callable(cfg.lr) else constant_schedule(cfg.lr)

    def init(params):
        if cfg.momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        if cfg.clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        else:
            gnorm = global_norm(grads)
        lr = lr_fn(step)
        if cfg.momentum == 0.0:
            updates = jax.tree.map(
                lambda g, p: (-lr * g.astype(jnp.float32)).astype(p.dtype),
                grads, params)
            return updates, state, gnorm
        new_mu = jax.tree.map(
            lambda mu, g: cfg.momentum * mu + g.astype(mu.dtype),
            state["mu"], grads)
        updates = jax.tree.map(
            lambda mu, p: (-lr * mu.astype(jnp.float32)).astype(p.dtype),
            new_mu, params)
        return updates, {"mu": new_mu}, gnorm

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
