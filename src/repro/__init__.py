"""repro — adaptive work-efficient Connected Components on TPU (JAX).

The public surface is the ``repro.api`` facade, re-exported here::

    from repro import Solver, solve

    res = solve(edges, num_nodes)            # one-shot, method="auto"
    s = Solver.open(edges, num_nodes)        # a session
    print(s.plan().explain())                # the adaptive decision

Engine subpackages (``repro.core``, ``repro.connectivity``,
``repro.graphs``, ``repro.kernels``) stay importable for power users,
but new code should come through the front door — everything routed
through ``Solver``/``BACKENDS`` gets policy selection, autotuning, and
inspectable plans for free.
"""
from repro.api import (BACKENDS, Backend, Capabilities, CCResult,
                       DeviceGraph, ExecutionPlan, Solver, WorkCounters,
                       available_backends, capability_matrix, get_backend,
                       register_backend, solve)

__version__ = "0.5.0"

__all__ = [
    "__version__",
    "Solver",
    "solve",
    "ExecutionPlan",
    "Backend",
    "Capabilities",
    "BACKENDS",
    "register_backend",
    "get_backend",
    "available_backends",
    "capability_matrix",
    "CCResult",
    "WorkCounters",
    "DeviceGraph",
]
