"""Warn-once deprecation plumbing for the legacy entrypoints.

Every pre-facade entrypoint (``connected_components`` and friends) now
forwards into ``repro.api`` and emits a ``DeprecationWarning`` exactly
once per process per entrypoint — loud enough to migrate callers,
quiet enough not to spam a hot loop. ``reset()`` exists for tests that
pin the exactly-once contract.
"""
from __future__ import annotations

import warnings

from repro.obs import trace as _obs

_WARNED: set[str] = set()


def warn_once(legacy: str, replacement: str) -> None:
    """Emit one ``DeprecationWarning`` per process for ``legacy``.

    EVERY call bumps the always-on ``deprecated.<legacy>`` obs counter
    (the warning fires once; legacy-path traffic stays visible in the
    tick summary and trace exports)."""
    _obs.count(f"deprecated.{legacy}")
    if legacy in _WARNED:
        return
    _WARNED.add(legacy)
    warnings.warn(
        f"{legacy} is deprecated; use {replacement} (the repro.api "
        "facade) instead", DeprecationWarning, stacklevel=3)


def reset() -> None:
    """Forget which entrypoints warned (test hook)."""
    _WARNED.clear()
