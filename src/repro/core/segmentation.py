"""Adaptive segmentation heuristic (paper §III-B).

The paper splits the edge list into ``s = 2|E| / |V|`` segments — i.e.
segments of ≈ |V|/2 edges — so that every Atomic-Hook round touches a
working set proportional to the |V|-sized parent workspace, and a full
O(|V|) Multi-Jump compress runs between rounds.  On TPU the "atomic
contention" argument becomes a gather/scatter *working-set* argument, but
the heuristic is unchanged (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SegmentationPlan:
    """Static segmentation plan (shapes must be known at trace time)."""

    num_edges: int          # true edge count
    num_nodes: int
    num_segments: int       # s
    segment_size: int       # padded per-segment edge count
    padded_edges: int       # num_segments * segment_size

    @property
    def avg_degree(self) -> float:
        return 2.0 * self.num_edges / max(self.num_nodes, 1)


def adaptive_num_segments(num_edges: int, num_nodes: int) -> int:
    """The paper's heuristic: s = 2|E|/|V| (at least 1)."""
    if num_nodes <= 0:
        return 1
    return max(1, int(round(2.0 * num_edges / num_nodes)))


def plan_segmentation(
    num_edges: int,
    num_nodes: int,
    num_segments: int | None = None,
) -> SegmentationPlan:
    """Build a static plan; ``num_segments=None`` uses the adaptive heuristic."""
    s = num_segments if num_segments is not None else adaptive_num_segments(
        num_edges, num_nodes)
    s = max(1, min(s, max(num_edges, 1)))
    seg = int(math.ceil(max(num_edges, 1) / s))
    return SegmentationPlan(
        num_edges=num_edges,
        num_nodes=num_nodes,
        num_segments=s,
        segment_size=seg,
        padded_edges=s * seg,
    )
