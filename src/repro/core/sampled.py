"""Sampling-accelerated CC — the k-out / Afforest-style engine.

Hong et al. (PAPERS.md) observe that on most real graphs — especially
skewed-degree (kron / social) inputs — a cheap neighbor-sampling phase
collapses the giant component before the full edge list is ever
touched, so the expensive scan only has to process the small residue.
This module is that observation composed out of the repo's existing
round machinery, in two jits (each a ``repro.analysis`` trace entry):

* ``_sample_phase_jit`` — build CSR offsets on device (sort +
  searchsorted over the SYMMETRIZED edge list, so one-direction
  undirected storage still samples both endpoints), take the first
  ``k`` slots per vertex (k-out sampling; invalid slots become (0, 0)
  no-ops and are never billed), then run ``sample_rounds`` fixed
  hook+compress rounds over the |V|*k sampled edges — recording the
  spanning-forest parent edges as it hooks. The giant component is
  identified with the existing census kernel (one scatter-add +
  argmax) for telemetry; correctness never depends on it.
* ``_residue_scan_jit`` — the residue is every stored edge whose
  endpoints still carry different labels (a strict superset filter of
  "both endpoints outside the giant component": intra-component edges
  of EVERY collapsed component are dropped, not just the giant's).
  Residue edges are compacted to a (0, 0)-padded prefix (one stable
  sort — the ``compact_alive`` idiom) and run through the ordinary
  Fig. 4 pipeline from the sampled labels: segment scan + trailing
  cleanup, billing the traced residue count only. ``fused=True``
  routes the scan through the ``cc_fused`` Pallas kernel
  (``sampled_fused``; the kernel does not record forest edges).

Work accounting: the sample phase bills ``valid-slot count x (1 +
lift_steps)`` hook evaluations per round; the residue scan bills true
residue edges only. On skewed inputs the total is a small fraction of
what the full-scan backends pay — the headline of BENCH_sampled.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import rounds
from repro.core.rounds import WorkCounters
from repro.core.segmentation import plan_segmentation

SAMPLE_K = 2          # neighbors sampled per vertex (Afforest's k)
SAMPLE_ROUNDS = 2     # fixed hook+compress rounds over the sample


class SampledResult(NamedTuple):
    """labels + forest + work, plus the phase-split telemetry."""

    labels: jnp.ndarray           # int32 [V] canonical min-id labels
    parents: jnp.ndarray          # int32 [V, 2] forest edges (-1 = root)
    work: WorkCounters            # combined (sample + residue) billing
    stats: dict                   # device scalars: phase split + giant


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "k", "sample_rounds",
                              "lift_steps"))
def _sample_phase_jit(edges, true_edges, *, num_nodes, k, sample_rounds,
                      lift_steps):
    """k-out sampling phase: CSR on device, hook each vertex to its
    first ``k`` neighbors for ``sample_rounds`` rounds, forest
    recorded. Returns ``(pi, parents, work, n_sampled, giant_label,
    giant_size)`` — all device values."""
    e = edges.shape[0]
    # symmetrize so vertices stored only as targets still get sampled;
    # padded (0, 0) rows stay (0, 0) and are masked out via the true
    # count below
    sym = jnp.concatenate([edges, edges[:, ::-1]], axis=0)
    row_real = jnp.arange(e, dtype=jnp.int32) < true_edges
    real = jnp.concatenate([row_real, row_real])
    src = sym[:, 0]
    order = jnp.argsort(src, stable=True)
    sorted_src = src[order]
    neighbors = sym[order, 1]
    real_sorted = real[order]
    offsets = jnp.searchsorted(
        sorted_src, jnp.arange(num_nodes + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    # slot (v, j) = CSR position offsets[v] + j; valid iff inside v's
    # row AND backed by a true (unpadded) edge
    slots = offsets[:-1, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    in_row = slots < offsets[1:, None]
    slots_c = jnp.minimum(slots, 2 * e - 1)
    valid = jnp.logical_and(in_row, real_sorted[slots_c])
    su = jnp.where(valid, jnp.arange(num_nodes,
                                     dtype=jnp.int32)[:, None], 0)
    sv = jnp.where(valid, neighbors[slots_c], 0)
    sampled = jnp.stack([su.reshape(-1), sv.reshape(-1)], axis=-1)
    n_sampled = jnp.sum(valid).astype(jnp.int32)

    pi = jnp.arange(num_nodes, dtype=jnp.int32)
    parents = rounds.empty_forest(num_nodes)
    work = WorkCounters.zeros()
    bill = n_sampled * (1 + lift_steps)
    for _ in range(sample_rounds):
        pi, parents = rounds.hook_edges_forest(pi, parents, sampled,
                                               lift_steps=lift_steps)
        work = work.add(hook_ops=bill, hook_rounds=1)
        pi, work = rounds.compress(pi, work)

    census = jnp.zeros((num_nodes,), jnp.int32).at[pi].add(1)
    giant = jnp.argmax(census).astype(jnp.int32)
    return pi, parents, work, n_sampled, giant, census[giant]


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "num_segments", "lift_steps",
                              "fused", "interpret"))
def _residue_scan_jit(edges, true_edges, pi, parents, work, *,
                      num_nodes, num_segments, lift_steps, fused,
                      interpret):
    """Adaptive Fig. 4 scan over the residue only: edges whose
    endpoints the sampling phase left in different components, packed
    to a prefix and billed as a traced count. Starts from the sampled
    labels (NOT identity). Returns ``(pi, parents, work, n_residue)``."""
    e = edges.shape[0]
    row_real = jnp.arange(e, dtype=jnp.int32) < true_edges
    live = jnp.logical_and(pi[edges[:, 0]] != pi[edges[:, 1]], row_real)
    n_res = jnp.sum(live).astype(jnp.int32)
    order = jnp.argsort(~live, stable=True)       # residue rows first
    packed = jnp.where(live[order][:, None], edges[order], 0)
    plan = plan_segmentation(e, num_nodes, num_segments)
    segments = rounds.pad_and_segment(packed, plan)
    counts = rounds.segment_true_counts(n_res, plan)
    if fused:
        ops = rounds.fused_round_ops(lift_steps, interpret=interpret)
        pi, work = rounds.segment_scan(pi, segments, ops, work,
                                       true_counts=counts)
        pi, work = rounds.cleanup_rounds(pi, segments.reshape(-1, 2),
                                         ops, work, true_edges=n_res)
    else:
        pi, parents, work = rounds.forest_segment_scan(
            pi, parents, segments, work, counts, lift_steps=lift_steps)
        pi, parents, work = rounds.forest_cleanup_rounds(
            pi, parents, segments.reshape(-1, 2), work,
            true_edges=n_res, lift_steps=lift_steps)
    return pi, parents, work, n_res


def solve_sampled(graph, num_nodes: int | None = None, *,
                  k: int = SAMPLE_K,
                  sample_rounds: int = SAMPLE_ROUNDS,
                  num_segments: int | None = None,
                  lift_steps: int = 2,
                  fused: bool = False,
                  interpret: bool | None = None) -> SampledResult:
    """The sampled engine entry (the ``sampled`` / ``sampled_fused``
    backends dispatch here; go through the ``repro.api`` facade).

    Two device programs: the k-out sampling phase, then the adaptive
    scan over the residue. ``fused=True`` runs the residue scan
    through the ``cc_fused`` Pallas kernel (no forest recording on the
    residue — ``sampled_fused`` reports ``spanning_forest=False``).
    Each phase runs under its own ``repro.obs`` span, and the
    sampled-vs-residue work split lands in ``SampledResult.stats``.
    """
    from repro.graphs.device import as_device_graph
    from repro.obs import trace as obs
    g = as_device_graph(graph, num_nodes, num_segments=num_segments)
    v = g.num_nodes
    if v <= 0:
        z = jnp.zeros((), jnp.int32)
        return SampledResult(jnp.zeros((0,), jnp.int32),
                             rounds.empty_forest(0),
                             WorkCounters.zeros(),
                             {"sample_hook_ops": z, "residue_hook_ops": z,
                              "n_sampled": z, "n_residue": z,
                              "giant_label": z, "giant_size": z})
    if g.edges.shape[0] == 0 or g.true_edges_static == 0:
        z = jnp.zeros((), jnp.int32)
        return SampledResult(jnp.arange(v, dtype=jnp.int32),
                             rounds.empty_forest(v),
                             WorkCounters.zeros(),
                             {"sample_hook_ops": z, "residue_hook_ops": z,
                              "n_sampled": z, "n_residue": z,
                              "giant_label": z,
                              "giant_size": jnp.ones((), jnp.int32)})
    if fused and interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    true = g.true_edges_device()
    with obs.span("sampled.sample_phase", num_nodes=v, k=k):
        pi, parents, s_work, n_sampled, giant, giant_size = \
            _sample_phase_jit(g.edges, true, num_nodes=v, k=k,
                              sample_rounds=sample_rounds,
                              lift_steps=lift_steps)
    with obs.span("sampled.residue_scan", num_nodes=v):
        pi, parents, work, n_res = _residue_scan_jit(
            g.edges, true, pi, parents, s_work, num_nodes=v,
            num_segments=g.plan.num_segments, lift_steps=lift_steps,
            fused=fused, interpret=bool(interpret))
    work = work.add(sync_rounds=2)      # one jit call per phase
    stats = {"sample_hook_ops": s_work.hook_ops,
             "residue_hook_ops": work.hook_ops - s_work.hook_ops,
             "n_sampled": n_sampled, "n_residue": n_res,
             "giant_label": giant, "giant_size": giant_size}
    # always-on host counters: the sampled-vs-residue work split is
    # part of obs_summary() whether or not span tracing is enabled
    obs.count("sampled.solves")
    obs.count("sampled.hook_ops.sample", int(stats["sample_hook_ops"]))
    obs.count("sampled.hook_ops.residue", int(stats["residue_hook_ops"]))
    return SampledResult(pi, parents, work, stats)
