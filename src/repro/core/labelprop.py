"""Label-propagation CC baseline (paper §I — the other classic parallel
approach). Included because the paper positions Hook-Compress against it:
label propagation needs O(diameter) sweeps, which is why it loses badly on
high-diameter (road) graphs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cc import CCResult, WorkCounters

_MAX_ITERS = 4096


def _cc_labelprop(edges: jnp.ndarray, num_nodes: int,
                  true_edges=None) -> CCResult:
    u, v = edges[:, 0], edges[:, 1]
    e = edges.shape[0] if true_edges is None else true_edges

    def cond(state):
        _, changed, iters, _ = state
        return jnp.logical_and(changed, iters < _MAX_ITERS)

    def body(state):
        lab, _, iters, w = state
        # disseminate min label across every edge, both directions
        new = lab.at[v].min(lab[u])
        new = new.at[u].min(new[v])
        changed = jnp.any(new != lab)
        w = w.add(hook_ops=2 * e, hook_rounds=1, sync_rounds=1)
        return new, changed, iters + 1, w

    lab0 = jnp.arange(num_nodes, dtype=jnp.int32)
    lab, _, _, work = jax.lax.while_loop(
        cond, body,
        (lab0, jnp.asarray(True), jnp.zeros((), jnp.int32),
         WorkCounters.zeros()))
    return CCResult(lab, work)
