"""Batched Connected Components: many graphs, one device program.

The serving-shaped workload (DESIGN.md §4): lots of small/medium graphs
— molecule batches, per-user subgraphs, sampled minibatch blocks —
where per-graph dispatch overhead dominates. Graphs are bucketed by
*padded* shape (vertex and edge counts rounded up to powers of two), and
each bucket runs the shared adaptive core (``rounds.adaptive_rounds``)
under ``jax.vmap`` as ONE jitted program:

  * vertices are padded as self-roots — ``pi0 = arange(V_pad)`` makes
    every padded vertex its own (untouched) component;
  * edges are padded with ``(0, 0)`` no-ops (self-loop hooks);
  * the jit cache is keyed on the bucket shape (static ``num_nodes`` /
    segment plan), so a stream of same-regime graphs compiles once.

Because every variant produces *canonical min-id labels* (a fixed point
independent of hook order), the batched labels are bit-identical to the
per-graph ``connected_components`` output — the tests assert exactly
that on mixed-size buckets.

Work accounting stays honest under padding: per-graph true edge counts
ride through the vmap as traced scalars, so ``hook_ops`` bills real
edges only and ``jump_ops`` bills the true |V| (padding is free; see
``rounds.WorkCounters``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.core.cc import CCResult
from repro.core.rounds import WorkCounters
from repro.core.segmentation import plan_segmentation

_MIN_NODES = 8
_MIN_EDGES = 8


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (>= 1)."""
    return _next_pow2(max(x, 1))


def pad_rows_pow2(arr: np.ndarray, min_rows: int = _MIN_EDGES
                  ) -> np.ndarray:
    """Pad axis 0 with zero rows to a power-of-two count (floored at
    ``min_rows``). The same bucket rule the batched engine uses for
    edge lists, reused by the connectivity service to route same-shape
    query microbatches through one jit cache entry; zero rows are
    no-ops for every query kernel (vertex 0 compared with itself)."""
    arr = np.asarray(arr)
    target = next_pow2(max(arr.shape[0], min_rows))
    if target == arr.shape[0]:
        return arr
    pad = np.zeros((target - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def bucket_shape(num_nodes: int, num_edges: int) -> tuple[int, int]:
    """The (V_pad, E_pad) bucket a graph lands in: next powers of two,
    floored at small minima so tiny graphs share one compile."""
    return (_next_pow2(max(num_nodes, _MIN_NODES)),
            _next_pow2(max(num_edges, _MIN_EDGES)))


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "num_segments", "lift_steps"))
def _cc_batched_jit(edges, true_edge_counts, true_node_counts, *,
                    num_nodes, num_segments, lift_steps):
    """vmapped adaptive CC over one bucket.

    edges: [B, E_pad, 2] int32 ((0,0)-padded);
    true_edge_counts / true_node_counts: [B] int32 billing scalars.
    """
    plan = plan_segmentation(edges.shape[1], num_nodes, num_segments)

    def one(ed, n_edges, n_nodes):
        ops = rounds.jnp_round_ops(lift_steps, bill_nodes=n_nodes)
        pi, work = rounds.adaptive_rounds(ed, num_nodes, plan, ops=ops,
                                          true_edges=n_edges)
        return CCResult(pi, work.add(sync_rounds=1))

    return jax.vmap(one)(edges, true_edge_counts, true_node_counts)


class GraphBatch(NamedTuple):
    """One shape bucket, ready for the device: [B, E_pad, 2] edges plus
    per-graph true sizes (for label truncation and work billing)."""
    edges: np.ndarray        # int32 [B, E_pad, 2]
    num_nodes: int           # V_pad (static bucket height)
    true_nodes: np.ndarray   # int32 [B]
    true_edges: np.ndarray   # int32 [B]
    indices: np.ndarray      # int32 [B] positions in the caller's list


def stack_device_graphs(graphs: Sequence) -> list[GraphBatch]:
    """DeviceGraph bucket stacking: group by the (V_pad, E_pad) pow2
    bucket, pad each member's edges on DEVICE (jitted (0,0) rows) and
    ``jnp.stack`` the bucket — no host round trip. True edge/node
    counts come from static DeviceGraph metadata (explicit device_put,
    so the path stays legal under ``jax.transfer_guard``)."""
    buckets: dict[tuple[int, int], list[int]] = {}
    for i, g in enumerate(graphs):
        if g.true_edges_static is None:
            raise ValueError("batched execution needs static true "
                             "edge counts (graph %d)" % i)
        buckets.setdefault(
            bucket_shape(g.num_nodes, int(g.edges.shape[0])),
            []).append(i)
    out = []
    for (v_pad, e_pad), members in sorted(buckets.items()):
        stack = jnp.stack(
            [graphs[i].pad_rows(e_pad).edges for i in members])
        tn = np.asarray([graphs[i].num_nodes for i in members], np.int32)
        te = np.asarray([graphs[i].true_edges_static for i in members],
                        np.int32)
        out.append(GraphBatch(edges=stack, num_nodes=v_pad,
                              true_nodes=tn, true_edges=te,
                              indices=np.asarray(members, np.int32)))
    return out


def bucketize(graphs: Sequence[tuple[np.ndarray, int]]
              ) -> list[GraphBatch]:
    """Group (edges, num_nodes) pairs into shape buckets."""
    buckets: dict[tuple[int, int], list[int]] = {}
    prepped = []
    for i, (edges, n) in enumerate(graphs):
        edges = np.asarray(edges, np.int32).reshape(-1, 2)
        prepped.append((edges, int(n)))
        buckets.setdefault(bucket_shape(int(n), edges.shape[0]),
                           []).append(i)
    out = []
    for (v_pad, e_pad), members in sorted(buckets.items()):
        stack = np.zeros((len(members), e_pad, 2), np.int32)
        tn = np.zeros(len(members), np.int32)
        te = np.zeros(len(members), np.int32)
        for row, i in enumerate(members):
            edges, n = prepped[i]
            stack[row, : edges.shape[0]] = edges
            tn[row], te[row] = n, edges.shape[0]
        out.append(GraphBatch(edges=stack, num_nodes=v_pad,
                              true_nodes=tn, true_edges=te,
                              indices=np.asarray(members, np.int32)))
    return out


def solve_batched(
    graphs: Sequence, *,
    num_segments: int | None = None,
    lift_steps: int = 2,
) -> list[CCResult]:
    """Adaptive CC over a batch of graphs, one device program per shape
    bucket (engine entry for the facade's ``batched`` backend; callers
    should go through ``repro.api.Solver.solve_batch``).

    Args:
      graphs: sequence of ``repro.graphs.format.Graph`` objects or
        ``(edges [E,2], num_nodes)`` pairs; sizes may be mixed freely.
      num_segments: override the bucket's 2|E_pad|/|V_pad| heuristic.
      lift_steps: bounded root-chase depth (as in the single-graph API).

    Returns:
      One ``CCResult`` per input graph, in input order, labels truncated
      to the graph's true |V| — bit-identical to per-graph
      ``connected_components``. DeviceGraph inputs stay device-resident
      end to end (device labels out); host inputs get host labels.
    """
    from repro.graphs.device import DeviceGraph
    graphs = list(graphs)
    device_in = bool(graphs) and all(
        isinstance(g, DeviceGraph) for g in graphs)
    if device_in:
        batches = stack_device_graphs(graphs)
    else:
        pairs = [(g.edges, g.num_nodes) if hasattr(g, "num_nodes") else g
                 for g in graphs]
        batches = bucketize(pairs)
    results: list[CCResult | None] = [None] * len(graphs)
    for batch in batches:
        res = _cc_batched_jit(
            jnp.asarray(batch.edges),
            jax.device_put(np.asarray(batch.true_edges)),
            jax.device_put(np.asarray(batch.true_nodes)),
            num_nodes=batch.num_nodes,
            num_segments=num_segments,
            lift_steps=lift_steps)
        if device_in:
            # stay on device: per-row static slices, no transfers
            for row, i in enumerate(batch.indices):
                n = int(batch.true_nodes[row])
                results[int(i)] = CCResult(
                    labels=res.labels[row, :n],
                    work=WorkCounters(*(c[row] for c in res.work)))
            continue
        # host views, no per-graph device transfers: [B, V_pad] -> B rows
        labels = np.asarray(res.labels)
        work = jax.tree.map(np.asarray, res.work)
        for row, i in enumerate(batch.indices):
            n = int(batch.true_nodes[row])
            results[int(i)] = CCResult(
                labels=labels[row, :n],
                work=WorkCounters(*(c[row] for c in work)))
    return results  # type: ignore[return-value]


def connected_components_batched(
    graphs: Sequence, *,
    num_segments: int | None = None,
    lift_steps: int = 2,
) -> list[CCResult]:
    """DEPRECATED legacy entrypoint — forwards through the facade's
    ``batched`` backend, bit-identical results."""
    from repro._deprecation import warn_once
    from repro.api import Solver
    warn_once("repro.core.batch.connected_components_batched",
              "repro.api.Solver.solve_batch")
    return Solver.solve_batch(graphs, num_segments=num_segments,
                              lift_steps=lift_steps)
