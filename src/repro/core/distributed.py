"""Multi-device Connected Components via ``shard_map``.

Spatial reinterpretation of the paper's segmentation (DESIGN.md §5):

  * edges are sharded over the mesh's data-parallel axes — each chip owns
    an edge partition (a "segment" in the paper's vocabulary);
  * the parent array π (the |V| workspace) is replicated;
  * each round every chip hooks its own segment (scatter-min, bounded
    lift), the per-chip π copies are merged with an elementwise
    ``pmin`` all-reduce — valid because scatter-min updates are monotone
    decreasing, so the elementwise min of per-chip results equals the
    result of hooking the union of the segments — then every chip runs the
    identical fused Multi-Jump compress;
  * convergence (all local edges consistent) is combined with a global
    ``pmin`` so the device-side while loop terminates simultaneously
    everywhere. The entire multi-round program is ONE jit call: zero
    host round-trips, the paper's device-centric property preserved
    across a pod.

Scale posture: replicated π costs |V|·4 bytes per chip (4 GB at |V|=1e9);
beyond that the design shards π over 'model' and turns the pmin into a
reduce-scatter + all-gather pair. That variant is sketched in
EXPERIMENTS.md §Perf; the replicated form is what ships here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import rounds as rounds_lib
from repro.core.rounds import WorkCounters, compress, edges_consistent
from repro.core.segmentation import plan_segmentation

# Global merge rounds to convergence measured on all four Table I graph
# classes: 2-4 (EXPERIMENTS.md §Perf). Fuel 8 is a 2x safety margin; the
# roofline's static loop bound (and the worst case) tightens 8x vs the
# original 64 fuel.
_MAX_ROUNDS = 8


def build_distributed_cc(graph, mesh: Mesh,
                         axis_names: tuple[str, ...] = ("data",),
                         lift_steps: int = 2,
                         local_segments: int | None = None):
    """Build a jitted distributed-CC callable for a sharded DeviceGraph
    (engine entry for the facade's ``distributed`` backend; callers
    should go through ``repro.api.Solver.open(graph, mesh=mesh)``).

    Args:
      graph: a ``DeviceGraph`` already sharded over ``mesh`` via
        ``DeviceGraph.shard(mesh, axis_names)`` — its (padded) edge
        array divides evenly into per-chip partitions. The callable is
        specialized to this graph's static shape/plan; run it on the
        graph itself or any same-shape sharded DeviceGraph.
      mesh: device mesh; edges are sharded over ``axis_names`` (flattened).
      axis_names: mesh axes the edge list is sharded over.
      local_segments: per-chip segmentation (None = paper heuristic on the
        per-chip subproblem).

    Returns:
      fn(graph: DeviceGraph) -> labels [V] (replicated).
    """
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    num_nodes = graph.num_nodes
    total = int(graph.edges.shape[0])
    if total % n_shards:
        raise ValueError(
            f"edge count {total} does not divide into {n_shards} shards; "
            "shard the graph with DeviceGraph.shard(mesh, axis_names)")
    edges_per_shard = total // n_shards
    segs = local_segments or plan_segmentation(
        edges_per_shard, num_nodes).num_segments
    segs = max(1, min(segs, edges_per_shard))
    # per-chip plan; the paper's segment scan over the local partition is
    # the shared rounds core (padding with (0,0) no-ops — the old local
    # scan silently truncated the remainder when edges_per_shard wasn't
    # divisible by the segment count).
    plan = plan_segmentation(edges_per_shard, num_nodes, segs)
    ops = rounds_lib.jnp_round_ops(lift_steps)

    def shard_fn(edges_local):
        # edges_local: [1 per sharded axis..., edges_per_shard, 2]
        edges_local = edges_local.reshape(edges_per_shard, 2)
        segments = rounds_lib.pad_and_segment(edges_local, plan)
        pi0 = jnp.arange(num_nodes, dtype=jnp.int32)

        def cond(state):
            _, done, rounds = state
            return jnp.logical_and(~done, rounds < _MAX_ROUNDS)

        def body(state):
            pi, _, rounds = state
            pi, _ = rounds_lib.segment_scan(pi, segments, ops,
                                            WorkCounters.zeros())
            # merge the monotone per-chip workspaces
            for ax in axis_names:
                pi = jax.lax.pmin(pi, ax)
            pi, _ = compress(pi, WorkCounters.zeros())
            local_ok = edges_consistent(pi, edges_local)
            ok = jnp.asarray(local_ok, jnp.int32)
            for ax in axis_names:
                ok = jax.lax.pmin(ok, ax)
            return pi, ok.astype(bool), rounds + 1

        pi, _, _ = jax.lax.while_loop(
            cond, body, (pi0, jnp.asarray(False), jnp.zeros((), jnp.int32)))
        return pi[None]  # leading axis collapses to the replicated out-spec

    in_spec = P(axis_names if len(axis_names) > 1 else axis_names[0], None)
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(in_spec,),
                   out_specs=P(axis_names if len(axis_names) > 1
                               else axis_names[0], None),
                   check_rep=False)

    def run(edges_sharded):
        out = fn(edges_sharded)          # [n_shards, V] identical rows
        return out[0]

    jitted = jax.jit(run)

    def call(g):
        from repro.graphs.device import as_device_graph
        return jitted(as_device_graph(g, num_nodes).edges)

    # the raw edges-level entry point ([n_shards*edges_per_shard, 2] ->
    # labels), for AOT lowering over ShapeDtypeStructs (launch.dryrun)
    call.on_edges = jitted
    return call


class DistributedRunnerCache:
    """Per-shape cache of ``build_distributed_cc`` callables.

    ``build_distributed_cc`` specializes to one (padded-rows, |V|)
    shape and is reusable on any same-shape sharded DeviceGraph — a
    property the fleet's sharded-tenant path leans on hard: a tenant's
    tombstone log re-solves after every mutated tick over a view whose
    pow2 capacity changes only on growth, so the builder (shard_map
    construction + jit entry) amortizes to one per shape bucket instead
    of one per tick. Host-side dict only; hit/miss counters ride in
    ``stats`` for the fleet benchmark."""

    def __init__(self, mesh: Mesh, axis_names=("data",),
                 lift_steps: int = 2):
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.lift_steps = lift_steps
        self._runners: dict = {}
        self.stats = {"hits": 0, "misses": 0}

    def runner(self, graph):
        """The cached callable for this graph's (rows, |V|) bucket —
        the graph must already be sharded over the cache's mesh."""
        key = (int(graph.edges.shape[0]), graph.num_nodes)
        fn = self._runners.get(key)
        if fn is None:
            self.stats["misses"] += 1
            fn = self._runners[key] = build_distributed_cc(
                graph, self.mesh, axis_names=self.axis_names,
                lift_steps=self.lift_steps)
        else:
            self.stats["hits"] += 1
        return fn

    def run(self, graph):
        """labels [V] (replicated) for a sharded DeviceGraph."""
        return self.runner(graph)(graph)

    def solve(self, graph):
        """Shard an unsharded DeviceGraph over the mesh, then run."""
        return self.run(graph.shard(self.mesh, self.axis_names))


def solve_distributed(graph, mesh: Mesh, axis_names=("data",),
                      lift_steps: int = 2):
    """Shard a graph (host ``Graph``, raw arrays, or an unsharded
    ``DeviceGraph``) over ``mesh`` and run (engine entry for the
    facade's ``distributed`` backend)."""
    from repro.graphs.device import as_device_graph
    dg = as_device_graph(graph).shard(mesh, axis_names)
    fn = build_distributed_cc(dg, mesh, axis_names=axis_names,
                              lift_steps=lift_steps)
    return fn(dg)


def make_distributed_cc(graph, mesh: Mesh,
                        axis_names: tuple[str, ...] = ("data",),
                        lift_steps: int = 2,
                        local_segments: int | None = None):
    """DEPRECATED legacy entrypoint — forwards to the engine builder
    the facade's ``distributed`` backend uses."""
    from repro._deprecation import warn_once
    warn_once("repro.core.distributed.make_distributed_cc",
              "repro.api.Solver.open(graph, mesh=mesh)")
    return build_distributed_cc(graph, mesh, axis_names=axis_names,
                                lift_steps=lift_steps,
                                local_segments=local_segments)


def distributed_connected_components(graph, mesh: Mesh,
                                     axis_names=("data",),
                                     lift_steps: int = 2):
    """DEPRECATED legacy entrypoint — forwards through the facade's
    ``distributed`` backend, bit-identical results."""
    from repro._deprecation import warn_once
    from repro.api import Solver
    warn_once("repro.core.distributed.distributed_connected_components",
              "repro.api.Solver.open(graph, mesh=mesh).solve()")
    res = Solver.open(graph, mesh=mesh, axis_names=axis_names,
                      lift_steps=lift_steps).solve()
    return res.labels
