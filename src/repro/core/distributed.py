"""Multi-device Connected Components via ``shard_map``.

Spatial reinterpretation of the paper's segmentation (DESIGN.md §5):

  * edges are sharded over the mesh's data-parallel axes — each chip owns
    an edge partition (a "segment" in the paper's vocabulary);
  * the parent array π (the |V| workspace) is replicated;
  * each round every chip hooks its own segment (scatter-min, bounded
    lift), the per-chip π copies are merged with an elementwise
    ``pmin`` all-reduce — valid because scatter-min updates are monotone
    decreasing, so the elementwise min of per-chip results equals the
    result of hooking the union of the segments — then every chip runs the
    identical fused Multi-Jump compress;
  * convergence (all local edges consistent) is combined with a global
    ``pmin`` so the device-side while loop terminates simultaneously
    everywhere. The entire multi-round program is ONE jit call: zero
    host round-trips, the paper's device-centric property preserved
    across a pod.

Scale posture: replicated π costs |V|·4 bytes per chip (4 GB at |V|=1e9);
beyond that the design shards π over 'model' and turns the pmin into a
reduce-scatter + all-gather pair. That variant is sketched in
EXPERIMENTS.md §Perf; the replicated form is what ships here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import rounds as rounds_lib
from repro.core.rounds import WorkCounters, compress, edges_consistent
from repro.core.segmentation import plan_segmentation

# Global merge rounds to convergence measured on all four Table I graph
# classes: 2-4 (EXPERIMENTS.md §Perf). Fuel 8 is a 2x safety margin; the
# roofline's static loop bound (and the worst case) tightens 8x vs the
# original 64 fuel.
_MAX_ROUNDS = 8


def make_distributed_cc(mesh: Mesh, num_nodes: int, edges_per_shard: int,
                        axis_names: tuple[str, ...] = ("data",),
                        lift_steps: int = 2,
                        local_segments: int | None = None):
    """Build a jitted distributed-CC callable for a fixed mesh/shape.

    Args:
      mesh: device mesh; edges are sharded over ``axis_names`` (flattened).
      num_nodes: |V| (static).
      edges_per_shard: per-chip edge count (static; pad with (0,0)).
      axis_names: mesh axes the edge list is sharded over.
      local_segments: per-chip segmentation (None = paper heuristic on the
        per-chip subproblem).

    Returns:
      fn(edges_sharded [n_shards*edges_per_shard, 2]) -> labels [V].
    """
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    segs = local_segments or plan_segmentation(
        edges_per_shard, num_nodes).num_segments
    segs = max(1, min(segs, edges_per_shard))
    # per-chip plan; the paper's segment scan over the local partition is
    # the shared rounds core (padding with (0,0) no-ops — the old local
    # scan silently truncated the remainder when edges_per_shard wasn't
    # divisible by the segment count).
    plan = plan_segmentation(edges_per_shard, num_nodes, segs)
    ops = rounds_lib.jnp_round_ops(lift_steps)

    def shard_fn(edges_local):
        # edges_local: [1 per sharded axis..., edges_per_shard, 2]
        edges_local = edges_local.reshape(edges_per_shard, 2)
        segments = rounds_lib.pad_and_segment(edges_local, plan)
        pi0 = jnp.arange(num_nodes, dtype=jnp.int32)

        def cond(state):
            _, done, rounds = state
            return jnp.logical_and(~done, rounds < _MAX_ROUNDS)

        def body(state):
            pi, _, rounds = state
            pi, _ = rounds_lib.segment_scan(pi, segments, ops,
                                            WorkCounters.zeros())
            # merge the monotone per-chip workspaces
            for ax in axis_names:
                pi = jax.lax.pmin(pi, ax)
            pi, _ = compress(pi, WorkCounters.zeros())
            local_ok = edges_consistent(pi, edges_local)
            ok = jnp.asarray(local_ok, jnp.int32)
            for ax in axis_names:
                ok = jax.lax.pmin(ok, ax)
            return pi, ok.astype(bool), rounds + 1

        pi, _, _ = jax.lax.while_loop(
            cond, body, (pi0, jnp.asarray(False), jnp.zeros((), jnp.int32)))
        return pi[None]  # leading axis collapses to the replicated out-spec

    in_spec = P(axis_names if len(axis_names) > 1 else axis_names[0], None)
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(in_spec,),
                   out_specs=P(axis_names if len(axis_names) > 1
                               else axis_names[0], None),
                   check_rep=False)

    def run(edges_sharded):
        edges_sharded = jnp.asarray(edges_sharded, jnp.int32).reshape(
            n_shards * edges_per_shard, 2)
        out = fn(edges_sharded)          # [n_shards, V] identical rows
        return out[0]

    return jax.jit(run)


def distributed_connected_components(graph, mesh: Mesh,
                                     axis_names=("data",),
                                     lift_steps: int = 2):
    """Convenience wrapper: partition a host Graph and run on ``mesh``."""
    from repro.graphs.partition import partition_edges
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    parts = partition_edges(graph, n_shards)          # [S, E/S, 2]
    fn = make_distributed_cc(mesh, graph.num_nodes, parts.shape[1],
                             axis_names=axis_names, lift_steps=lift_steps)
    return fn(parts.reshape(-1, 2))
