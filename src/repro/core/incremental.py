"""Incremental Connected Components: absorb edge insertions without a
full recompute (DESIGN.md §6; Hong et al., arXiv 2008.11839).

``IncrementalCC`` keeps the canonical label array as persistent state.
An insertion batch is absorbed by running the shared cleanup loop
(``rounds.cleanup_rounds``) over ONLY the new edges: hooking a new edge
(u, v) merges the two existing stars by their min roots, the fused
Multi-Jump compress re-flattens, and the loop repeats until every new
edge is consistent. Because the state is always at the canonical min-id
fixed point, the result after any insertion sequence is bit-identical to
a from-scratch run over the accumulated edge set — the tests assert
this against the union-find oracle after every batch.

Cost model (the paper's currency): a from-scratch recompute hooks all
|E_total| edges every time, the incremental absorb hooks only the
|ΔE| new edges — and a batch that lands entirely inside existing
components short-circuits at the initial consistency check, costing
ZERO hook rounds. The work counters accumulate across batches so the
saving is measurable (``benchmarks/run.py --only incremental``).

Batches are padded to power-of-two lengths with (0, 0) no-op edges so a
stream of variably-sized batches hits a handful of jit entries; padding
is never billed (true counts thread through the shared core).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.core.rounds import WorkCounters

_MIN_BATCH_PAD = 64


@functools.partial(jax.jit, static_argnames=("lift_steps",))
def _absorb_jit(pi, new_edges, true_count, *, lift_steps):
    ops = rounds.jnp_round_ops(lift_steps)
    new_pi, work = rounds.cleanup_rounds(pi, new_edges, ops,
                                         WorkCounters.zeros(),
                                         true_edges=true_count)
    # merge detection rides in the same jit: the label-version counter
    # (query-cache invalidation) must tick ONLY when labels changed
    return new_pi, work, jnp.any(new_pi != pi)


@jax.jit
def _labels_changed(old_pi, new_pi):
    return jnp.any(new_pi != old_pi)


class IncrementalCC:
    """Connectivity state under streaming edge insertions.

    >>> inc = IncrementalCC(num_nodes=6)
    >>> inc.insert([[0, 1], [2, 3]])
    >>> inc.connected(0, 1)
    True
    >>> inc.insert([[1, 2]])          # merges {0,1} and {2,3}
    >>> int(inc.labels[3])
    0
    """

    def __init__(self, num_nodes: int, *, lift_steps: int = 2):
        if num_nodes < 0:
            raise ValueError(f"num_nodes must be >= 0, got {num_nodes}")
        self.num_nodes = num_nodes
        self.lift_steps = lift_steps
        self._pi = jnp.arange(num_nodes, dtype=jnp.int32)
        self.num_edges_inserted = 0
        self.batches_absorbed = 0
        # label version: ticks ONLY when an insert actually merges
        # components (labels changed) — the registry invalidates cached
        # query results on version change and nothing else
        self.version = 0
        # accumulated work, host-side ints (billed on true edges only)
        self.work = {k: 0 for k in WorkCounters._fields}

    @property
    def labels(self) -> jnp.ndarray:
        """Canonical min-id labels, [num_nodes] int32."""
        return self._pi

    def insert(self, new_edges) -> jnp.ndarray:
        """Absorb a batch of edge insertions; returns the new labels.

        Self loops, duplicates, and already-connected edges are
        harmless (the latter cost zero hook rounds).
        """
        new_edges = np.asarray(new_edges, np.int32).reshape(-1, 2)
        if (new_edges.size and
                (new_edges.min() < 0 or new_edges.max() >= self.num_nodes)):
            raise ValueError("edge endpoint out of range "
                             f"[0, {self.num_nodes})")
        e = new_edges.shape[0]
        self.num_edges_inserted += e
        self.batches_absorbed += 1
        if e == 0 or self.num_nodes == 0:
            return self._pi
        # pad to a power-of-two bucket: few jit entries for a stream of
        # ragged batches ((0,0) self-loop no-ops, never billed)
        target = max(_MIN_BATCH_PAD,
                     1 << int(e - 1).bit_length())
        padded = np.zeros((target, 2), np.int32)
        padded[:e] = new_edges
        self._pi, work, changed = _absorb_jit(
            self._pi, jnp.asarray(padded),
            jnp.asarray(e, jnp.int32), lift_steps=self.lift_steps)
        for k, v in work._asdict().items():
            self.work[k] += int(v)
        self.work["sync_rounds"] += 1   # one jit call per absorb
        if bool(changed):
            self.version += 1
        return self._pi

    def adopt(self, labels, work=None, num_edges: int = 0) -> jnp.ndarray:
        """Adopt externally computed canonical labels as the new state
        (the registry's bulk-load path: the policy routed a large batch
        through a static engine instead of the absorb). Bills ``work``
        (a ``WorkCounters`` or field dict) into the accumulated
        counters and ticks the version iff the labels changed.
        """
        labels = jnp.asarray(labels, jnp.int32)
        if labels.shape != (self.num_nodes,):
            raise ValueError(f"labels shape {labels.shape} != "
                             f"({self.num_nodes},)")
        changed = bool(_labels_changed(self._pi, labels)) \
            if self.num_nodes else False
        self._pi = labels
        self.num_edges_inserted += int(num_edges)
        self.batches_absorbed += 1
        if work is not None:
            if isinstance(work, WorkCounters):
                work = work._asdict()
            for k, v in work.items():
                self.work[k] += int(v)
        if changed:
            self.version += 1
        return self._pi

    def connected(self, u: int, v: int) -> bool:
        for x in (u, v):
            if not 0 <= x < self.num_nodes:
                raise ValueError(f"vertex {x} out of range "
                                 f"[0, {self.num_nodes})")
        return int(self._pi[u]) == int(self._pi[v])

    def num_components(self) -> int:
        """Component count — on-device sort/segment kernel, no host
        ``np.unique`` round trip (``connectivity.queries``)."""
        from repro.connectivity.queries import count_components
        return int(count_components(self._pi))
