"""Incremental Connected Components: absorb edge insertions without a
full recompute (DESIGN.md §6; Hong et al., arXiv 2008.11839).

``IncrementalCC`` keeps the canonical label array as persistent state.
An insertion batch is absorbed by running the shared cleanup loop
(``rounds.cleanup_rounds``) over ONLY the new edges: hooking a new edge
(u, v) merges the two existing stars by their min roots, the fused
Multi-Jump compress re-flattens, and the loop repeats until every new
edge is consistent. Because the state is always at the canonical min-id
fixed point, the result after any insertion sequence is bit-identical to
a from-scratch run over the accumulated edge set — the tests assert
this against the union-find oracle after every batch.

Cost model (the paper's currency): a from-scratch recompute hooks all
|E_total| edges every time, the incremental absorb hooks only the
|ΔE| new edges — and a batch that lands entirely inside existing
components short-circuits at the initial consistency check, costing
ZERO hook rounds. The work counters accumulate across batches so the
saving is measurable (``benchmarks/run.py --only incremental``).

State residency (DESIGN.md §8): labels AND the label version live on
device and are threaded through the absorb jit — the steady-state
insert path performs ZERO host synchronizations (no ``bool(changed)``,
no per-field ``int(...)``). The version ticks inside the same device
program that detects a merge. Per-batch work counters come back as
int32 device scalars and queue unsynced; they fold into host
arbitrary-precision ints lazily (at ``work`` access, or every
``_DRAIN_EVERY`` batches as an amortized sync point), so accumulated
totals never wrap int32 over a long-lived instance.

Insert batches arrive as host arrays (validated + padded on host) or as
``DeviceGraph``s (``insert_graph`` — the service's coalesced path:
device-side concat + jitted pow2 padding, transfer-free under
``jax.transfer_guard("disallow")``). Padding is (0, 0) no-op edges and
is never billed (true counts thread through the shared core).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.core.rounds import WorkCounters

_MIN_BATCH_PAD = 64
_DRAIN_EVERY = 256   # fold pending per-batch work into host ints


@functools.partial(jax.jit, static_argnames=("lift_steps",))
def _absorb_jit(pi, new_edges, true_count, version, *, lift_steps):
    """One absorb: cleanup loop over the new edges + merge detection +
    version tick, all in ONE device program. Returns the PER-BATCH
    work counters (int32 — safe for a single batch; the caller
    accumulates across batches in host arbitrary-precision ints,
    lazily, so no int32 wraparound over a long-lived instance)."""
    ops = rounds.jnp_round_ops(lift_steps)
    new_pi, work = rounds.cleanup_rounds(pi, new_edges, ops,
                                         WorkCounters.zeros(),
                                         true_edges=true_count)
    work = work.add(sync_rounds=1)      # one jit call per absorb
    # the label-version counter (query-cache invalidation) must tick
    # ONLY when labels changed — detected on device, no host round trip
    version = version + jnp.any(new_pi != pi).astype(version.dtype)
    return new_pi, version, work


@jax.jit
def _adopt_jit(pi, labels, version):
    changed = jnp.any(labels != pi)
    return labels, version + changed.astype(version.dtype)


class IncrementalCC:
    """Connectivity state under streaming edge insertions.

    >>> inc = IncrementalCC(num_nodes=6)
    >>> inc.insert([[0, 1], [2, 3]])
    >>> inc.connected(0, 1)
    True
    >>> inc.insert([[1, 2]])          # merges {0,1} and {2,3}
    >>> int(inc.labels[3])
    0
    """

    def __init__(self, num_nodes: int, *, lift_steps: int = 2):
        if num_nodes < 0:
            raise ValueError(f"num_nodes must be >= 0, got {num_nodes}")
        self.num_nodes = num_nodes
        self.lift_steps = lift_steps
        self._pi = jnp.arange(num_nodes, dtype=jnp.int32)
        self.num_edges_inserted = 0
        self.batches_absorbed = 0
        # device-resident: the version ticks inside the absorb jit
        self._version = jnp.zeros((), jnp.int32)
        # work accounting: each absorb emits per-batch int32 device
        # counters (billed on true edges only); they queue here unsynced
        # and fold into host arbitrary-precision ints lazily — at
        # inspection (``work``) or every _DRAIN_EVERY batches — so the
        # steady-state insert path stays transfer-free AND the
        # accumulated totals never wrap int32
        self._work_host = {k: 0 for k in WorkCounters._fields}
        self._work_pending: list[WorkCounters] = []

    @property
    def labels(self) -> jnp.ndarray:
        """Canonical min-id labels, [num_nodes] int32."""
        return self._pi

    @property
    def version(self) -> int:
        """Label version as a host int (syncs; see ``version_device``)."""
        return int(self._version)

    @property
    def version_device(self) -> jnp.ndarray:
        """Label version as a device int32 scalar (no sync)."""
        return self._version

    def _drain_work(self) -> None:
        # explicit device_get, not int(): the amortized drain can fire
        # inside a jax.transfer_guard("disallow") region (every
        # _DRAIN_EVERY-th absorb), where implicit conversions raise but
        # explicit transfers are allowed
        for w in jax.device_get(self._work_pending):
            for k, v in w._asdict().items():
                self._work_host[k] += int(v)
        self._work_pending.clear()

    def _queue_work(self, work: WorkCounters | dict | None) -> None:
        if work is None:
            return
        if isinstance(work, WorkCounters):
            self._work_pending.append(work)
        else:
            for k, v in work.items():
                self._work_host[k] += int(v)
        if len(self._work_pending) >= _DRAIN_EVERY:
            self._drain_work()           # rare amortized sync point

    @property
    def work(self) -> dict:
        """Accumulated work counters as host ints (syncs on access)."""
        self._drain_work()
        return dict(self._work_host)

    def insert(self, new_edges) -> jnp.ndarray:
        """Absorb a host-array batch of edge insertions; returns the new
        labels. Self loops, duplicates, and already-connected edges are
        harmless (the latter cost zero hook rounds)."""
        new_edges = np.asarray(new_edges, np.int32).reshape(-1, 2)
        if (new_edges.size and
                (new_edges.min() < 0 or new_edges.max() >= self.num_nodes)):
            raise ValueError("edge endpoint out of range "
                             f"[0, {self.num_nodes})")
        e = new_edges.shape[0]
        self.num_edges_inserted += e
        self.batches_absorbed += 1
        if e == 0 or self.num_nodes == 0:
            return self._pi
        # pad to a power-of-two bucket: few jit entries for a stream of
        # ragged batches ((0,0) self-loop no-ops, never billed)
        target = max(_MIN_BATCH_PAD, 1 << int(e - 1).bit_length())
        padded = np.zeros((target, 2), np.int32)
        padded[:e] = new_edges
        self._pi, self._version, batch_work = _absorb_jit(
            self._pi, jax.device_put(padded),
            jax.device_put(np.int32(e)), self._version,
            lift_steps=self.lift_steps)
        self._queue_work(batch_work)
        return self._pi

    def insert_graph(self, delta) -> jnp.ndarray:
        """Absorb a device-resident ``DeviceGraph`` insert batch — the
        registry/service steady-state path. Coalescing (``concat``) and
        pow2 padding happen on device; the absorb jit threads labels,
        version, and work counters without a single host transfer
        (validated under ``jax.transfer_guard("disallow")``). Bounds are
        NOT re-checked on this path (device values; the API boundary
        validates host inputs)."""
        if delta.num_nodes != self.num_nodes:
            raise ValueError(f"delta num_nodes {delta.num_nodes} != "
                             f"{self.num_nodes}")
        self.num_edges_inserted += delta.num_edges
        self.batches_absorbed += 1
        if self.num_nodes == 0 or delta.edges.shape[0] == 0:
            return self._pi
        padded = delta.pad_pow2(min_rows=_MIN_BATCH_PAD)
        self._pi, self._version, batch_work = _absorb_jit(
            self._pi, padded.edges, padded.true_edges_device(),
            self._version, lift_steps=self.lift_steps)
        self._queue_work(batch_work)
        return self._pi

    def adopt(self, labels, work=None, num_edges: int = 0) -> jnp.ndarray:
        """Adopt externally computed canonical labels as the new state
        (the registry's bulk-load path: the policy routed a large batch
        through a static engine instead of the absorb). Bills ``work``
        (a ``WorkCounters`` or field dict) into the accumulated
        counters and ticks the version iff the labels changed — the
        merge detection runs on device.
        """
        labels = jnp.asarray(labels, jnp.int32)
        if labels.shape != (self.num_nodes,):
            raise ValueError(f"labels shape {labels.shape} != "
                             f"({self.num_nodes},)")
        self.num_edges_inserted += int(num_edges)
        self.batches_absorbed += 1
        self._queue_work(work)
        if self.num_nodes == 0:
            return self._pi
        self._pi, self._version = _adopt_jit(self._pi, labels,
                                             self._version)
        return self._pi

    def connected(self, u: int, v: int) -> bool:
        for x in (u, v):
            if not 0 <= x < self.num_nodes:
                raise ValueError(f"vertex {x} out of range "
                                 f"[0, {self.num_nodes})")
        return int(self._pi[u]) == int(self._pi[v])

    def num_components(self) -> int:
        """Component count — on-device sort/segment kernel, no host
        ``np.unique`` round trip (``connectivity.queries``)."""
        from repro.connectivity.queries import count_components
        return int(count_components(self._pi))
