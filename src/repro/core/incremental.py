"""Incremental Connected Components: absorb edge insertions without a
full recompute (DESIGN.md §6; Hong et al., arXiv 2008.11839) — and,
via ``DynamicCC`` (DESIGN.md §9), edge DELETIONS through a
device-resident tombstone log with scoped recompute and split-aware
version ticks.

``IncrementalCC`` keeps the canonical label array as persistent state.
An insertion batch is absorbed by running the shared cleanup loop
(``rounds.cleanup_rounds``) over ONLY the new edges: hooking a new edge
(u, v) merges the two existing stars by their min roots, the fused
Multi-Jump compress re-flattens, and the loop repeats until every new
edge is consistent. Because the state is always at the canonical min-id
fixed point, the result after any insertion sequence is bit-identical to
a from-scratch run over the accumulated edge set — the tests assert
this against the union-find oracle after every batch.

Cost model (the paper's currency): a from-scratch recompute hooks all
|E_total| edges every time, the incremental absorb hooks only the
|ΔE| new edges — and a batch that lands entirely inside existing
components short-circuits at the initial consistency check, costing
ZERO hook rounds. The work counters accumulate across batches so the
saving is measurable (``benchmarks/run.py --only incremental``).

State residency (DESIGN.md §8): labels AND the label version live on
device and are threaded through the absorb jit — the steady-state
insert path performs ZERO host synchronizations (no ``bool(changed)``,
no per-field ``int(...)``). The version ticks inside the same device
program that detects a merge. Per-batch work counters come back as
int32 device scalars and queue unsynced; they fold into host
arbitrary-precision ints lazily (at ``work`` access, or every
``_DRAIN_EVERY`` batches as an amortized sync point), so accumulated
totals never wrap int32 over a long-lived instance.

Insert batches arrive as host arrays (validated + padded on host) or as
``DeviceGraph``s (``insert_graph`` — the service's coalesced path:
device-side concat + jitted pow2 padding, transfer-free under
``jax.transfer_guard("disallow")``). Padding is (0, 0) no-op edges and
is never billed (true counts thread through the shared core).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.core.rounds import WorkCounters

_MIN_BATCH_PAD = 64
_DRAIN_EVERY = 256   # fold pending per-batch work into host ints


@functools.partial(jax.jit, static_argnames=("lift_steps",))
def _absorb_jit(pi, new_edges, true_count, version, *, lift_steps):
    """One absorb: cleanup loop over the new edges + merge detection +
    version tick, all in ONE device program. Returns the PER-BATCH
    work counters (int32 — safe for a single batch; the caller
    accumulates across batches in host arbitrary-precision ints,
    lazily, so no int32 wraparound over a long-lived instance)."""
    ops = rounds.jnp_round_ops(lift_steps)
    new_pi, work = rounds.cleanup_rounds(pi, new_edges, ops,
                                         WorkCounters.zeros(),
                                         true_edges=true_count)
    work = work.add(sync_rounds=1)      # one jit call per absorb
    # the label-version counter (query-cache invalidation) must tick
    # ONLY when labels changed — detected on device, no host round trip
    version = version + jnp.any(new_pi != pi).astype(version.dtype)
    return new_pi, version, work


@jax.jit
def _adopt_jit(pi, labels, version):
    changed = jnp.any(labels != pi)
    return labels, version + changed.astype(version.dtype)


class IncrementalCC:
    """Connectivity state under streaming edge insertions.

    >>> inc = IncrementalCC(num_nodes=6)
    >>> inc.insert([[0, 1], [2, 3]])
    >>> inc.connected(0, 1)
    True
    >>> inc.insert([[1, 2]])          # merges {0,1} and {2,3}
    >>> int(inc.labels[3])
    0
    """

    def __init__(self, num_nodes: int, *, lift_steps: int = 2):
        if num_nodes < 0:
            raise ValueError(f"num_nodes must be >= 0, got {num_nodes}")
        self.num_nodes = num_nodes
        self.lift_steps = lift_steps
        self._pi = jnp.arange(num_nodes, dtype=jnp.int32)
        self.num_edges_inserted = 0
        self.batches_absorbed = 0
        # device-resident: the version ticks inside the absorb jit
        self._version = jnp.zeros((), jnp.int32)
        # work accounting: each absorb emits per-batch int32 device
        # counters (billed on true edges only); they queue here unsynced
        # and fold into host arbitrary-precision ints lazily — at
        # inspection (``work``) or every _DRAIN_EVERY batches — so the
        # steady-state insert path stays transfer-free AND the
        # accumulated totals never wrap int32
        self._work_host = {k: 0 for k in WorkCounters._fields}
        self._work_pending: list[WorkCounters] = []
        # optional on-device telemetry (repro.obs Metrics pytree):
        # None (default) costs one attribute check per mutation;
        # attached, it is updated by a device program per batch —
        # still transfer-free — and flushed only on explicit request
        self.metrics = None

    def enable_metrics(self) -> None:
        """Attach zeroed ``repro.obs`` Metrics accumulators (no-op if
        already attached)."""
        if self.metrics is None:
            from repro.obs.metrics import Metrics
            self.metrics = Metrics.zeros()

    def _record_metrics(self, kind: str, batch_work, true_count,
                        version_before) -> None:
        """Fold one mutation batch into the attached accumulators —
        every operand is already a device scalar, so the update is one
        more staged program on the tick (no transfer)."""
        if self.metrics is None:
            return
        from repro.obs import metrics as obs_metrics
        self.metrics = obs_metrics.record_mutation(
            self.metrics, batch_work, true_count, version_before,
            self._version, kind=kind)

    @property
    def labels(self) -> jnp.ndarray:
        """Canonical min-id labels, [num_nodes] int32."""
        return self._pi

    @property
    def version(self) -> int:
        """Label version as a host int (syncs; see ``version_device``)."""
        return int(self._version)

    @property
    def version_device(self) -> jnp.ndarray:
        """Label version as a device int32 scalar (no sync)."""
        return self._version

    def _drain_work(self) -> None:
        # explicit device_get, not int(): the amortized drain can fire
        # inside a jax.transfer_guard("disallow") region (every
        # _DRAIN_EVERY-th absorb), where implicit conversions raise but
        # explicit transfers are allowed
        for w in jax.device_get(self._work_pending):
            for k, v in w._asdict().items():
                self._work_host[k] += int(v)
        self._work_pending.clear()

    def _queue_work(self, work: WorkCounters | dict | None) -> None:
        if work is None:
            return
        if isinstance(work, WorkCounters):
            self._work_pending.append(work)
        else:
            for k, v in work.items():
                self._work_host[k] += int(v)
        if len(self._work_pending) >= _DRAIN_EVERY:
            self._drain_work()           # rare amortized sync point

    @property
    def work(self) -> dict:
        """Accumulated work counters as host ints (syncs on access)."""
        self._drain_work()
        return dict(self._work_host)

    def insert(self, new_edges) -> jnp.ndarray:
        """Absorb a host-array batch of edge insertions; returns the new
        labels. Self loops, duplicates, and already-connected edges are
        harmless (the latter cost zero hook rounds)."""
        new_edges = np.asarray(new_edges, np.int32).reshape(-1, 2)
        if (new_edges.size and
                (new_edges.min() < 0 or new_edges.max() >= self.num_nodes)):
            raise ValueError("edge endpoint out of range "
                             f"[0, {self.num_nodes})")
        e = new_edges.shape[0]
        self.num_edges_inserted += e
        self.batches_absorbed += 1
        if e == 0 or self.num_nodes == 0:
            return self._pi
        # pad to a power-of-two bucket: few jit entries for a stream of
        # ragged batches ((0,0) self-loop no-ops, never billed)
        target = max(_MIN_BATCH_PAD, 1 << int(e - 1).bit_length())
        padded = np.zeros((target, 2), np.int32)
        padded[:e] = new_edges
        v0, true_count = self._version, jax.device_put(np.int32(e))
        self._pi, self._version, batch_work = _absorb_jit(
            self._pi, jax.device_put(padded), true_count, self._version,
            lift_steps=self.lift_steps)
        self._queue_work(batch_work)
        self._record_metrics("insert", batch_work, true_count, v0)
        return self._pi

    def insert_graph(self, delta) -> jnp.ndarray:
        """Absorb a device-resident ``DeviceGraph`` insert batch — the
        registry/service steady-state path. Coalescing (``concat``) and
        pow2 padding happen on device; the absorb jit threads labels,
        version, and work counters without a single host transfer
        (validated under ``jax.transfer_guard("disallow")``). Bounds are
        NOT re-checked on this path (device values; the API boundary
        validates host inputs)."""
        if delta.num_nodes != self.num_nodes:
            raise ValueError(f"delta num_nodes {delta.num_nodes} != "
                             f"{self.num_nodes}")
        self.num_edges_inserted += delta.num_edges
        self.batches_absorbed += 1
        if self.num_nodes == 0 or delta.edges.shape[0] == 0:
            return self._pi
        padded = delta.pad_pow2(min_rows=_MIN_BATCH_PAD)
        v0, true_count = self._version, padded.true_edges_device()
        self._pi, self._version, batch_work = _absorb_jit(
            self._pi, padded.edges, true_count,
            self._version, lift_steps=self.lift_steps)
        self._queue_work(batch_work)
        self._record_metrics("insert", batch_work, true_count, v0)
        return self._pi

    def adopt(self, labels, work=None, num_edges: int = 0) -> jnp.ndarray:
        """Adopt externally computed canonical labels as the new state
        (the registry's bulk-load path: the policy routed a large batch
        through a static engine instead of the absorb). Bills ``work``
        (a ``WorkCounters`` or field dict) into the accumulated
        counters and ticks the version iff the labels changed — the
        merge detection runs on device.
        """
        labels = jnp.asarray(labels, jnp.int32)
        if labels.shape != (self.num_nodes,):
            raise ValueError(f"labels shape {labels.shape} != "
                             f"({self.num_nodes},)")
        self.num_edges_inserted += int(num_edges)
        self.batches_absorbed += 1
        self._queue_work(work)
        if self.num_nodes == 0:
            return self._pi
        self._pi, self._version = _adopt_jit(self._pi, labels,
                                             self._version)
        if self.metrics is not None:
            # rebuild work is billed through the engine's own
            # WorkCounters; the accumulator counts the route
            from repro.obs import metrics as obs_metrics
            self.metrics = obs_metrics.record_rebuild(self.metrics)
        return self._pi

    def connected(self, u: int, v: int) -> bool:
        for x in (u, v):
            if not 0 <= x < self.num_nodes:
                raise ValueError(f"vertex {x} out of range "
                                 f"[0, {self.num_nodes})")
        return int(self._pi[u]) == int(self._pi[v])

    def num_components(self) -> int:
        """Component count — on-device sort/segment kernel, no host
        ``np.unique`` round trip (``connectivity.queries``)."""
        from repro.connectivity.queries import count_components
        return int(count_components(self._pi))


# ---------------------------------------------------------------------------
# Fully-dynamic connectivity: + edge deletions (DESIGN.md §9)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("lift_steps", "num_segments",
                                             "scan_method", "interpret"))
def _delete_jit(edges, alive, pi, dels, d_true, version, deleted, *,
                lift_steps, num_segments, scan_method, interpret):
    """One delete tick, ONE device program: tombstone the delete batch
    against the log, detect the affected components, and — only if the
    batch actually retired an edge — run the scoped recompute over
    their surviving edges (``rounds.scoped_rounds``). The version ticks
    iff labels changed, which under a pure-delete batch means an
    ACTUAL SPLIT: a non-bridge deletion reproduces the same canonical
    partition bit-for-bit, so cached query answers stay warm. This is
    the deletion-side mirror of the absorb jit's merge tick — no host
    round trip anywhere on the path."""
    from repro.graphs.device import tombstone_mask
    from repro.core.segmentation import plan_segmentation

    num_nodes = pi.shape[0]
    alive2, killed = tombstone_mask(edges, alive, dels, d_true)
    deleted = deleted + jnp.sum(killed).astype(deleted.dtype)
    plan = plan_segmentation(edges.shape[0], num_nodes, num_segments)

    def recompute(_):
        # components touched by a retired edge: both endpoints of an
        # alive edge share a label, so marking pi[u] covers pi[v]
        aff = jnp.zeros((num_nodes,), jnp.bool_) \
            .at[pi[edges[:, 0]]].max(killed)
        in_aff = aff[pi]                       # vertex in affected comp?
        edge_aff = alive2 & in_aff[edges[:, 0]]
        n_aff_nodes = jnp.sum(in_aff).astype(jnp.int32)
        if scan_method == "pallas_fused":
            ops = rounds.fused_round_ops(lift_steps, interpret=interpret,
                                         bill_nodes=n_aff_nodes)
        else:
            ops = rounds.jnp_round_ops(lift_steps,
                                       bill_nodes=n_aff_nodes)
        return rounds.scoped_rounds(pi, edges, edge_aff, in_aff, plan,
                                    ops, WorkCounters.zeros())

    def no_op(_):
        # nothing retired (unknown edges / double deletes): zero hook
        # rounds, zero sweeps — the delete-side analogue of the
        # absorb's already-connected short circuit
        return pi, WorkCounters.zeros()

    pi1, work = jax.lax.cond(jnp.any(killed), recompute, no_op, None)
    work = work.add(sync_rounds=1)             # one jit call per tick
    version = version + jnp.any(pi1 != pi).astype(version.dtype)
    return pi1, alive2, version, deleted, work


# ---------------------------------------------------------------------------
# Maintained spanning forest (DESIGN.md §14): forest-threading jits
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("lift_steps",))
def _absorb_forest_jit(pi, parents, parent_eidx, new_edges, eid_base,
                       true_count, version, *, lift_steps):
    """``_absorb_jit`` + forest extension: the batch's true rows were
    just appended to the EdgeLog at offset ``eid_base`` (a TRACED
    device scalar — a static offset would recompile once per append
    cursor value), so batch slot i is log row ``eid_base + i``. A
    winning hook records its edge AND that log row (same scatter-min
    win rule as the forest round variants). Labels and version are
    bit-identical to ``_absorb_jit`` — recording never changes a pi
    update."""
    p = new_edges.shape[0]
    slot = jnp.arange(p, dtype=jnp.int32)
    eids = jnp.where(slot < true_count, eid_base + slot, -1)
    new_pi, parents, parent_eidx, work = rounds.forest_cleanup_rounds_ids(
        pi, parents, parent_eidx, new_edges, eids, WorkCounters.zeros(),
        true_edges=true_count, lift_steps=lift_steps)
    work = work.add(sync_rounds=1)
    version = version + jnp.any(new_pi != pi).astype(version.dtype)
    return new_pi, parents, parent_eidx, version, work


@functools.partial(jax.jit, static_argnames=("lift_steps",))
def _delete_forest_jit(edges, alive, pi, parents, parent_eidx, dels,
                       d_true, version, deleted, routes, *, lift_steps):
    """The tree-aware delete tick, ONE device program (DESIGN.md §14):

    1. tombstone the batch (orientation-blind multiset matching via
       ``undirected_group_ids`` inside ``tombstone_mask``);
    2. classify tree vs. non-tree hits with one O(V) gather — vertex r
       lost its tree edge iff its recorded log row just died
       (``killed[parent_eidx[r]]``; deleting {u, v} kills EVERY alive
       copy, including the recorded one, so the gather is exact);
    3. ``lax.cond``: zero tree hits → labels, forest, and version are
       UNTOUCHED (the common case bills zero hook rounds and zero
       sweeps); otherwise ``rounds.forest_scoped_rounds`` reconnects
       only the components that lost a tree edge via the surviving
       forest skeleton + crossing replacement edges (unlifted hooks —
       ``lift_steps`` only keeps this tick's static signature parallel
       to the absorb jit's).

    ``routes`` is an int32 [2] device counter
    (nontree_shortcircuit, tree_scoped) — how a batch classified is
    only known on device and the steady-state tick must not sync to
    find out; hosts drain it lazily into the obs counters."""
    from repro.graphs.device import tombstone_mask

    num_nodes = pi.shape[0]
    alive2, killed = tombstone_mask(edges, alive, dels, d_true)
    deleted = deleted + jnp.sum(killed).astype(deleted.dtype)
    has_parent = parent_eidx >= 0
    safe = jnp.maximum(parent_eidx, 0)
    tree_hit = has_parent & killed[safe]
    any_hit = jnp.any(tree_hit)

    def tree_scoped(_):
        aff = jnp.zeros((num_nodes,), jnp.bool_).at[pi].max(tree_hit)
        in_aff = aff[pi]                   # vertex in an affected comp?
        edge_aff = alive2 & in_aff[edges[:, 0]]
        forest_keep = in_aff & has_parent & ~killed[safe]
        eids = jnp.arange(edges.shape[0], dtype=jnp.int32)
        return rounds.forest_scoped_rounds(
            pi, parents, parent_eidx, edges, eids, edge_aff,
            forest_keep, in_aff, WorkCounters.zeros())

    def no_op(_):
        return pi, parents, parent_eidx, WorkCounters.zeros()

    pi1, parents1, eidx1, work = jax.lax.cond(any_hit, tree_scoped,
                                              no_op, None)
    work = work.add(sync_rounds=1)
    version = version + jnp.any(pi1 != pi).astype(version.dtype)
    routes = routes + jnp.stack([(~any_hit).astype(jnp.int32),
                                 any_hit.astype(jnp.int32)])
    return pi1, alive2, parents1, eidx1, version, deleted, routes, work


@functools.partial(jax.jit, static_argnames=("num_nodes", "lift_steps",
                                             "num_segments"))
def _rebuild_forest_jit(edges, alive, *, num_nodes, lift_steps,
                        num_segments):
    """From-scratch forest (re)derivation over the surviving log — the
    lazy fallback when a bulk route (static rebuild, tombstone-only
    delete) left the maintained forest stale. Runs the Fig. 4 pipeline
    with id-recording hooks; the resulting labels are canonical and
    therefore bit-identical to the live state's, so assigning them is
    safe and the version must NOT tick."""
    from repro.core.segmentation import plan_segmentation

    e = edges.shape[0]
    ids = jnp.arange(e, dtype=jnp.int32)
    packed, pids, true = rounds.pack_edge_rows(edges, ids, alive)
    plan = plan_segmentation(e, num_nodes, num_segments)
    segments = rounds.pad_and_segment(packed, plan)
    pad = plan.padded_edges - e
    seg_ids = pids if pad <= 0 else jnp.concatenate(
        [pids, jnp.full((pad,), -1, jnp.int32)])
    seg_ids = seg_ids.reshape(plan.num_segments, plan.segment_size)
    counts = rounds.segment_true_counts(true, plan)
    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, parents, eidx, work = rounds.forest_segment_scan_ids(
        pi0, rounds.empty_forest(num_nodes),
        rounds.empty_forest_idx(num_nodes), segments, seg_ids,
        WorkCounters.zeros(), counts, lift_steps=lift_steps)
    pi, parents, eidx, work = rounds.forest_cleanup_rounds_ids(
        pi, parents, eidx, packed, pids, work, true_edges=true,
        lift_steps=lift_steps)
    work = work.add(sync_rounds=1)
    return pi, parents, eidx, work


@jax.jit
def _remap_eidx_jit(parent_eidx, perm):
    """Remap forest log-row pointers through a compaction permutation
    (roots and retired rows stay -1)."""
    safe = jnp.maximum(parent_eidx, 0)
    return jnp.where(parent_eidx >= 0, perm[safe], -1)


class DynamicCC(IncrementalCC):
    """Fully-dynamic connectivity: streaming edge insertions AND
    deletions over one device-resident state (DESIGN.md §9; Hong,
    Dhulipala & Shun, arXiv 2008.11839 motivate why insert-only
    structures break under churn).

    On top of ``IncrementalCC`` this keeps the accumulated edge set in
    a ``graphs.device.EdgeLog`` (tombstone mask + pow2 capacity
    buckets). Inserts append to the log and absorb as before; a delete
    batch tombstones matching log rows and falls back to a *scoped
    recompute* — re-running the Fig. 4 scan over only the components a
    retired edge touched — instead of a full recompute. A deletion
    that is not a bridge reproduces the identical canonical partition,
    so the label version (query-cache invalidation) ticks only on
    ACTUAL splits, exactly mirroring the insert path's merge tick.

    Deletion semantics: a delete of undirected edge {u, v} is
    orientation-blind and retires EVERY alive copy in the (multiset)
    log; deleting an absent edge is a zero-cost no-op. After any
    interleaved insert/delete script the labels are bit-identical to a
    from-scratch run over the surviving edge set (oracle-tested).

    ``scan_method`` picks the scoped-recompute backend: ``"jnp"``
    (default) or ``"pallas_fused"`` (one kernel launch per scoped
    scan) — the policy layer routes this via the delete-rate feature
    (``connectivity.policy.select_for``).

    >>> dyn = DynamicCC(num_nodes=4)
    >>> dyn.insert([[0, 1], [1, 2]])
    >>> dyn.delete([[1, 2]])
    >>> dyn.connected(0, 1), dyn.connected(1, 2)
    (True, False)
    """

    def __init__(self, num_nodes: int, *, lift_steps: int = 2,
                 scan_method: str = "jnp"):
        super().__init__(num_nodes, lift_steps=lift_steps)
        from repro.graphs.device import EdgeLog
        if scan_method not in ("jnp", "pallas_fused"):
            raise ValueError(f"unknown scan_method {scan_method!r}; "
                             "choose from ('jnp', 'pallas_fused')")
        self.scan_method = scan_method
        self.log = EdgeLog(num_nodes)
        self.delete_batches = 0
        # device-resident retired-edge count: how many log rows a
        # delete batch matched is only known on device, and the
        # steady-state delete tick must not sync to find out
        self._deleted = jnp.zeros((), jnp.int32)
        # maintained spanning forest (DESIGN.md §14): parent edges +
        # the EdgeLog row each was recorded from, extended in-jit by
        # the forest absorb and consumed by the tree-aware delete.
        # ``_forest_valid`` is a HOST flag: bulk routes (adopt,
        # tombstone-only deletes, the plain scoped delete) mutate
        # labels or the log without maintaining the forest, and the
        # next forest-routed delete rebuilds it lazily.
        self._parents = rounds.empty_forest(num_nodes)
        self._parent_eidx = rounds.empty_forest_idx(num_nodes)
        self._forest_valid = True
        # delete-route telemetry: device [nontree_shortcircuit,
        # tree_scoped] counter (ticked inside the delete jit) + host
        # rebuild count; drained into obs by delete_route_counts()
        self._delete_routes = jnp.zeros((2,), jnp.int32)
        self.forest_rebuilds = 0
        self._routes_flushed = {"nontree_shortcircuit": 0,
                                "tree_scoped": 0, "rebuild": 0}

    # -- inserts (log-keeping overrides) -----------------------------------

    def insert(self, new_edges) -> jnp.ndarray:
        """Absorb a host-array insert batch (validated, device_put,
        logged)."""
        from repro.graphs.device import DeviceGraph, validate_edge_bounds
        arr = np.asarray(new_edges, np.int32).reshape(-1, 2)
        validate_edge_bounds(arr, self.num_nodes)
        return self.insert_graph(
            DeviceGraph.from_edges(arr, self.num_nodes))

    def insert_graph(self, delta) -> jnp.ndarray:
        """Absorb a DeviceGraph insert batch; the delta's true rows are
        appended to the device edge log first (static true count
        required — same contract as ``DeviceGraph.concat``). While the
        maintained forest is valid the absorb runs the forest-extending
        jit (labels/version bit-identical to the plain absorb; a
        winning hook also records its log row), so inserts never stale
        the forest."""
        rows_before = self.log.rows
        self.log.append(delta)          # validates |V| + static count
        if not self._forest_valid:
            return super().insert_graph(delta)
        self.num_edges_inserted += delta.num_edges
        self.batches_absorbed += 1
        if self.num_nodes == 0 or delta.edges.shape[0] == 0:
            return self._pi
        padded = delta.pad_pow2(min_rows=_MIN_BATCH_PAD)
        v0, true_count = self._version, padded.true_edges_device()
        (self._pi, self._parents, self._parent_eidx, self._version,
         batch_work) = _absorb_forest_jit(
            self._pi, self._parents, self._parent_eidx, padded.edges,
            jax.device_put(np.int32(rows_before)), true_count,
            self._version, lift_steps=self.lift_steps)
        self._queue_work(batch_work)
        self._record_metrics("insert", batch_work, true_count, v0)
        return self._pi

    def stage(self, delta) -> None:
        """Append a delta to the log WITHOUT absorbing — the registry's
        bulk-rebuild route, where a static engine recomputes over the
        whole log view and ``adopt``s the result (which does the
        version/work accounting)."""
        self.log.append(delta)

    def adopt(self, labels, work=None, num_edges: int = 0) -> jnp.ndarray:
        """``IncrementalCC.adopt`` + forest invalidation: a static
        engine recomputed labels without recording parent edges, so the
        maintained forest is stale until the next forest-routed delete
        rebuilds it."""
        self._forest_valid = False
        return super().adopt(labels, work=work, num_edges=num_edges)

    # -- deletes ------------------------------------------------------------

    def delete(self, edges) -> jnp.ndarray:
        """Delete a host-array edge batch; returns the new labels."""
        from repro.graphs.device import DeviceGraph, validate_edge_bounds
        arr = np.asarray(edges, np.int32).reshape(-1, 2)
        validate_edge_bounds(arr, self.num_nodes)
        return self.delete_graph(
            DeviceGraph.from_edges(arr, self.num_nodes))

    def delete_graph(self, dels) -> jnp.ndarray:
        """Delete a device-resident ``DeviceGraph`` batch — the
        registry/service steady-state path. Tombstoning, bridge
        detection (did the partition change?), the scoped recompute,
        and the split-version tick all run in ONE device program with
        zero host transfers (validated under
        ``jax.transfer_guard("disallow")``)."""
        if dels.num_nodes != self.num_nodes:
            raise ValueError(f"dels num_nodes {dels.num_nodes} != "
                             f"{self.num_nodes}")
        self.delete_batches += 1
        if self.num_nodes == 0 or dels.edges.shape[0] == 0 \
                or self.log.rows == 0:
            return self._pi
        from repro.core.segmentation import adaptive_num_segments
        from repro.kernels import default_interpret
        padded = dels.pad_pow2(min_rows=_MIN_BATCH_PAD)
        v0, true_count = self._version, padded.true_edges_device()
        (self._pi, self.log.alive, self._version, self._deleted,
         batch_work) = _delete_jit(
            self.log.edges, self.log.alive, self._pi, padded.edges,
            true_count, self._version, self._deleted,
            lift_steps=self.lift_steps,
            num_segments=adaptive_num_segments(self.log.capacity,
                                               self.num_nodes),
            scan_method=self.scan_method,
            interpret=default_interpret())
        # the plain scoped recompute does not maintain parent edges —
        # whether it even ran (anything killed?) is device knowledge,
        # so conservatively stale the forest
        self._forest_valid = False
        self._queue_work(batch_work)
        self._record_metrics("delete", batch_work, true_count, v0)
        return self._pi

    def ensure_forest(self) -> None:
        """(Re)derive the maintained forest from the surviving log if a
        bulk route staled it. The rebuild's labels are canonical and
        bit-identical to the live state's, so assigning them is safe;
        the version does not tick. Counts into
        ``dynamic.deletes.rebuild``."""
        if self._forest_valid:
            return
        from repro.core.segmentation import adaptive_num_segments
        from repro.obs import trace as obs
        (self._pi, self._parents, self._parent_eidx,
         work) = _rebuild_forest_jit(
            self.log.edges, self.log.alive, num_nodes=self.num_nodes,
            lift_steps=self.lift_steps,
            num_segments=adaptive_num_segments(self.log.capacity,
                                               self.num_nodes))
        self._queue_work(work)
        self._forest_valid = True
        self.forest_rebuilds += 1
        obs.count("dynamic.deletes.rebuild")

    def delete_graph_forest(self, dels) -> jnp.ndarray:
        """Tree-aware delete (DESIGN.md §14): one device program
        tombstones the batch, classifies tree vs. non-tree hits against
        the maintained forest, short-circuits the all-non-tree case
        (labels, forest, and version untouched — ~zero hook_ops), and
        otherwise reconnects only the components that lost a tree edge
        via the surviving forest skeleton + crossing replacement
        edges. Transfer-free on the steady-state path (the lazy
        ``ensure_forest`` fallback is the only exception, and only
        after a bulk route)."""
        if dels.num_nodes != self.num_nodes:
            raise ValueError(f"dels num_nodes {dels.num_nodes} != "
                             f"{self.num_nodes}")
        self.delete_batches += 1
        if self.num_nodes == 0 or dels.edges.shape[0] == 0 \
                or self.log.rows == 0:
            return self._pi
        self.ensure_forest()
        padded = dels.pad_pow2(min_rows=_MIN_BATCH_PAD)
        v0, true_count = self._version, padded.true_edges_device()
        (self._pi, self.log.alive, self._parents, self._parent_eidx,
         self._version, self._deleted, self._delete_routes,
         batch_work) = _delete_forest_jit(
            self.log.edges, self.log.alive, self._pi, self._parents,
            self._parent_eidx, padded.edges, true_count, self._version,
            self._deleted, self._delete_routes,
            lift_steps=self.lift_steps)
        self._queue_work(batch_work)
        self._record_metrics("delete", batch_work, true_count, v0)
        return self._pi

    def tombstone_graph(self, dels) -> None:
        """Tombstone a delete batch WITHOUT the scoped recompute — the
        bulk-delete route, where the policy decided a full static
        rebuild over the remaining log beats scoping (the caller
        rebuilds and ``adopt``s; adopt's device-side diff supplies the
        split tick)."""
        if dels.num_nodes != self.num_nodes:
            raise ValueError(f"dels num_nodes {dels.num_nodes} != "
                             f"{self.num_nodes}")
        self.delete_batches += 1
        if self.num_nodes == 0 or dels.edges.shape[0] == 0 \
                or self.log.rows == 0:
            return
        padded = dels.pad_pow2(min_rows=_MIN_BATCH_PAD)
        killed = self.log.delete(padded.edges,
                                 padded.true_edges_device())
        self._deleted = self._deleted + \
            jnp.sum(killed).astype(self._deleted.dtype)
        # rows died without forest maintenance (the caller rebuilds
        # labels via a static engine + adopt, which also invalidates)
        self._forest_valid = False

    def compact(self) -> None:
        """Compact the EdgeLog in place and remap the maintained
        forest's ``parent_eidx`` through the compaction permutation —
        the two must move together or every forest pointer silently
        refers to the wrong post-compaction row (the seeded bug in
        ``analysis/fixtures.py``). One host sync (the log cursor);
        maintenance operation, not a tick."""
        perm = self.log.compact()
        if self._forest_valid:
            self._parent_eidx = _remap_eidx_jit(self._parent_eidx, perm)

    # -- views / introspection ----------------------------------------------

    @property
    def forest(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(parents [V, 2], parent_eidx [V]) — the maintained spanning
        forest (device arrays; -1 rows are component roots). Check
        ``forest_valid`` (or call ``ensure_forest()``) first if a bulk
        route may have staled it."""
        return self._parents, self._parent_eidx

    @property
    def forest_valid(self) -> bool:
        return self._forest_valid

    def delete_route_counts(self, flush_obs: bool = True) -> dict:
        """Drain the delete-route telemetry: syncs the device
        [nontree_shortcircuit, tree_scoped] counter (introspection
        point — never on the steady-state tick) and, unless told
        otherwise, folds the deltas into the host obs counters
        ``dynamic.deletes.{nontree_shortcircuit,tree_scoped,rebuild}``."""
        vals = np.asarray(jax.device_get(self._delete_routes))
        counts = {"nontree_shortcircuit": int(vals[0]),
                  "tree_scoped": int(vals[1]),
                  "rebuild": self.forest_rebuilds}
        if flush_obs:
            from repro.obs import trace as obs
            for k in ("nontree_shortcircuit", "tree_scoped"):
                delta = counts[k] - self._routes_flushed[k]
                if delta:
                    obs.count(f"dynamic.deletes.{k}", delta)
                self._routes_flushed[k] = counts[k]
        return counts

    def graph(self):
        """The surviving edge set as a compacted DeviceGraph (traced
        true count) — what the bulk-rebuild path feeds to the static
        engines."""
        return self.log.view()

    @property
    def num_edges_deleted(self) -> int:
        """Retired-edge count as a host int (syncs; introspection)."""
        return int(self._deleted)

    @property
    def num_edges_alive(self) -> int:
        """Surviving-edge count (syncs; introspection)."""
        return self.log.num_alive
