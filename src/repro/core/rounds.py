"""Shared adaptive hook+compress round machinery (DESIGN.md §3).

This module is the single home of the paper's round primitives —
deterministic Hook (scatter-min with bounded root chase), fused
Multi-Jump Compress (pointer doubling in one ``lax.while_loop``), the
work counters, and the segment-scan / cleanup-loop composition of Fig. 4
— so that every execution mode consumes ONE implementation:

  * ``repro.core.cc``          — single-graph variants + public API,
  * ``repro.core.cc`` (Pallas) — same composition, kernel-backed ops,
  * ``repro.core.batch``       — ``vmap``ped over shape-bucketed batches,
  * ``repro.core.incremental`` — edge-insertion batches hooked into an
                                 existing label array (Hong et al.),
  * ``repro.core.distributed`` — per-chip segment scan under shard_map.

Everything here is pure jnp + lax control flow: safe under ``vmap``
(batched CC), ``shard_map`` (distributed CC), and jit caching.

Work accounting (the paper's currency is work-efficiency) bills *true*
edge counts: padded ``(0, 0)`` no-op edges — introduced by segmentation,
shape bucketing, or edge-tile alignment — are never counted. Callers
pass the true edge count (static int or traced scalar; the latter is
what the batched path uses, one count per graph in the bucket).
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.segmentation import SegmentationPlan

MAX_ROUNDS = 64          # outer hook-round fuel


def compress_fuel(num_nodes: int) -> int:
    """Pointer doubling squares path lengths per sweep, so
    ceil(log2(V)) + 2 sweeps provably flatten any forest on V nodes —
    a 2-3x tighter static loop bound than a fixed 64 (the roofline's
    memory term for CC scales with this fuel)."""
    return max(4, math.ceil(math.log2(max(num_nodes, 2))) + 2)


class WorkCounters(NamedTuple):
    """Hardware-independent work counters (DESIGN.md §2).

    * ``hook_ops``    — edge-hook evaluations performed (true edges only),
    * ``jump_ops``    — vertex-jump (gather) evaluations performed,
    * ``jump_sweeps`` — full |V|-wide pointer-jump sweeps,
    * ``hook_rounds`` — edge-set hook rounds,
    * ``sync_rounds`` — host-equivalent synchronization points.
    """

    hook_ops: jnp.ndarray
    jump_ops: jnp.ndarray
    jump_sweeps: jnp.ndarray
    hook_rounds: jnp.ndarray
    sync_rounds: jnp.ndarray

    @staticmethod
    def zeros() -> "WorkCounters":
        z = jnp.zeros((), jnp.int32)
        return WorkCounters(z, z, z, z, z)

    def add(self, **kw) -> "WorkCounters":
        d = self._asdict()
        for k, v in kw.items():
            d[k] = d[k] + jnp.asarray(v, jnp.int32)
        return WorkCounters(**d)


# ---------------------------------------------------------------------------
# Primitive operations
# ---------------------------------------------------------------------------

def hook_edges(pi: jnp.ndarray, edges: jnp.ndarray, lift_steps: int = 0
               ) -> jnp.ndarray:
    """One deterministic hook round over ``edges`` (TPU analogue of Hook /
    Atomic-Hook, DESIGN.md §2).

    For every edge (u, v): H = max(pi(u), pi(v)), L = min(...), then
    ``pi[H] <- min(pi[H], L)`` via scatter-min (race-free winner selection —
    the deterministic stand-in for the CAS consensus; identical fixed point
    under the paper's high-to-low rule). ``lift_steps`` performs the bounded
    vectorized root chase of Atomic-Hook (pu <- pi[pu]) before hooking.
    """
    u, v = edges[..., 0], edges[..., 1]
    pu, pv = pi[u], pi[v]
    for _ in range(lift_steps):
        pu, pv = pi[pu], pi[pv]
    hi = jnp.maximum(pu, pv)
    lo = jnp.minimum(pu, pv)
    return pi.at[hi].min(lo)


def jump_once(pi: jnp.ndarray) -> jnp.ndarray:
    """Single-level Jump (Fig. 2): pi <- pi[pi] for every vertex."""
    return pi[pi]


def compress(pi: jnp.ndarray, work: WorkCounters,
             count_syncs: bool = False,
             bill_nodes: int | jnp.ndarray | None = None,
             ) -> tuple[jnp.ndarray, WorkCounters]:
    """Full Compress via fused pointer doubling (the Multi-Jump kernel).

    Runs pi <- pi[pi] sweeps on-device until every tree is a star. Each
    sweep *squares* path lengths (pointer doubling), the same
    work-efficiency lever as the paper's in-kernel chase + continuous
    write-back. With ``count_syncs`` every sweep also bills one host
    synchronization (used by the Soman baseline whose Jump loop re-checks
    convergence from the host after every single-level kernel).
    ``bill_nodes`` overrides the per-sweep jump_ops billing (the batched
    path passes the true |V| so padded self-root vertices are free).
    """
    v = pi.shape[0] if bill_nodes is None else bill_nodes
    fuel = compress_fuel(pi.shape[0])

    def cond(state):
        _, changed, sweeps, _ = state
        return jnp.logical_and(changed, sweeps < fuel)

    def body(state):
        p, _, sweeps, w = state
        nxt = p[p]
        changed = jnp.any(nxt != p)
        w = w.add(jump_ops=v, jump_sweeps=1,
                  sync_rounds=1 if count_syncs else 0)
        return nxt, changed, sweeps + 1, w

    pi, _, _, work = jax.lax.while_loop(
        cond, body, (pi, jnp.asarray(True), jnp.zeros((), jnp.int32), work))
    return pi, work


def edges_consistent(pi: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """True iff every edge has both endpoints under the same label."""
    return jnp.all(pi[edges[..., 0]] == pi[edges[..., 1]])


# ---------------------------------------------------------------------------
# Pluggable round operations
# ---------------------------------------------------------------------------

class RoundOps(NamedTuple):
    """The pluggable kernels of a hook+compress round.

    * ``hook(pi, edges) -> pi``        — one hook pass over an edge set,
    * ``compress(pi, work) -> (pi, work)`` — full compress, threading work,
    * ``bill_lift``                    — hook evaluations billed per true
                                         edge (1 + lift_steps for the
                                         root-chasing Atomic-Hook),
    * ``scan``                         — optional FUSED segment scan:
      ``scan(pi, segments, true_counts, work) -> (pi, work)`` runs the
      whole Fig. 4 inner pipeline (every hook round + every compress
      sweep) in ONE kernel launch, billing internally. When set,
      ``segment_scan`` delegates to it and ``cleanup_rounds`` issues one
      launch per cleanup round instead of ``1 + jump_sweeps``.
    """

    hook: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    compress: Callable[[jnp.ndarray, WorkCounters],
                       tuple[jnp.ndarray, WorkCounters]]
    bill_lift: int
    scan: Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray, WorkCounters],
                   tuple[jnp.ndarray, WorkCounters]] | None = None


def jnp_round_ops(lift_steps: int = 2,
                  bill_nodes: int | jnp.ndarray | None = None) -> RoundOps:
    """Pure-jnp ops (the default backend)."""
    return RoundOps(
        hook=lambda pi, e: hook_edges(pi, e, lift_steps=lift_steps),
        compress=lambda pi, w: compress(pi, w, bill_nodes=bill_nodes),
        bill_lift=1 + lift_steps,
    )


def pallas_round_ops(lift_steps: int, edge_tile: int, node_tile: int,
                     interpret: bool) -> RoundOps:
    """Pallas-kernel-backed ops (hook + multi_jump kernels, DESIGN.md §2).
    The kernels do not thread work counters; compress passes them through.
    """
    from repro.kernels.hook.ops import hook_edges_pallas
    from repro.kernels.multi_jump.ops import full_compress
    return RoundOps(
        hook=lambda pi, e: hook_edges_pallas(
            pi, e, edge_tile=edge_tile, lift_steps=lift_steps,
            interpret=interpret),
        compress=lambda pi, w: (full_compress(
            pi, tile=node_tile, interpret=interpret), w),
        bill_lift=1 + lift_steps,
    )


def fused_round_ops(lift_steps: int = 2, *,
                    interpret: bool | None = None,
                    bill_nodes: int | jnp.ndarray | None = None
                    ) -> RoundOps:
    """Fused-kernel ops (``kernels.cc_fused``): the whole segment scan —
    hook rounds with bounded root chase plus multi-jump compress — in
    ONE ``pallas_call`` per scan. Billing is bit-compatible with the
    jnp backend: hook_ops on scalar-prefetched TRUE per-segment counts,
    jump_sweeps from the kernel's exact per-segment sweep counters.
    ``hook``/``compress`` fall back to the jnp primitives (used only by
    callers that bypass the fused scan)."""
    from repro.kernels.cc_fused.ops import fused_segment_scan
    bill = 1 + lift_steps

    def scan(pi, segments, true_counts, work):
        v = pi.shape[0] if bill_nodes is None else bill_nodes
        pi, sweeps = fused_segment_scan(pi, segments, true_counts,
                                        lift_steps=lift_steps,
                                        interpret=interpret)
        total = jnp.sum(sweeps)
        return pi, work.add(
            hook_ops=jnp.sum(true_counts) * bill,
            hook_rounds=segments.shape[0],
            jump_ops=total * v, jump_sweeps=total)

    return RoundOps(
        hook=lambda pi, e: hook_edges(pi, e, lift_steps=lift_steps),
        compress=lambda pi, w: compress(pi, w, bill_nodes=bill_nodes),
        bill_lift=bill,
        scan=scan,
    )


# ---------------------------------------------------------------------------
# Forest-recording hook (spanning forest as a by-product of hook rounds)
# ---------------------------------------------------------------------------
# Every hook round runs over a fully-compressed pi (all compositions in
# this module compress to fixpoint between hooks), so a scatter-min
# write at position ``hi`` is a STRICT decrease of a root's own label:
# pi[hi] == hi before the write, pi[hi] = lo < hi after, and hi never
# reappears as a label. Each position is therefore recorded at most
# once over the whole run, each recorded edge merges two components
# that were distinct at record time, and the recorded set is exactly a
# spanning forest of the input: V - C edges, one unrecorded root (the
# component minimum) per component. These are SEPARATE compositions
# from the plain ones above so the non-forest paths stay bit-identical.


def empty_forest(num_nodes: int) -> jnp.ndarray:
    """int32 [V, 2] parent-edge table, all (-1, -1): row r will hold
    the original graph edge whose hook retired root r (see
    ``hook_edges_forest``); rows still (-1, -1) at the end are the
    per-component roots."""
    return jnp.full((num_nodes, 2), -1, jnp.int32)


def hook_edges_forest(pi: jnp.ndarray, parents: jnp.ndarray,
                      edges: jnp.ndarray, lift_steps: int = 0
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``hook_edges`` + spanning-forest recording (same pi updates).

    An edge wins position ``hi`` iff its scatter-min write actually
    landed (``new_pi[hi] == lo``) AND strictly lowered the root's label
    (``new_pi[hi] < pi[hi]`` — rules out self loops, duplicates, and
    already-merged endpoints). Ties between same-(hi, lo) edges are
    broken by a second scatter-min over edge indices, so exactly one
    original edge is recorded per retired root.
    """
    n = pi.shape[0]
    u, v = edges[..., 0], edges[..., 1]
    pu, pv = pi[u], pi[v]
    for _ in range(lift_steps):
        pu, pv = pi[pu], pi[pv]
    hi = jnp.maximum(pu, pv)
    lo = jnp.minimum(pu, pv)
    new_pi = pi.at[hi].min(lo)
    won = jnp.logical_and(new_pi[hi] == lo, new_pi[hi] < pi[hi])
    eidx = jnp.arange(edges.shape[0], dtype=jnp.int32)
    sentinel = jnp.full((n,), jnp.iinfo(jnp.int32).max, jnp.int32)
    winner = sentinel.at[jnp.where(won, hi, n)].min(eidx, mode="drop")
    rec = jnp.logical_and(won, winner[hi] == eidx)
    parents = parents.at[jnp.where(rec, hi, n)].set(
        jnp.stack([u, v], axis=-1), mode="drop")
    return new_pi, parents


def forest_segment_scan(pi: jnp.ndarray, parents: jnp.ndarray,
                        segments: jnp.ndarray, work: WorkCounters,
                        true_counts: jnp.ndarray,
                        lift_steps: int = 2,
                        ) -> tuple[jnp.ndarray, jnp.ndarray, WorkCounters]:
    """``segment_scan`` with the parent-edge table threaded through the
    ``lax.scan`` carry (jnp ops only; billing matches ``jnp_round_ops``)."""
    bill = 1 + lift_steps

    def seg_body(carry, xs):
        p, f, w = carry
        seg, cnt = xs
        p, f = hook_edges_forest(p, f, seg, lift_steps=lift_steps)
        w = w.add(hook_ops=cnt * bill, hook_rounds=1)
        p, w = compress(p, w)
        return (p, f, w), None

    (pi, parents, work), _ = jax.lax.scan(
        seg_body, (pi, parents, work), (segments, true_counts))
    return pi, parents, work


def forest_cleanup_rounds(pi: jnp.ndarray, parents: jnp.ndarray,
                          edges: jnp.ndarray, work: WorkCounters,
                          true_edges: int | jnp.ndarray | None = None,
                          lift_steps: int = 2,
                          max_rounds: int = MAX_ROUNDS,
                          ) -> tuple[jnp.ndarray, jnp.ndarray, WorkCounters]:
    """``cleanup_rounds`` with forest recording (same short-circuit on
    already-consistent edge sets, same true-edge billing)."""
    if true_edges is None:
        true_edges = edges.shape[0]
    bill = jnp.asarray(true_edges, jnp.int32) * (1 + lift_steps)

    def cond(state):
        _, _, done, rounds_, _ = state
        return jnp.logical_and(~done, rounds_ < max_rounds)

    def body(state):
        p, f, _, rounds_, w = state
        p, f = hook_edges_forest(p, f, edges, lift_steps=lift_steps)
        w = w.add(hook_ops=bill, hook_rounds=1)
        p, w = compress(p, w)
        return p, f, edges_consistent(p, edges), rounds_ + 1, w

    done0 = edges_consistent(pi, edges)
    pi, parents, _, _, work = jax.lax.while_loop(
        cond, body,
        (pi, parents, done0, jnp.zeros((), jnp.int32), work))
    return pi, parents, work


def forest_adaptive_rounds(edges: jnp.ndarray, num_nodes: int,
                           plan: SegmentationPlan, *,
                           lift_steps: int = 2,
                           true_edges: int | jnp.ndarray | None = None,
                           max_rounds: int = MAX_ROUNDS,
                           ) -> tuple[jnp.ndarray, jnp.ndarray,
                                      WorkCounters]:
    """The Fig. 4 pipeline (segment scan + cleanup) with the spanning
    forest recorded along the way. Labels and counters match
    ``adaptive_rounds`` bit for bit (asserted in tests)."""
    if true_edges is None:
        true_edges = plan.num_edges
    segments = pad_and_segment(edges, plan)
    counts = segment_true_counts(true_edges, plan)
    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, parents, work = forest_segment_scan(
        pi0, empty_forest(num_nodes), segments, WorkCounters.zeros(),
        counts, lift_steps=lift_steps)
    flat = segments.reshape(-1, 2)
    pi, parents, work = forest_cleanup_rounds(
        pi, parents, flat, work, true_edges=true_edges,
        lift_steps=lift_steps, max_rounds=max_rounds)
    return pi, parents, work


# ---------------------------------------------------------------------------
# Id-recording forest rounds (maintained forest, DESIGN.md §14)
# ---------------------------------------------------------------------------
# Same win rule as above, but each recorded row also remembers WHICH
# edge won — an external id (the EdgeLog row) scattered alongside the
# endpoints. ``parent_eidx[r]`` is the log row of the edge recorded at
# ``parents[r]`` (-1 for roots), which is what lets a delete batch
# classify tree vs. non-tree hits with one O(V) gather instead of an
# orientation-blind join over the whole log.


def empty_forest_idx(num_nodes: int) -> jnp.ndarray:
    """int32 [V] log-row table matching ``empty_forest``: all -1."""
    return jnp.full((num_nodes,), -1, jnp.int32)


def hook_edges_forest_ids(pi: jnp.ndarray, parents: jnp.ndarray,
                          parent_eidx: jnp.ndarray, edges: jnp.ndarray,
                          edge_ids: jnp.ndarray, lift_steps: int = 0,
                          ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``hook_edges_forest`` + external-id recording (same pi updates,
    same tie-break: the lowest batch SLOT wins, and that slot's
    ``edge_ids`` entry is what lands in ``parent_eidx``). Padded slots
    carry id -1 but can never win (their (0, 0) self-loop fails the
    strict-decrease test)."""
    n = pi.shape[0]
    u, v = edges[..., 0], edges[..., 1]
    pu, pv = pi[u], pi[v]
    for _ in range(lift_steps):
        pu, pv = pi[pu], pi[pv]
    hi = jnp.maximum(pu, pv)
    lo = jnp.minimum(pu, pv)
    new_pi = pi.at[hi].min(lo)
    won = jnp.logical_and(new_pi[hi] == lo, new_pi[hi] < pi[hi])
    slot = jnp.arange(edges.shape[0], dtype=jnp.int32)
    sentinel = jnp.full((n,), jnp.iinfo(jnp.int32).max, jnp.int32)
    winner = sentinel.at[jnp.where(won, hi, n)].min(slot, mode="drop")
    rec = jnp.logical_and(won, winner[hi] == slot)
    at = jnp.where(rec, hi, n)
    parents = parents.at[at].set(jnp.stack([u, v], axis=-1), mode="drop")
    parent_eidx = parent_eidx.at[at].set(edge_ids, mode="drop")
    return new_pi, parents, parent_eidx


def forest_cleanup_rounds_ids(pi: jnp.ndarray, parents: jnp.ndarray,
                              parent_eidx: jnp.ndarray,
                              edges: jnp.ndarray, edge_ids: jnp.ndarray,
                              work: WorkCounters,
                              true_edges: int | jnp.ndarray | None = None,
                              lift_steps: int = 2,
                              max_rounds: int = MAX_ROUNDS,
                              bill_nodes: int | jnp.ndarray | None = None,
                              ) -> tuple[jnp.ndarray, jnp.ndarray,
                                         jnp.ndarray, WorkCounters]:
    """``forest_cleanup_rounds`` threading the log-row table. The
    scoped delete path passes ``bill_nodes`` (true affected-vertex
    count) so compress sweeps bill the scoped region, not |V|."""
    if true_edges is None:
        true_edges = edges.shape[0]
    bill = jnp.asarray(true_edges, jnp.int32) * (1 + lift_steps)

    def cond(state):
        _, _, _, done, rounds_, _ = state
        return jnp.logical_and(~done, rounds_ < max_rounds)

    def body(state):
        p, f, fi, _, rounds_, w = state
        p, f, fi = hook_edges_forest_ids(p, f, fi, edges, edge_ids,
                                         lift_steps=lift_steps)
        w = w.add(hook_ops=bill, hook_rounds=1)
        p, w = compress(p, w, bill_nodes=bill_nodes)
        return p, f, fi, edges_consistent(p, edges), rounds_ + 1, w

    done0 = edges_consistent(pi, edges)
    pi, parents, parent_eidx, _, _, work = jax.lax.while_loop(
        cond, body,
        (pi, parents, parent_eidx, done0, jnp.zeros((), jnp.int32), work))
    return pi, parents, parent_eidx, work


def forest_segment_scan_ids(pi: jnp.ndarray, parents: jnp.ndarray,
                            parent_eidx: jnp.ndarray,
                            segments: jnp.ndarray, seg_ids: jnp.ndarray,
                            work: WorkCounters, true_counts: jnp.ndarray,
                            lift_steps: int = 2,
                            bill_nodes: int | jnp.ndarray | None = None,
                            ) -> tuple[jnp.ndarray, jnp.ndarray,
                                       jnp.ndarray, WorkCounters]:
    """``forest_segment_scan`` threading the log-row table (used by the
    from-scratch forest rebuild over the surviving EdgeLog and by the
    scoped delete's scan phases, which pass ``bill_nodes`` so compress
    sweeps bill the affected region, not |V|)."""
    bill = 1 + lift_steps

    def seg_body(carry, xs):
        p, f, fi, w = carry
        seg, ids, cnt = xs
        p, f, fi = hook_edges_forest_ids(p, f, fi, seg, ids,
                                         lift_steps=lift_steps)
        w = w.add(hook_ops=cnt * bill, hook_rounds=1)
        p, w = compress(p, w, bill_nodes=bill_nodes)
        return (p, f, fi, w), None

    (pi, parents, parent_eidx, work), _ = jax.lax.scan(
        seg_body, (pi, parents, parent_eidx, work),
        (segments, seg_ids, true_counts))
    return pi, parents, parent_eidx, work


def forest_scan_rounds_ids(pi: jnp.ndarray, parents: jnp.ndarray,
                           parent_eidx: jnp.ndarray, packed: jnp.ndarray,
                           packed_ids: jnp.ndarray,
                           n_true: jnp.ndarray, work: WorkCounters, *,
                           lift_steps: int = 2,
                           max_rounds: int = MAX_ROUNDS,
                           bill_nodes: int | jnp.ndarray | None = None,
                           segment_size: int = 512,
                           ) -> tuple[jnp.ndarray, jnp.ndarray,
                                      jnp.ndarray, WorkCounters]:
    """Work-efficient drive of the id-recording hook over a packed
    (true-prefix) edge list: one Fig. 4 segment-scan pass — each true
    row billed ONCE, with a full compress between segments so later
    segments hook against already-flattened labels — then the fixpoint
    cleanup loop, which after the scan is usually a 0-round no-op
    (``done0`` short-circuits before any billing). Driving the packed
    rows with the flat round loop instead re-bills every row each
    round, turning the skeleton phase into rounds * V_aff work and
    erasing most of the tree-aware path's advantage over the plain
    scoped recompute."""
    cap = packed.shape[0]
    seg = min(segment_size, cap)
    pad = (-cap) % seg
    if pad:
        packed = jnp.concatenate(
            [packed, jnp.zeros((pad, 2), packed.dtype)])
        packed_ids = jnp.concatenate(
            [packed_ids, jnp.full((pad,), -1, jnp.int32)])
    segments = packed.reshape(-1, seg, 2)
    seg_ids = packed_ids.reshape(-1, seg)
    starts = jnp.arange(segments.shape[0], dtype=jnp.int32) * seg
    counts = jnp.clip(jnp.asarray(n_true, jnp.int32) - starts, 0, seg)
    pi, parents, parent_eidx, work = forest_segment_scan_ids(
        pi, parents, parent_eidx, segments, seg_ids, work, counts,
        lift_steps=lift_steps, bill_nodes=bill_nodes)
    return forest_cleanup_rounds_ids(
        pi, parents, parent_eidx, packed, packed_ids, work,
        true_edges=n_true, lift_steps=lift_steps, max_rounds=max_rounds,
        bill_nodes=bill_nodes)


def pack_edge_rows(edges: jnp.ndarray, edge_ids: jnp.ndarray,
                   mask: jnp.ndarray
                   ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pack the rows under ``mask`` to a dense prefix (stable order);
    the tail becomes (0, 0) no-op edges with id -1. Returns
    ``(packed_edges, packed_ids, true_count)``."""
    order = jnp.argsort(~mask, stable=True)
    keep = mask[order]
    packed = jnp.where(keep[:, None], edges[order], 0)
    ids = jnp.where(keep, edge_ids[order], -1)
    return packed, ids, jnp.sum(mask).astype(jnp.int32)


def forest_scoped_rounds(pi: jnp.ndarray, parents: jnp.ndarray,
                         parent_eidx: jnp.ndarray, edges: jnp.ndarray,
                         edge_ids: jnp.ndarray, edge_mask: jnp.ndarray,
                         forest_keep: jnp.ndarray,
                         vertex_mask: jnp.ndarray, work: WorkCounters, *,
                         max_rounds: int = MAX_ROUNDS,
                         ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                    WorkCounters]:
    """Tree-aware scoped reconnection (DESIGN.md §14): relabel only the
    components that lost a spanning-forest edge, in two phases that
    together bill O(V_aff + crossing) instead of O(E_aff):

    1. **skeleton** — re-run hook+compress over the *surviving* forest
       edges of the affected components (``forest_keep``, ~V_aff rows).
       This reassembles the fragments the deletions cut the trees into,
       without touching the (much larger) set of non-tree edges.
    2. **replacement search** — only the alive scoped edges whose
       endpoints still disagree after phase 1 (*crossing* edges) can
       reconnect fragments; pack exactly those and hook to fixpoint,
       recording the replacement edges into the forest.

    Affected vertices restart as self-roots with their forest rows
    cleared; unaffected components keep labels and forest rows
    untouched, so labels stay canonical (component minima) and the
    no-split case reproduces the pre-delete labels bit-identically.

    Both phases run UNLIFTED hooks (``lift_steps=0``): a full compress
    runs between every segment and every cleanup round, so each hook
    reads already-flat labels and lifted re-gathers would be redundant
    loads — billing them would triple the skeleton bill for work a
    flat-label implementation never issues. Labels are bit-identical
    either way (pinned by the conformance oracle scripts).
    """
    n_v = pi.shape[0]
    bill_nodes = jnp.sum(vertex_mask).astype(jnp.int32)
    pi0 = jnp.where(vertex_mask, jnp.arange(n_v, dtype=jnp.int32), pi)
    parents0 = jnp.where(vertex_mask[:, None], -1, parents)
    eidx0 = jnp.where(vertex_mask, -1, parent_eidx)

    skel, skel_ids, n_skel = pack_edge_rows(parents, parent_eidx,
                                            forest_keep)
    # 1024-row segments: fewer scan iterations (the skeleton is V-sized
    # from the first tick even while the log is still small) at the
    # same 2-pass billing floor as 512 on the bench fixtures
    pi1, parents1, eidx1, work = forest_scan_rounds_ids(
        pi0, parents0, eidx0, skel, skel_ids, n_skel, work,
        lift_steps=0, max_rounds=max_rounds, bill_nodes=bill_nodes,
        segment_size=1024)

    crossing = jnp.logical_and(edge_mask,
                               pi1[edges[:, 0]] != pi1[edges[:, 1]])
    # crossing is the small set (inter-fragment survivors); the flat
    # fixpoint loop converges in O(fragments) rounds. Mask in place
    # instead of packing: (0, 0)/-1 rows are hook no-ops and billing
    # runs on the TRUE crossing count either way, while a pack would
    # argsort the full log capacity on every delete tick
    c_edges = jnp.where(crossing[:, None], edges, 0)
    c_ids = jnp.where(crossing, edge_ids, -1)
    n_cross = jnp.sum(crossing).astype(jnp.int32)
    pi2, parents2, eidx2, work = forest_cleanup_rounds_ids(
        pi1, parents1, eidx1, c_edges, c_ids, work, true_edges=n_cross,
        lift_steps=0, max_rounds=max_rounds, bill_nodes=bill_nodes)
    return pi2, parents2, eidx2, work


# ---------------------------------------------------------------------------
# Segmentation helpers
# ---------------------------------------------------------------------------

def pad_and_segment(edges: jnp.ndarray, plan: SegmentationPlan
                    ) -> jnp.ndarray:
    """Pad ``edges`` with (0, 0) no-ops to ``plan.padded_edges`` and
    reshape to [num_segments, segment_size, 2]. Trace-safe (static pad)."""
    pad = plan.padded_edges - edges.shape[0]
    if pad > 0:
        edges = jnp.concatenate(
            [edges, jnp.zeros((pad, 2), edges.dtype)], axis=0)
    return edges.reshape(plan.num_segments, plan.segment_size, 2)


def segment_true_counts(true_edges: int | jnp.ndarray,
                        plan: SegmentationPlan) -> jnp.ndarray:
    """Per-segment count of *true* (unpadded) edges, [num_segments] int32.

    Segment i holds edge slots [i*seg, (i+1)*seg); the first
    ``true_edges`` slots are real, the rest are (0, 0) padding. Accepts a
    static int or a traced scalar (the batched path's per-graph counts).
    """
    starts = jnp.arange(plan.num_segments, dtype=jnp.int32) * plan.segment_size
    return jnp.clip(jnp.asarray(true_edges, jnp.int32) - starts,
                    0, plan.segment_size)


# ---------------------------------------------------------------------------
# Round composition (Fig. 4)
# ---------------------------------------------------------------------------

def segment_scan(pi: jnp.ndarray, segments: jnp.ndarray, ops: RoundOps,
                 work: WorkCounters,
                 true_counts: jnp.ndarray | None = None,
                 ) -> tuple[jnp.ndarray, WorkCounters]:
    """Fig. 4 inner structure: for each segment, hook then fully
    compress, all inside one ``lax.scan`` (zero host round-trips).

    ``true_counts`` ([num_segments] int32) bills hook_ops per segment on
    true edges only; None bills the full (padded) segment size.

    With fused ops (``ops.scan`` set) the whole scan is ONE kernel
    launch instead of ``num_segments + jump_sweeps``.
    """
    if true_counts is None:
        true_counts = jnp.full((segments.shape[0],), segments.shape[1],
                               jnp.int32)
    if ops.scan is not None:
        return ops.scan(pi, segments, true_counts, work)

    def seg_body(carry, xs):
        p, w = carry
        seg, cnt = xs
        p = ops.hook(p, seg)
        w = w.add(hook_ops=cnt * ops.bill_lift, hook_rounds=1)
        p, w = ops.compress(p, w)
        return (p, w), None

    (pi, work), _ = jax.lax.scan(seg_body, (pi, work),
                                 (segments, true_counts))
    return pi, work


def cleanup_rounds(pi: jnp.ndarray, edges: jnp.ndarray, ops: RoundOps,
                   work: WorkCounters,
                   true_edges: int | jnp.ndarray | None = None,
                   max_rounds: int = MAX_ROUNDS,
                   ) -> tuple[jnp.ndarray, WorkCounters]:
    """Re-hook ``edges`` until every edge is consistent (usually 0-1
    rounds). Covers hook candidates dropped by deterministic
    min-selection — the CAS retry loop of the GPU version resolves those
    in-kernel (DESIGN.md §2). Also the whole of an *incremental* insert:
    hooking a new edge batch into an existing label array is exactly
    this loop (DESIGN.md §6; Hong et al.).

    The initial consistency check short-circuits already-connected edge
    sets to zero hook rounds — the incremental path's common case.
    """
    if true_edges is None:
        true_edges = edges.shape[0]
    bill = jnp.asarray(true_edges, jnp.int32) * ops.bill_lift
    true1 = jnp.asarray(true_edges, jnp.int32).reshape(1)

    def cond(state):
        _, done, rounds, _ = state
        return jnp.logical_and(~done, rounds < max_rounds)

    def body(state):
        p, _, rounds, w = state
        if ops.scan is not None:
            # fused backend: hook + full compress of the (single-segment)
            # edge set in ONE launch per cleanup round
            p, w = ops.scan(p, edges[None], true1, w)
        else:
            p = ops.hook(p, edges)
            w = w.add(hook_ops=bill, hook_rounds=1)
            p, w = ops.compress(p, w)
        return p, edges_consistent(p, edges), rounds + 1, w

    done0 = edges_consistent(pi, edges)
    pi, _, _, work = jax.lax.while_loop(
        cond, body, (pi, done0, jnp.zeros((), jnp.int32), work))
    return pi, work


def scoped_rounds(pi: jnp.ndarray, edges: jnp.ndarray,
                  edge_mask: jnp.ndarray, vertex_mask: jnp.ndarray,
                  plan: SegmentationPlan, ops: RoundOps,
                  work: WorkCounters,
                  max_rounds: int = MAX_ROUNDS,
                  ) -> tuple[jnp.ndarray, WorkCounters]:
    """Scoped recompute (DESIGN.md §9): re-derive labels for ONLY the
    vertices under ``vertex_mask`` from the edges under ``edge_mask``,
    leaving every other label untouched — the deletion fallback of the
    fully-dynamic engine, where ``vertex_mask`` marks the components a
    tombstoned edge may have split and ``edge_mask`` their surviving
    edges.

    The masked edges are compacted to a (0, 0)-padded prefix on device
    (one stable sort — same invariant restoration as
    ``graphs.device.compact_alive``), then run through the ordinary
    Fig. 4 pipeline: segment scan over ``plan`` + trailing cleanup.
    Affected vertices restart as self-roots; unaffected vertices keep
    their (canonical) labels, which hook can neither read nor write
    because every scoped edge joins two affected vertices — k
    simultaneous splits ride ONE stacked scan. Billing is scoped too:
    ``hook_ops`` covers masked edges only (traced count), and callers
    pass ``bill_nodes`` = affected-vertex count into ``ops`` so
    ``jump_ops`` ignores the untouched remainder.
    """
    n_scoped = jnp.sum(edge_mask).astype(jnp.int32)
    order = jnp.argsort(~edge_mask, stable=True)     # scoped rows first
    packed = jnp.where(edge_mask[order][:, None], edges[order], 0)
    pi0 = jnp.where(vertex_mask,
                    jnp.arange(pi.shape[0], dtype=jnp.int32), pi)
    segments = pad_and_segment(packed, plan)
    counts = segment_true_counts(n_scoped, plan)
    pi1, work = segment_scan(pi0, segments, ops, work, true_counts=counts)
    pi1, work = cleanup_rounds(pi1, segments.reshape(-1, 2), ops, work,
                               true_edges=n_scoped, max_rounds=max_rounds)
    return pi1, work


def adaptive_rounds(edges: jnp.ndarray, num_nodes: int,
                    plan: SegmentationPlan, *,
                    ops: RoundOps | None = None,
                    lift_steps: int = 2,
                    true_edges: int | jnp.ndarray | None = None,
                    max_rounds: int = MAX_ROUNDS,
                    ) -> tuple[jnp.ndarray, WorkCounters]:
    """The full adaptive pipeline (Fig. 4): segment scan, then cleanup.

    ``true_edges`` defaults to ``plan.num_edges`` (the single-graph
    case); the batched path passes a traced per-graph scalar instead.
    Returns (labels, work) — callers add their own sync_rounds billing.
    """
    if ops is None:
        ops = jnp_round_ops(lift_steps)
    if true_edges is None:
        true_edges = plan.num_edges
    segments = pad_and_segment(edges, plan)
    counts = segment_true_counts(true_edges, plan)

    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, work = segment_scan(pi0, segments, ops, WorkCounters.zeros(),
                            true_counts=counts)
    flat = segments.reshape(-1, 2)
    pi, work = cleanup_rounds(pi, flat, ops, work, true_edges=true_edges,
                              max_rounds=max_rounds)
    return pi, work
