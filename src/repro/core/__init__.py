# The paper's primary contribution: adaptive work-efficient Connected
# Components (Hook-Compress with Multi-Jump, Atomic-Hook analogue, and
# 2|E|/|V| adaptive segmentation), plus baselines and the distributed form.
from repro.core.cc import (
    CCResult,
    WorkCounters,
    connected_components,
    connected_components_hostloop,
    num_components,
    METHODS,
)
from repro.core.segmentation import (
    SegmentationPlan,
    adaptive_num_segments,
    plan_segmentation,
)
from repro.core.unionfind import connected_components_oracle
from repro.core.batch import connected_components_batched
from repro.core.incremental import IncrementalCC
