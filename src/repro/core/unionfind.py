"""Host-side union-find oracle for connected components.

Pure numpy; used only as the ground-truth reference in tests and
benchmarks. Labels follow the same canonical convention as the JAX
implementations: every vertex is labeled with the *minimum* vertex id of
its component.
"""
from __future__ import annotations

import numpy as np


class UnionFind:
    """Classic union-find with path compression + union by size."""

    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)
        self.size = np.ones(n, dtype=np.int64)

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        # path compression
        while self.parent[x] != root:
            self.parent[x], x = root, self.parent[x]
        return int(root)

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]


def connected_components_oracle(edges: np.ndarray, num_nodes: int) -> np.ndarray:
    """Min-vertex-id component labels via union-find.

    Args:
      edges: int array [E, 2]; self loops / duplicates / empty allowed.
      num_nodes: number of vertices.

    Returns:
      int32 [num_nodes] labels; labels[v] == min vertex id in v's component.
    """
    uf = UnionFind(num_nodes)
    edges = np.asarray(edges).reshape(-1, 2)
    for u, v in edges:
        if 0 <= u < num_nodes and 0 <= v < num_nodes:
            uf.union(int(u), int(v))
    roots = np.array([uf.find(i) for i in range(num_nodes)], dtype=np.int64)
    # canonicalize: label = min vertex id in component
    min_label = np.full(num_nodes, np.iinfo(np.int64).max, dtype=np.int64)
    np.minimum.at(min_label, roots, np.arange(num_nodes, dtype=np.int64))
    return min_label[roots].astype(np.int32)


def num_components(labels: np.ndarray) -> int:
    return int(np.unique(np.asarray(labels)).size)


def connected_components_scipy(edges: np.ndarray, num_nodes: int
                               ) -> np.ndarray | None:
    """Independent second oracle via ``scipy.sparse.csgraph``,
    canonicalized to the same min-vertex-id convention; returns None
    when scipy is absent (the union-find oracle stands alone then).
    Two disagreeing oracles would flag an oracle bug rather than an
    engine bug — the conformance suite cross-checks them."""
    try:
        import scipy.sparse as sp
        from scipy.sparse.csgraph import connected_components as cc
    except ImportError:                                # pragma: no cover
        return None
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    ok = ((edges >= 0) & (edges < num_nodes)).all(axis=1) \
        if edges.size else np.zeros((0,), bool)
    edges = edges[ok]
    mat = sp.coo_matrix(
        (np.ones(edges.shape[0]), (edges[:, 0], edges[:, 1])),
        shape=(num_nodes, num_nodes))
    _, comp = cc(mat, directed=False)
    min_label = np.full(num_nodes, np.iinfo(np.int64).max, np.int64)
    np.minimum.at(min_label, comp, np.arange(num_nodes, dtype=np.int64))
    return min_label[comp].astype(np.int32)


class DynamicConnectivityOracle:
    """Host ground truth for interleaved insert/delete scripts
    (DESIGN.md §9): a multiset edge log with the SAME deletion
    semantics as ``repro.core.incremental.DynamicCC`` — a delete of
    undirected edge {u, v} is orientation-blind and retires every
    surviving copy; deleting an absent edge is a no-op. ``labels()``
    recomputes from scratch over the survivors via union-find (and the
    scipy cross-oracle when available), so the dynamic engines' scoped
    shortcuts are checked against the most boring correct answer."""

    def __init__(self, num_nodes: int):
        self.num_nodes = int(num_nodes)
        self.edges: list[tuple[int, int]] = []

    @staticmethod
    def _norm(e) -> tuple[int, int]:
        u, v = int(e[0]), int(e[1])
        return (u, v) if u <= v else (v, u)

    def insert(self, edges) -> None:
        for e in np.asarray(edges, np.int64).reshape(-1, 2):
            self.edges.append((int(e[0]), int(e[1])))

    def delete(self, edges) -> None:
        kill = {self._norm(e)
                for e in np.asarray(edges, np.int64).reshape(-1, 2)}
        self.edges = [e for e in self.edges
                      if self._norm(e) not in kill]

    def alive(self) -> np.ndarray:
        return np.asarray(self.edges, np.int64).reshape(-1, 2)

    def labels(self) -> np.ndarray:
        want = connected_components_oracle(self.alive(), self.num_nodes)
        cross = connected_components_scipy(self.alive(), self.num_nodes)
        if cross is not None and not np.array_equal(want, cross):
            raise AssertionError(       # pragma: no cover - oracle bug
                "union-find and scipy oracles disagree")
        return want
