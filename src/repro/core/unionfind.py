"""Host-side union-find oracle for connected components.

Pure numpy; used only as the ground-truth reference in tests and
benchmarks. Labels follow the same canonical convention as the JAX
implementations: every vertex is labeled with the *minimum* vertex id of
its component.
"""
from __future__ import annotations

import numpy as np


class UnionFind:
    """Classic union-find with path compression + union by size."""

    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)
        self.size = np.ones(n, dtype=np.int64)

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        # path compression
        while self.parent[x] != root:
            self.parent[x], x = root, self.parent[x]
        return int(root)

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]


def connected_components_oracle(edges: np.ndarray, num_nodes: int) -> np.ndarray:
    """Min-vertex-id component labels via union-find.

    Args:
      edges: int array [E, 2]; self loops / duplicates / empty allowed.
      num_nodes: number of vertices.

    Returns:
      int32 [num_nodes] labels; labels[v] == min vertex id in v's component.
    """
    uf = UnionFind(num_nodes)
    edges = np.asarray(edges).reshape(-1, 2)
    for u, v in edges:
        if 0 <= u < num_nodes and 0 <= v < num_nodes:
            uf.union(int(u), int(v))
    roots = np.array([uf.find(i) for i in range(num_nodes)], dtype=np.int64)
    # canonicalize: label = min vertex id in component
    min_label = np.full(num_nodes, np.iinfo(np.int64).max, dtype=np.int64)
    np.minimum.at(min_label, roots, np.arange(num_nodes, dtype=np.int64))
    return min_label[roots].astype(np.int32)


def num_components(labels: np.ndarray) -> int:
    return int(np.unique(np.asarray(labels)).size)
