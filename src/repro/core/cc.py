"""Adaptive work-efficient Connected Components — the paper's core, in JAX.

Implements the four variants compared in the paper's Fig. 5, adapted for
TPU (see DESIGN.md §2 for the GPU→TPU mapping):

  * ``soman``       — Soman et al. baseline (Fig. 1/2): single-level hook
                      rounds + single-level Jump sweeps, a convergence
                      check after *every* sweep (each check is a
                      host-round-trip on the GPU baseline; we count them).
  * ``multijump``   — + the paper's Multi-Jump: the whole Compress phase is
                      fused into one on-device ``lax.while_loop``.
  * ``atomic_hook`` — + the paper's Atomic-Hook: a root-chasing hook pass
                      (bounded vectorized lift + deterministic scatter-min,
                      the TPU analogue of the CAS chase) over the whole edge
                      list, fused with compress into a single device loop.
  * ``adaptive``    — + the paper's adaptive segmentation: the edge list is
                      split into s = 2|E|/|V| segments; each segment hook is
                      followed by a full compress (Fig. 4), all inside one
                      jitted program (zero host round-trips).

All variants produce canonical labels: ``labels[v] == min vertex id of
v's component`` (a strictly stronger guarantee than the paper's "some
representative" — see DESIGN.md).

Work accounting (the paper's currency is work-efficiency):
  * ``hook_ops``    — edge-hook evaluations performed,
  * ``jump_ops``    — vertex-jump (gather) evaluations performed,
  * ``jump_sweeps`` — full |V|-wide pointer-jump sweeps,
  * ``hook_rounds`` — edge-set hook rounds,
  * ``sync_rounds`` — host-equivalent synchronization points (device→host
                      convergence checks a GPU host-side loop would incur;
                      fused variants count 1 per jit call).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.segmentation import SegmentationPlan, plan_segmentation

_MAX_ROUNDS = 64          # outer hook-round fuel


def _compress_fuel(num_nodes: int) -> int:
    """Pointer doubling squares path lengths per sweep, so
    ceil(log2(V)) + 2 sweeps provably flatten any forest on V nodes —
    a 2-3x tighter static loop bound than a fixed 64 (the roofline's
    memory term for CC scales with this fuel)."""
    import math
    return max(4, math.ceil(math.log2(max(num_nodes, 2))) + 2)

METHODS = ("soman", "multijump", "atomic_hook", "adaptive", "labelprop")


class WorkCounters(NamedTuple):
    hook_ops: jnp.ndarray
    jump_ops: jnp.ndarray
    jump_sweeps: jnp.ndarray
    hook_rounds: jnp.ndarray
    sync_rounds: jnp.ndarray

    @staticmethod
    def zeros() -> "WorkCounters":
        z = jnp.zeros((), jnp.int32)
        return WorkCounters(z, z, z, z, z)

    def add(self, **kw) -> "WorkCounters":
        d = self._asdict()
        for k, v in kw.items():
            d[k] = d[k] + jnp.asarray(v, jnp.int32)
        return WorkCounters(**d)


class CCResult(NamedTuple):
    labels: jnp.ndarray       # int32 [V]; labels[v] = min id of v's component
    work: WorkCounters


# ---------------------------------------------------------------------------
# Primitive operations
# ---------------------------------------------------------------------------

def hook_edges(pi: jnp.ndarray, edges: jnp.ndarray, lift_steps: int = 0
               ) -> jnp.ndarray:
    """One deterministic hook round over ``edges`` (TPU analogue of Hook /
    Atomic-Hook).

    For every edge (u, v): H = max(pi(u), pi(v)), L = min(...), then
    ``pi[H] <- min(pi[H], L)`` via scatter-min (race-free winner selection —
    the deterministic stand-in for the CAS consensus; identical fixed point
    under the paper's high-to-low rule). ``lift_steps`` performs the bounded
    vectorized root chase of Atomic-Hook (pu <- pi[pu]) before hooking.
    """
    u, v = edges[..., 0], edges[..., 1]
    pu, pv = pi[u], pi[v]
    for _ in range(lift_steps):
        pu, pv = pi[pu], pi[pv]
    hi = jnp.maximum(pu, pv)
    lo = jnp.minimum(pu, pv)
    return pi.at[hi].min(lo)


def jump_once(pi: jnp.ndarray) -> jnp.ndarray:
    """Single-level Jump (Fig. 2): pi <- pi[pi] for every vertex."""
    return pi[pi]


def compress(pi: jnp.ndarray, work: WorkCounters,
             count_syncs: bool = False) -> tuple[jnp.ndarray, WorkCounters]:
    """Full Compress via fused pointer doubling (the Multi-Jump kernel).

    Runs pi <- pi[pi] sweeps on-device until every tree is a star. Each
    sweep *squares* path lengths (pointer doubling), the same
    work-efficiency lever as the paper's in-kernel chase + continuous
    write-back. With ``count_syncs`` every sweep also bills one host
    synchronization (used by the Soman baseline whose Jump loop re-checks
    convergence from the host after every single-level kernel).
    """
    v = pi.shape[0]
    fuel = _compress_fuel(v)

    def cond(state):
        _, changed, sweeps, _ = state
        return jnp.logical_and(changed, sweeps < fuel)

    def body(state):
        p, _, sweeps, w = state
        nxt = p[p]
        changed = jnp.any(nxt != p)
        w = w.add(jump_ops=v, jump_sweeps=1,
                  sync_rounds=1 if count_syncs else 0)
        return nxt, changed, sweeps + 1, w

    pi, _, _, work = jax.lax.while_loop(
        cond, body, (pi, jnp.asarray(True), jnp.zeros((), jnp.int32), work))
    return pi, work


def edges_consistent(pi: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """True iff every edge has both endpoints under the same label."""
    return jnp.all(pi[edges[..., 0]] == pi[edges[..., 1]])


# ---------------------------------------------------------------------------
# Variant: Soman et al. baseline (Fig. 1) — single-level hooks and jumps
# ---------------------------------------------------------------------------

def _cc_soman(edges: jnp.ndarray, num_nodes: int) -> CCResult:
    e = edges.shape[0]

    def outer_cond(state):
        _, changed, rounds, _ = state
        return jnp.logical_and(changed, rounds < _MAX_ROUNDS)

    def outer_body(state):
        pi, _, rounds, w = state
        new_pi = hook_edges(pi, edges, lift_steps=0)
        hook_changed = jnp.any(new_pi != pi)
        # bill the hook kernel + its host-side convergence check
        w = w.add(hook_ops=e, hook_rounds=1, sync_rounds=1)
        # Fig. 1 lines 6-10: single-level Jump until no change, a host
        # convergence check after every sweep.
        new_pi, w = compress(new_pi, w, count_syncs=True)
        return new_pi, hook_changed, rounds + 1, w

    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, _, _, work = jax.lax.while_loop(
        outer_cond, outer_body,
        (pi0, jnp.asarray(True), jnp.zeros((), jnp.int32),
         WorkCounters.zeros()))
    return CCResult(pi, work)


# ---------------------------------------------------------------------------
# Variant: + Multi-Jump (fused compress, device-resident)
# ---------------------------------------------------------------------------

def _cc_multijump(edges: jnp.ndarray, num_nodes: int) -> CCResult:
    e = edges.shape[0]

    def outer_cond(state):
        _, changed, rounds, _ = state
        return jnp.logical_and(changed, rounds < _MAX_ROUNDS)

    def outer_body(state):
        pi, _, rounds, w = state
        new_pi = hook_edges(pi, edges, lift_steps=0)
        hook_changed = jnp.any(new_pi != pi)
        # one hook kernel + ONE fused Multi-Jump kernel => 2 syncs/round
        w = w.add(hook_ops=e, hook_rounds=1, sync_rounds=2)
        new_pi, w = compress(new_pi, w, count_syncs=False)
        return new_pi, hook_changed, rounds + 1, w

    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, _, _, work = jax.lax.while_loop(
        outer_cond, outer_body,
        (pi0, jnp.asarray(True), jnp.zeros((), jnp.int32),
         WorkCounters.zeros()))
    return CCResult(pi, work)


# ---------------------------------------------------------------------------
# Variant: + Atomic-Hook (root-chasing hook, zero host round-trips)
# ---------------------------------------------------------------------------

def _cc_atomic_hook(edges: jnp.ndarray, num_nodes: int,
                    lift_steps: int = 2) -> CCResult:
    e = edges.shape[0]

    def cond(state):
        pi, done, rounds, _ = state
        return jnp.logical_and(~done, rounds < _MAX_ROUNDS)

    def body(state):
        pi, _, rounds, w = state
        pi = hook_edges(pi, edges, lift_steps=lift_steps)
        w = w.add(hook_ops=e * (1 + lift_steps), hook_rounds=1)
        pi, w = compress(pi, w)
        done = edges_consistent(pi, edges)
        return pi, done, rounds + 1, w

    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, _, _, work = jax.lax.while_loop(
        cond, body,
        (pi0, jnp.asarray(False), jnp.zeros((), jnp.int32),
         WorkCounters.zeros()))
    # the whole program is one fused device loop: a single host sync
    work = work.add(sync_rounds=1)
    return CCResult(pi, work)


# ---------------------------------------------------------------------------
# Variant: adaptive segmentation (Fig. 4) — the paper's contribution
# ---------------------------------------------------------------------------

def _cc_adaptive(edges: jnp.ndarray, num_nodes: int,
                 plan: SegmentationPlan, lift_steps: int = 2) -> CCResult:
    """Fig. 4: for each of the s = 2|E|/|V| segments, Atomic-Hook the
    segment then fully compress. A trailing consistency loop covers hook
    candidates dropped by deterministic min-selection (the CAS retry loop
    of the GPU version resolves those in-kernel; see DESIGN.md §2) —
    typically 0–1 extra rounds, visible in the work counters.
    """
    pad = plan.padded_edges - edges.shape[0]
    if pad > 0:
        edges = jnp.concatenate(
            [edges, jnp.zeros((pad, 2), edges.dtype)], axis=0)
    segments = edges.reshape(plan.num_segments, plan.segment_size, 2)

    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)

    def seg_body(carry, seg):
        pi, w = carry
        pi = hook_edges(pi, seg, lift_steps=lift_steps)
        w = w.add(hook_ops=plan.segment_size * (1 + lift_steps),
                  hook_rounds=1)
        pi, w = compress(pi, w)
        return (pi, w), None

    (pi, work), _ = jax.lax.scan(
        seg_body, (pi0, WorkCounters.zeros()), segments)

    # cleanup: re-hook full edge list until consistent (usually converged)
    def cond(state):
        pi, done, rounds, _ = state
        return jnp.logical_and(~done, rounds < _MAX_ROUNDS)

    def body(state):
        pi, _, rounds, w = state
        pi = hook_edges(pi, edges, lift_steps=lift_steps)
        w = w.add(hook_ops=edges.shape[0] * (1 + lift_steps), hook_rounds=1)
        pi, w = compress(pi, w)
        done = edges_consistent(pi, edges)
        return pi, done, rounds + 1, w

    done0 = edges_consistent(pi, edges)
    pi, _, _, work = jax.lax.while_loop(
        cond, body, (pi, done0, jnp.zeros((), jnp.int32), work))
    work = work.add(sync_rounds=1)   # one jit call end-to-end
    return CCResult(pi, work)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("num_nodes", "method", "num_segments",
                              "lift_steps"))
def _cc_jit(edges, *, num_nodes, method, num_segments, lift_steps):
    if method == "soman":
        return _cc_soman(edges, num_nodes)
    if method == "multijump":
        return _cc_multijump(edges, num_nodes)
    if method == "atomic_hook":
        return _cc_atomic_hook(edges, num_nodes, lift_steps)
    if method == "adaptive":
        plan = plan_segmentation(edges.shape[0], num_nodes, num_segments)
        return _cc_adaptive(edges, num_nodes, plan, lift_steps)
    if method == "labelprop":
        from repro.core.labelprop import _cc_labelprop
        return _cc_labelprop(edges, num_nodes)
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def connected_components(
    edges,
    num_nodes: int,
    method: str = "adaptive",
    *,
    num_segments: int | None = None,
    lift_steps: int = 2,
) -> CCResult:
    """Compute connected components.

    Args:
      edges: [E, 2] int array of undirected edges (one direction suffices;
        self loops and duplicates are harmless).
      num_nodes: |V| (static).
      method: one of ``soman | multijump | atomic_hook | adaptive |
        labelprop``.
      num_segments: override the adaptive 2|E|/|V| heuristic (adaptive only).
      lift_steps: bounded root-chase depth in the Atomic-Hook analogue.

    Returns:
      ``CCResult(labels, work)`` with canonical min-id labels.
    """
    edges = jnp.asarray(edges, jnp.int32).reshape(-1, 2)
    if num_nodes <= 0:
        return CCResult(jnp.zeros((0,), jnp.int32), WorkCounters.zeros())
    if edges.shape[0] == 0:
        return CCResult(jnp.arange(num_nodes, dtype=jnp.int32),
                        WorkCounters.zeros())
    return _cc_jit(edges, num_nodes=num_nodes, method=method,
                   num_segments=num_segments, lift_steps=lift_steps)


# ---------------------------------------------------------------------------
# Pallas-kernel backend (TPU target; interpret-mode on CPU)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("num_nodes", "num_segments", "lift_steps",
                              "interpret"))
def _cc_adaptive_pallas(edges, *, num_nodes, num_segments, lift_steps,
                        interpret):
    from repro.kernels.hook.ops import hook_edges_pallas
    from repro.kernels.multi_jump.ops import full_compress

    plan = plan_segmentation(edges.shape[0], num_nodes, num_segments)
    pad = plan.padded_edges - edges.shape[0]
    if pad > 0:
        edges = jnp.concatenate(
            [edges, jnp.zeros((pad, 2), edges.dtype)], axis=0)
    segments = edges.reshape(plan.num_segments, plan.segment_size, 2)
    tile = min(512, max(8, num_nodes))
    etile = min(1024, plan.segment_size)

    def seg_body(pi, seg):
        pi = hook_edges_pallas(pi, seg, edge_tile=etile,
                               lift_steps=lift_steps, interpret=interpret)
        pi = full_compress(pi, tile=tile, interpret=interpret)
        return pi, None

    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, _ = jax.lax.scan(seg_body, pi0, segments)

    def cond(state):
        pi, done, rounds = state
        return jnp.logical_and(~done, rounds < _MAX_ROUNDS)

    def body(state):
        pi, _, rounds = state
        pi = hook_edges_pallas(pi, edges, edge_tile=etile,
                               lift_steps=lift_steps, interpret=interpret)
        pi = full_compress(pi, tile=tile, interpret=interpret)
        return pi, edges_consistent(pi, edges), rounds + 1

    pi, _, _ = jax.lax.while_loop(
        cond, body,
        (pi, edges_consistent(pi, edges), jnp.zeros((), jnp.int32)))
    return pi


def connected_components_pallas(edges, num_nodes: int, *,
                                num_segments: int | None = None,
                                lift_steps: int = 2,
                                interpret: bool | None = None) -> jnp.ndarray:
    """Adaptive CC on the Pallas kernel backend (hook + multi_jump
    kernels; DESIGN.md §2). Returns canonical min-id labels."""
    from repro.kernels import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    edges = jnp.asarray(edges, jnp.int32).reshape(-1, 2)
    if num_nodes <= 0:
        return jnp.zeros((0,), jnp.int32)
    if edges.shape[0] == 0:
        return jnp.arange(num_nodes, dtype=jnp.int32)
    return _cc_adaptive_pallas(edges, num_nodes=num_nodes,
                               num_segments=num_segments,
                               lift_steps=lift_steps, interpret=interpret)


# ---------------------------------------------------------------------------
# Host-driven execution (GPU-baseline control flow, for benchmarking)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _host_hook(pi, edges):
    new = hook_edges(pi, edges, lift_steps=0)
    return new, jnp.any(new != pi)


@functools.partial(jax.jit, donate_argnums=(0,))
def _host_jump(pi):
    new = pi[pi]
    return new, jnp.any(new != pi)


@jax.jit
def _host_compress(pi):
    pi, w = compress(pi, WorkCounters.zeros())
    return pi, w.jump_sweeps


def connected_components_hostloop(
    edges, num_nodes: int, method: str = "soman",
) -> tuple[np.ndarray, dict]:
    """Run the Soman baseline (or +multijump) with *host-side* control
    flow — one ``device_get`` per convergence check, faithful to the GPU
    baseline's CPU-GPU round trips. Used by the benchmarks to expose the
    cost the paper's device-centric design removes.
    """
    edges = jnp.asarray(edges, jnp.int32).reshape(-1, 2)
    pi = jnp.arange(num_nodes, dtype=jnp.int32)
    syncs = 0
    stats = {"hook_rounds": 0, "jump_sweeps": 0}
    while True:
        pi, hook_changed = _host_hook(pi, edges)
        stats["hook_rounds"] += 1
        syncs += 1
        if method == "soman":
            while True:
                pi, jchanged = _host_jump(pi)
                stats["jump_sweeps"] += 1
                syncs += 1
                if not bool(jchanged):          # device->host round trip
                    break
        else:  # multijump: one fused compress kernel, one sync
            pi, sweeps = _host_compress(pi)
            stats["jump_sweeps"] += int(sweeps)
            syncs += 1
        if not bool(hook_changed):              # device->host round trip
            break
    stats["sync_rounds"] = syncs
    return np.asarray(pi), stats


def num_components(labels) -> int:
    return int(np.unique(np.asarray(labels)).size)
