"""Adaptive work-efficient Connected Components — the paper's core, in JAX.

Implements the four variants compared in the paper's Fig. 5, adapted for
TPU (see DESIGN.md §2 for the GPU→TPU mapping):

  * ``soman``       — Soman et al. baseline (Fig. 1/2): single-level hook
                      rounds + single-level Jump sweeps, a convergence
                      check after *every* sweep (each check is a
                      host-round-trip on the GPU baseline; we count them).
  * ``multijump``   — + the paper's Multi-Jump: the whole Compress phase is
                      fused into one on-device ``lax.while_loop``.
  * ``atomic_hook`` — + the paper's Atomic-Hook: a root-chasing hook pass
                      (bounded vectorized lift + deterministic scatter-min,
                      the TPU analogue of the CAS chase) over the whole edge
                      list, fused with compress into a single device loop.
  * ``adaptive``    — + the paper's adaptive segmentation: the edge list is
                      split into s = 2|E|/|V| segments; each segment hook is
                      followed by a full compress (Fig. 4), all inside one
                      jitted program (zero host round-trips).

All variants produce canonical labels: ``labels[v] == min vertex id of
v's component`` (a strictly stronger guarantee than the paper's "some
representative" — see DESIGN.md §2; it is also what makes batched and
incremental execution bit-compatible with the single-graph path).

The round primitives (hook, compress, segment scan, cleanup loop) live
in ``repro.core.rounds`` and are shared with the batched
(``repro.core.batch``), incremental (``repro.core.incremental``), and
distributed (``repro.core.distributed``) engines; this module keeps the
single-graph variants and their engine entries (``solve_static`` /
``solve_pallas`` / ``solve_hostloop``) — the PUBLIC door is the
``repro.api`` facade, which the deprecated ``connected_components*``
shims forward into. Work accounting (the paper's
currency) bills *true* edge counts — padding is free; see
``rounds.WorkCounters`` for the counter glossary.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.core.rounds import (        # re-exported; shared machinery
    WorkCounters,
    compress,
    edges_consistent,
    hook_edges,
    jump_once,
)
from repro.core.segmentation import SegmentationPlan, plan_segmentation

_MAX_ROUNDS = rounds.MAX_ROUNDS   # outer hook-round fuel

METHODS = ("soman", "multijump", "atomic_hook", "adaptive", "labelprop")
# + the fused Pallas backend (one kernel launch per segment scan);
# labels are bit-identical to the jnp backend, validated in tests
FUSED_METHOD = "pallas_fused"
# + the k-out sampling engines (repro.core.sampled): sampling phase
# collapses the giant component, adaptive scan covers the residue only
SAMPLED_METHODS = ("sampled", "sampled_fused")
ALL_METHODS = METHODS + (FUSED_METHOD,) + SAMPLED_METHODS
HOSTLOOP_METHODS = ("soman", "multijump")
# the static methods whose jnp hook rounds record the spanning forest
# (labelprop propagates labels without hooking; the fused kernel hooks
# in-kernel without recording; sampled_fused records the sampling
# phase only, so it does not claim the capability)
FOREST_METHODS = ("soman", "multijump", "atomic_hook", "adaptive",
                  "sampled")


class CCResult(NamedTuple):
    labels: jnp.ndarray       # int32 [V]; labels[v] = min id of v's component
    work: WorkCounters


class ForestResult(NamedTuple):
    """Labels + the spanning forest recorded during hook rounds.

    ``parents`` is int32 [V, 2]: row r holds the original graph edge
    whose hook retired root r (rows left (-1, -1) are the component
    roots — exactly one per component, the component minimum). The
    recorded rows are exactly |V| - C edges forming a spanning forest
    whose partition equals ``labels`` (property-tested)."""

    labels: jnp.ndarray
    parents: jnp.ndarray
    work: WorkCounters


# ---------------------------------------------------------------------------
# Variant: Soman et al. baseline (Fig. 1) — single-level hooks and jumps
# ---------------------------------------------------------------------------

def _cc_soman(edges: jnp.ndarray, num_nodes: int,
              true_edges=None) -> CCResult:
    e = edges.shape[0] if true_edges is None else true_edges

    def outer_cond(state):
        _, changed, rounds_, _ = state
        return jnp.logical_and(changed, rounds_ < _MAX_ROUNDS)

    def outer_body(state):
        pi, _, rounds_, w = state
        new_pi = hook_edges(pi, edges, lift_steps=0)
        hook_changed = jnp.any(new_pi != pi)
        # bill the hook kernel + its host-side convergence check
        w = w.add(hook_ops=e, hook_rounds=1, sync_rounds=1)
        # Fig. 1 lines 6-10: single-level Jump until no change, a host
        # convergence check after every sweep.
        new_pi, w = compress(new_pi, w, count_syncs=True)
        return new_pi, hook_changed, rounds_ + 1, w

    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, _, _, work = jax.lax.while_loop(
        outer_cond, outer_body,
        (pi0, jnp.asarray(True), jnp.zeros((), jnp.int32),
         WorkCounters.zeros()))
    return CCResult(pi, work)


# ---------------------------------------------------------------------------
# Variant: + Multi-Jump (fused compress, device-resident)
# ---------------------------------------------------------------------------

def _cc_multijump(edges: jnp.ndarray, num_nodes: int,
                  true_edges=None) -> CCResult:
    e = edges.shape[0] if true_edges is None else true_edges

    def outer_cond(state):
        _, changed, rounds_, _ = state
        return jnp.logical_and(changed, rounds_ < _MAX_ROUNDS)

    def outer_body(state):
        pi, _, rounds_, w = state
        new_pi = hook_edges(pi, edges, lift_steps=0)
        hook_changed = jnp.any(new_pi != pi)
        # one hook kernel + ONE fused Multi-Jump kernel => 2 syncs/round
        w = w.add(hook_ops=e, hook_rounds=1, sync_rounds=2)
        new_pi, w = compress(new_pi, w, count_syncs=False)
        return new_pi, hook_changed, rounds_ + 1, w

    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, _, _, work = jax.lax.while_loop(
        outer_cond, outer_body,
        (pi0, jnp.asarray(True), jnp.zeros((), jnp.int32),
         WorkCounters.zeros()))
    return CCResult(pi, work)


# ---------------------------------------------------------------------------
# Variant: + Atomic-Hook (root-chasing hook, zero host round-trips)
# ---------------------------------------------------------------------------

def _cc_atomic_hook(edges: jnp.ndarray, num_nodes: int,
                    lift_steps: int = 2, true_edges=None) -> CCResult:
    # Atomic-Hook is the adaptive cleanup loop run from scratch over the
    # whole (single-segment) edge list.
    if true_edges is None:
        true_edges = edges.shape[0]
    ops = rounds.jnp_round_ops(lift_steps)
    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    pi, work = rounds.cleanup_rounds(pi0, edges, ops, WorkCounters.zeros(),
                                     true_edges=true_edges)
    # the whole program is one fused device loop: a single host sync
    work = work.add(sync_rounds=1)
    return CCResult(pi, work)


# ---------------------------------------------------------------------------
# Variant: adaptive segmentation (Fig. 4) — the paper's contribution
# ---------------------------------------------------------------------------

def _cc_adaptive(edges: jnp.ndarray, num_nodes: int,
                 plan: SegmentationPlan, lift_steps: int = 2,
                 true_edges=None) -> CCResult:
    """Fig. 4: for each of the s = 2|E|/|V| segments, Atomic-Hook the
    segment then fully compress, then a trailing consistency loop —
    all via the shared ``rounds.adaptive_rounds`` core, which bills
    hook_ops on true (unpadded) edges only.
    """
    pi, work = rounds.adaptive_rounds(
        edges, num_nodes, plan, lift_steps=lift_steps,
        true_edges=edges.shape[0] if true_edges is None else true_edges)
    work = work.add(sync_rounds=1)   # one jit call end-to-end
    return CCResult(pi, work)


# ---------------------------------------------------------------------------
# Public API — consumes DeviceGraph (raw arrays via the from_edges shim)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("num_nodes", "method", "num_segments",
                              "lift_steps"))
def _cc_jit(edges, true_edges, *, num_nodes, method, num_segments,
            lift_steps):
    if method == "soman":
        return _cc_soman(edges, num_nodes, true_edges)
    if method == "multijump":
        return _cc_multijump(edges, num_nodes, true_edges)
    if method == "atomic_hook":
        return _cc_atomic_hook(edges, num_nodes, lift_steps, true_edges)
    if method == "adaptive":
        plan = plan_segmentation(edges.shape[0], num_nodes, num_segments)
        return _cc_adaptive(edges, num_nodes, plan, lift_steps,
                            true_edges)
    if method == "labelprop":
        from repro.core.labelprop import _cc_labelprop
        return _cc_labelprop(edges, num_nodes, true_edges)
    raise ValueError(f"unknown method {method!r}; choose from "
                     f"{ALL_METHODS}")


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "num_segments", "lift_steps",
                              "interpret"))
def _cc_fused_jit(edges, true_edges, *, num_nodes, num_segments,
                  lift_steps, interpret):
    """method="pallas_fused": the shared adaptive composition over the
    fused segment-scan kernel — ONE pallas_call per segment scan (and
    one per cleanup round) instead of ``num_segments + jump_sweeps``
    launches. Labels and work counters are bit-compatible with the jnp
    backend (asserted in tests)."""
    plan = plan_segmentation(edges.shape[0], num_nodes, num_segments)
    ops = rounds.fused_round_ops(lift_steps, interpret=interpret)
    pi, work = rounds.adaptive_rounds(edges, num_nodes, plan, ops=ops,
                                      true_edges=true_edges)
    return CCResult(pi, work.add(sync_rounds=1))


def solve_static(
    graph,
    num_nodes: int | None = None,
    method: str = "adaptive",
    *,
    num_segments: int | None = None,
    lift_steps: int = 2,
) -> CCResult:
    """Compute connected components (the engine entry the ``repro.api``
    backends dispatch to; callers should go through the facade —
    ``repro.api.solve`` / ``Solver`` — which adds policy routing and
    inspectable plans).

    Args:
      graph: a ``repro.graphs.device.DeviceGraph`` (the native input),
        a host ``repro.graphs.format.Graph``, or a raw [E, 2] int edge
        array (one direction per undirected edge suffices; self loops
        and duplicates are harmless) — raw arrays go through the
        ``DeviceGraph.from_edges`` shim and need ``num_nodes``.
      num_nodes: |V| (static; only for raw edge arrays).
      method: one of ``soman | multijump | atomic_hook | adaptive |
        labelprop | pallas_fused``, or ``auto`` — the adaptive-selection
        policy (``repro.connectivity.policy``) picks from the graph's
        features (density 2|E|/|V| heuristic, overridden by a measured
        autotune cache when one is warm). ``pallas_fused`` runs the
        fused segment-scan kernel (one launch per scan; interpret mode
        off-TPU).
      num_segments: override the adaptive 2|E|/|V| heuristic.
      lift_steps: bounded root-chase depth in the Atomic-Hook analogue.

    Returns:
      ``CCResult(labels, work)`` with canonical min-id labels. Work is
      billed on TRUE (unpadded) edges — a padded DeviceGraph costs what
      its real edges cost.
    """
    from repro.graphs.device import as_device_graph
    g = as_device_graph(graph, num_nodes, num_segments=num_segments)
    if g.num_nodes <= 0:
        return CCResult(jnp.zeros((0,), jnp.int32), WorkCounters.zeros())
    if g.edges.shape[0] == 0 or g.true_edges_static == 0:
        return CCResult(jnp.arange(g.num_nodes, dtype=jnp.int32),
                        WorkCounters.zeros())
    if method == "auto":
        from repro.connectivity.policy import select_method
        method = select_method(g.num_nodes, g.num_edges,
                               degree_skew=g.degree_skew)
    if method in SAMPLED_METHODS:
        from repro.core.sampled import solve_sampled
        res = solve_sampled(g, num_segments=num_segments,
                            lift_steps=lift_steps,
                            fused=(method == "sampled_fused"))
        return CCResult(res.labels, res.work)
    # the common exact-sized case keeps true_edges out of the traced
    # operands entirely (None): billing stays a compile-time constant
    # and no per-call scalar device_put is paid; only padded graphs
    # thread a traced scalar
    t = g.true_edges_static
    true = None if (t is not None and t == int(g.edges.shape[0])) \
        else g.true_edges_device()
    if method == FUSED_METHOD:
        from repro.kernels import default_interpret
        return _cc_fused_jit(g.edges, true, num_nodes=g.num_nodes,
                             num_segments=g.plan.num_segments,
                             lift_steps=lift_steps,
                             interpret=default_interpret())
    return _cc_jit(g.edges, true, num_nodes=g.num_nodes, method=method,
                   num_segments=g.plan.num_segments,
                   lift_steps=lift_steps)


# ---------------------------------------------------------------------------
# Spanning-forest solves (forest recorded during hook rounds)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("num_nodes", "method", "num_segments",
                              "lift_steps"))
def _cc_forest_jit(edges, true_edges, *, num_nodes, method, num_segments,
                   lift_steps):
    """Forest-recording twin of ``_cc_jit``: identical pi updates and
    billing, with the parent-edge table threaded through every hook.
    Kept a SEPARATE program so the plain solves stay bit-identical."""
    e = edges.shape[0] if true_edges is None else true_edges
    pi0 = jnp.arange(num_nodes, dtype=jnp.int32)
    parents0 = rounds.empty_forest(num_nodes)

    if method in ("soman", "multijump"):
        count_syncs = method == "soman"
        per_round = 1 if count_syncs else 2

        def outer_cond(state):
            _, _, changed, rounds_, _ = state
            return jnp.logical_and(changed, rounds_ < _MAX_ROUNDS)

        def outer_body(state):
            pi, f, _, rounds_, w = state
            new_pi, f = rounds.hook_edges_forest(pi, f, edges,
                                                 lift_steps=0)
            hook_changed = jnp.any(new_pi != pi)
            w = w.add(hook_ops=e, hook_rounds=1, sync_rounds=per_round)
            new_pi, w = compress(new_pi, w, count_syncs=count_syncs)
            return new_pi, f, hook_changed, rounds_ + 1, w

        pi, parents, _, _, work = jax.lax.while_loop(
            outer_cond, outer_body,
            (pi0, parents0, jnp.asarray(True), jnp.zeros((), jnp.int32),
             WorkCounters.zeros()))
        return ForestResult(pi, parents, work)

    if method == "atomic_hook":
        pi, parents, work = rounds.forest_cleanup_rounds(
            pi0, parents0, edges, WorkCounters.zeros(),
            true_edges=e, lift_steps=lift_steps)
        return ForestResult(pi, parents, work.add(sync_rounds=1))

    if method == "adaptive":
        plan = plan_segmentation(edges.shape[0], num_nodes, num_segments)
        pi, parents, work = rounds.forest_adaptive_rounds(
            edges, num_nodes, plan, lift_steps=lift_steps, true_edges=e)
        return ForestResult(pi, parents, work.add(sync_rounds=1))

    raise ValueError(f"unknown forest method {method!r}; choose from "
                     f"{FOREST_METHODS}")


def solve_forest(
    graph,
    num_nodes: int | None = None,
    method: str = "adaptive",
    *,
    num_segments: int | None = None,
    lift_steps: int = 2,
) -> ForestResult:
    """Connected components WITH the spanning forest: the parent edges
    each hook round records, as a first-class product (DESIGN.md §13).

    ``method`` must be one of ``FOREST_METHODS`` — the static engines
    whose jnp hook rounds run through ``rounds.hook_edges_forest``
    (``sampled`` records during both the sampling phase and the
    residue scan). Labels are the same canonical min-id fixed point as
    the plain solves. The engine entry behind
    ``Solver.spanning_forest()``; prefer the facade.
    """
    from repro.graphs.device import as_device_graph
    if method not in FOREST_METHODS:
        raise ValueError(f"method {method!r} does not record a spanning "
                         f"forest; choose from {FOREST_METHODS}")
    g = as_device_graph(graph, num_nodes, num_segments=num_segments)
    if g.num_nodes <= 0:
        return ForestResult(jnp.zeros((0,), jnp.int32),
                            rounds.empty_forest(0), WorkCounters.zeros())
    if g.edges.shape[0] == 0 or g.true_edges_static == 0:
        return ForestResult(jnp.arange(g.num_nodes, dtype=jnp.int32),
                            rounds.empty_forest(g.num_nodes),
                            WorkCounters.zeros())
    if method == "sampled":
        from repro.core.sampled import solve_sampled
        res = solve_sampled(g, num_segments=num_segments,
                            lift_steps=lift_steps, fused=False)
        return ForestResult(res.labels, res.parents, res.work)
    t = g.true_edges_static
    true = None if (t is not None and t == int(g.edges.shape[0])) \
        else g.true_edges_device()
    return _cc_forest_jit(g.edges, true, num_nodes=g.num_nodes,
                          method=method,
                          num_segments=g.plan.num_segments,
                          lift_steps=lift_steps)


def connected_components(
    graph,
    num_nodes: int | None = None,
    method: str = "adaptive",
    *,
    num_segments: int | None = None,
    lift_steps: int = 2,
) -> CCResult:
    """DEPRECATED legacy entrypoint — forwards through the
    ``repro.api`` facade (``Solver``/``BACKENDS``), bit-identical to
    calling it directly. Use ``repro.api.solve`` (one-shot) or
    ``repro.api.Solver`` (sessions) instead."""
    from repro._deprecation import warn_once
    from repro.api import solve
    warn_once("repro.core.cc.connected_components", "repro.api.solve")
    return solve(graph, num_nodes, method,
                 num_segments=num_segments, lift_steps=lift_steps)


# ---------------------------------------------------------------------------
# Pallas-kernel backend (TPU target; interpret-mode on CPU)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("num_nodes", "num_segments", "lift_steps",
                              "interpret"))
def _cc_adaptive_pallas(edges, *, num_nodes, num_segments, lift_steps,
                        interpret):
    plan = plan_segmentation(edges.shape[0], num_nodes, num_segments)
    ops = rounds.pallas_round_ops(
        lift_steps=lift_steps,
        edge_tile=min(1024, plan.segment_size),
        node_tile=min(512, max(8, num_nodes)),
        interpret=interpret)
    pi, _ = rounds.adaptive_rounds(edges, num_nodes, plan, ops=ops,
                                   true_edges=edges.shape[0])
    return pi


def solve_pallas(graph, num_nodes: int | None = None, *,
                 num_segments: int | None = None,
                 lift_steps: int = 2,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Adaptive CC on the per-round Pallas kernel backend (hook +
    multi_jump kernels; DESIGN.md §2) — one launch per segment hook and
    per compress sweep. Prefer ``backend="pallas_fused"`` for the
    single-launch fused pipeline. Returns canonical min-id labels.
    (Engine entry for the ``pallas`` backend; go through the facade.)"""
    from repro.graphs.device import as_device_graph
    from repro.kernels import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    g = as_device_graph(graph, num_nodes, num_segments=num_segments)
    if g.num_nodes <= 0:
        return jnp.zeros((0,), jnp.int32)
    if g.edges.shape[0] == 0:
        return jnp.arange(g.num_nodes, dtype=jnp.int32)
    return _cc_adaptive_pallas(g.edges, num_nodes=g.num_nodes,
                               num_segments=g.plan.num_segments,
                               lift_steps=lift_steps, interpret=interpret)


def connected_components_pallas(graph, num_nodes: int | None = None, *,
                                num_segments: int | None = None,
                                lift_steps: int = 2,
                                interpret: bool | None = None
                                ) -> jnp.ndarray:
    """DEPRECATED legacy entrypoint — forwards through the facade's
    ``pallas`` backend; returns labels only, as before."""
    from repro._deprecation import warn_once
    from repro.api import Solver
    warn_once("repro.core.cc.connected_components_pallas",
              'repro.api.solve(..., backend="pallas")')
    res = Solver.open(graph, num_nodes, num_segments=num_segments,
                      lift_steps=lift_steps).solve(
        backend="pallas", interpret=interpret)
    return res.labels


# ---------------------------------------------------------------------------
# Host-driven execution (GPU-baseline control flow, for benchmarking)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _host_hook(pi, edges):
    new = hook_edges(pi, edges, lift_steps=0)
    return new, jnp.any(new != pi)


@functools.partial(jax.jit, donate_argnums=(0,))
def _host_jump(pi):
    new = pi[pi]
    return new, jnp.any(new != pi)


@jax.jit
def _host_compress(pi):
    pi, w = compress(pi, WorkCounters.zeros())
    return pi, w.jump_sweeps


def solve_hostloop(
    edges, num_nodes: int, method: str = "soman",
) -> tuple[np.ndarray, dict]:
    """Run the Soman baseline (or +multijump) with *host-side* control
    flow — one ``device_get`` per convergence check, faithful to the GPU
    baseline's CPU-GPU round trips. Used by the benchmarks (through the
    facade's ``hostloop`` backend) to expose the cost the paper's
    device-centric design removes.
    """
    if method not in HOSTLOOP_METHODS:
        raise ValueError(f"unknown method {method!r}; choose from "
                         f"{HOSTLOOP_METHODS}")
    edges = jnp.asarray(edges, jnp.int32).reshape(-1, 2)
    pi = jnp.arange(num_nodes, dtype=jnp.int32)
    syncs = 0
    stats = {"hook_rounds": 0, "jump_sweeps": 0}
    while True:
        pi, hook_changed = _host_hook(pi, edges)
        stats["hook_rounds"] += 1
        syncs += 1
        if method == "soman":
            while True:
                pi, jchanged = _host_jump(pi)
                stats["jump_sweeps"] += 1
                syncs += 1
                if not bool(jchanged):          # device->host round trip
                    break
        else:  # multijump: one fused compress kernel, one sync
            pi, sweeps = _host_compress(pi)
            stats["jump_sweeps"] += int(sweeps)
            syncs += 1
        if not bool(hook_changed):              # device->host round trip
            break
    stats["sync_rounds"] = syncs
    return np.asarray(pi), stats


def connected_components_hostloop(
    edges, num_nodes: int, method: str = "soman",
) -> tuple[np.ndarray, dict]:
    """DEPRECATED legacy entrypoint — forwards through the facade's
    ``hostloop`` backend; returns ``(labels, stats)`` as before."""
    from repro._deprecation import warn_once
    from repro.api import Solver
    warn_once("repro.core.cc.connected_components_hostloop",
              'Solver.plan(backend="hostloop")')
    plan = Solver.open(edges, num_nodes).plan(backend="hostloop",
                                              hostloop_method=method)
    res = plan.run()
    return np.asarray(res.labels), plan.artifacts["hostloop_stats"]


def num_components(labels) -> int:
    """Distinct-label count — thin wrapper over the on-device
    sort/segment kernel (``connectivity.queries.count_components``);
    the old host-side ``np.unique`` round trip is gone."""
    from repro.connectivity.queries import count_components
    return int(count_components(jnp.asarray(labels)))
