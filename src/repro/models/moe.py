"""Mixture-of-Experts FFN (GShard-style top-k dispatch with capacity).

Used by grok-1 (8 experts, top-2) and phi3.5-moe (16 experts, top-2).
Dispatch is sort-free: positions within each expert's capacity buffer are
computed with a one-hot cumsum, tokens are scattered into an [E, C, D]
buffer, experts run as one batched einsum, and outputs are combined with
the gate weights. Overflow tokens are dropped (standard capacity-factor
semantics); the auxiliary load-balancing loss is returned for training.

Expert parallelism comes from the *sharding* of the [E, ...] dims — see
``repro.launch.shardings``: phi (16e) shards experts over the 16-way
'model' axis (all-to-all dispatch); grok (8e) tensor-shards d_ff inside
each expert instead (8 < 16 would idle half the EP ranks).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init
from repro.models.sharding import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    dispatch_chunk: int = 16384     # tokens per scanned dispatch chunk:
                                    # bounds the [E, C, d_ff] expert
                                    # hiddens (grok prefill_32k: 172 TB
                                    # logical unchunked)


def moe_params(rng, d_model: int, cfg: MoEConfig, dtype) -> dict:
    r0, r1, r2, r3 = jax.random.split(rng, 4)
    e, f = cfg.num_experts, cfg.d_ff_expert
    return {
        "router": normal_init(r0, (d_model, e), d_model ** -0.5,
                              jnp.float32),
        "w_gate": normal_init(r1, (e, d_model, f), d_model ** -0.5, dtype),
        "w_up": normal_init(r2, (e, d_model, f), d_model ** -0.5, dtype),
        "w_down": normal_init(r3, (e, f, d_model), f ** -0.5, dtype),
    }


def moe_apply(params: dict, x: jnp.ndarray, cfg: MoEConfig
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss).

    Tokens are processed in ``dispatch_chunk``-sized chunks under
    ``lax.scan`` + remat: the [E, C, d_ff] expert hiddens exist only
    chunk-locally (forward and backward). Capacity is per chunk —
    Switch-style microbatch capacity semantics."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    chunk = min(cfg.dispatch_chunk, t)
    nchunk = -(-t // chunk)
    pad = nchunk * chunk - t
    cap = max(int(cfg.capacity_factor * chunk * k / e), 1)

    xt = x.reshape(t, d)
    xt = constrain(xt, "batch", None)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xc = xt.reshape(nchunk, chunk, d)

    def chunk_body(aux_acc, xchunk):
        out, aux = _dispatch_chunk(params, xchunk, cfg, cap)
        return aux_acc + aux, out

    body = jax.checkpoint(
        chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    aux_total, out_c = jax.lax.scan(
        body, jnp.zeros((), jnp.float32), xc)
    out = out_c.reshape(nchunk * chunk, d)[:t]
    return out.reshape(b, s, d), aux_total / nchunk


def _dispatch_chunk(params: dict, xt: jnp.ndarray, cfg: MoEConfig,
                    cap: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One token chunk through router -> dispatch -> experts -> combine
    (GShard-style sort-free dispatch via one-hot cumsum positions)."""
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.top_k

    logits = (xt.astype(jnp.float32) @ params["router"])     # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize

    # load-balancing auxiliary loss (Switch/GShard form)
    me = probs.mean(axis=0)                                  # [E]
    ce = jax.nn.one_hot(gate_idx[:, 0], e).mean(axis=0)      # top-1 frac
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    # positions within each expert buffer, priority = (choice, token id)
    flat_e = gate_idx.T.reshape(-1)                          # [kT]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # [kT, E]
    pos_all = jnp.cumsum(onehot, axis=0) - 1                 # rank in expert
    pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap                                         # overflow drop

    tok_idx = jnp.tile(jnp.arange(t), k)                     # [kT]
    buf_slot = flat_e * cap + jnp.where(keep, pos, 0)
    contrib = jnp.where(keep[:, None], xt[tok_idx], 0).astype(xt.dtype)
    buffer = jnp.zeros((e * cap, d), xt.dtype).at[buf_slot].add(
        jnp.where(keep[:, None], contrib, 0))
    buffer = buffer.reshape(e, cap, d)

    # expert computation: batched SwiGLU
    g = jnp.einsum("ecd,edf->ecf", buffer, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buffer, params["w_up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = out_buf.reshape(e * cap, d)

    # combine: gather each (token, choice) result, weight by gate value
    gathered = out_buf[buf_slot]                             # [kT, D]
    w = (gate_vals.T.reshape(-1) * keep).astype(xt.dtype)    # [kT]
    combined = jnp.zeros((t, d), xt.dtype).at[tok_idx].add(
        gathered * w[:, None])
    combined = constrain(combined, "batch", None)
    return combined, aux
