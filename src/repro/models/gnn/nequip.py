"""NequIP — O(3)-equivariant interatomic potential (arXiv:2101.03164).

Assigned config: n_layers=5, d_hidden=32 (channels per irrep),
l_max=2, n_rbf=8, cutoff=5 Å, E(3)-tensor-product interactions.

Irrep features are stored per degree l as ``[V, C, 2l+1]`` arrays (real
spherical-harmonic basis). The interaction block is the NequIP
convolution:

    m_j->i = Σ_paths  R_path(r_ij) ⊗ ( h_j^{l1} ⊗ Y^{l2}(r̂_ij) )_{l3}

where the ``l1 × l2 → l3`` couplings are contracted with **numerically
computed Gaunt coefficients** ``G[l1,l2,l3][m1,m2,m3] = ∫ Y_{l1m1}
Y_{l2m2} Y_{l3m3} dΩ`` evaluated *exactly* with Gauss–Legendre (θ) ×
trapezoid (φ) quadrature — band-limited integrands, so the rule is exact,
giving machine-precision equivariance. Gaunt coefficients differ from
Clebsch–Gordan only by per-(l1,l2,l3) scalars, which the learnable radial
weights absorb (the eSCN observation; see kernel_taxonomy §GNN).

Selection rules keep 11 parity-even paths at l_max=2. The radial network
is an MLP over a Bessel basis with the DimeNet polynomial cutoff
envelope. Nonlinearity is the NequIP gate: SiLU on scalars,
sigmoid(scalar gates) multiplying l>0 irreps. Energy is an invariant
(l=0) readout summed per graph; forces are exact ``-∂E/∂positions``
(autograd), which rotate equivariantly — both are property-tested.

Message passing is edge-gather → ``segment_sum`` (JAX has no sparse CSR;
this IS the system's message-passing substrate, shared with the
``segment_reduce`` Pallas kernel on TPU).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.gnn import common as C


# ==========================================================================
# Real spherical harmonics (orthonormal, Condon–Shortley-free real basis)
# ==========================================================================

def _sh_np(xyz: np.ndarray, l_max: int) -> list[np.ndarray]:
    """Real SH on unit vectors, numpy (used for quadrature tables)."""
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    out = [np.full(x.shape + (1,), 0.28209479177387814)]
    if l_max >= 1:
        c1 = 0.4886025119029199
        out.append(np.stack([c1 * y, c1 * z, c1 * x], axis=-1))
    if l_max >= 2:
        c2a, c2b, c2c = 1.0925484305920792, 0.31539156525252005, \
            0.5462742152960396
        out.append(np.stack([
            c2a * x * y, c2a * y * z, c2b * (3 * z * z - 1),
            c2a * x * z, c2c * (x * x - y * y)], axis=-1))
    return out[: l_max + 1]


def spherical_harmonics(unit: jnp.ndarray, l_max: int) -> list[jnp.ndarray]:
    """Real SH of unit vectors ``[E, 3]`` -> list of ``[E, 2l+1]``."""
    x, y, z = unit[..., 0], unit[..., 1], unit[..., 2]
    out = [jnp.full(x.shape + (1,), 0.28209479177387814, unit.dtype)]
    if l_max >= 1:
        c1 = 0.4886025119029199
        out.append(jnp.stack([c1 * y, c1 * z, c1 * x], axis=-1))
    if l_max >= 2:
        c2a, c2b, c2c = 1.0925484305920792, 0.31539156525252005, \
            0.5462742152960396
        out.append(jnp.stack([
            c2a * x * y, c2a * y * z, c2b * (3 * z * z - 1),
            c2a * x * z, c2c * (x * x - y * y)], axis=-1))
    return out[: l_max + 1]


@functools.lru_cache(maxsize=None)
def gaunt_tables(l_max: int) -> dict:
    """Exact Gaunt tensors {(l1,l2,l3): [2l1+1, 2l2+1, 2l3+1]} for all
    parity-even paths with l* <= l_max.

    Quadrature: Gauss–Legendre in u=cosθ (degree ≤ 3·l_max polynomial →
    n_u = 2·l_max+2 nodes exact) × uniform trapezoid in φ (trig degree ≤
    3·l_max → n_φ = 4·l_max+4 exact).
    """
    n_u = 2 * l_max + 2
    n_phi = 6 * l_max + 4
    u, wu = np.polynomial.legendre.leggauss(n_u)
    phi = 2 * np.pi * np.arange(n_phi) / n_phi
    w_phi = 2 * np.pi / n_phi
    uu, pp = np.meshgrid(u, phi, indexing="ij")          # [n_u, n_phi]
    st = np.sqrt(1 - uu * uu)
    xyz = np.stack([st * np.cos(pp), st * np.sin(pp), uu], axis=-1)
    sh = _sh_np(xyz.reshape(-1, 3), l_max)               # list [N, 2l+1]
    w = (wu[:, None] * w_phi * np.ones_like(pp)).reshape(-1)

    tables = {}
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if not (abs(l1 - l2) <= l3 <= l1 + l2):
                    continue
                if (l1 + l2 + l3) % 2 != 0:
                    continue  # parity-odd Gaunt integrals vanish
                g = np.einsum("n,na,nb,nc->abc",
                              w, sh[l1], sh[l2], sh[l3])
                g[np.abs(g) < 1e-12] = 0.0
                if np.abs(g).max() > 1e-10:
                    tables[(l1, l2, l3)] = jnp.asarray(g, jnp.float32)
    return tables


def coupling_paths(l_max: int) -> list[tuple[int, int, int]]:
    return sorted(gaunt_tables(l_max).keys())


# ==========================================================================
# Radial basis
# ==========================================================================

def bessel_basis(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """sqrt(2/c)·sin(nπr/c)/r (n = 1..n_rbf), DimeNet polynomial envelope
    (p=6). r: [E] -> [E, n_rbf]; r=0 (padding self-loops) is safe."""
    r_safe = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        n[None, :] * jnp.pi * r_safe[:, None] / cutoff) / r_safe[:, None]
    # polynomial cutoff envelope: 1 at r=0, C^2-smooth 0 at r=cutoff
    p = 6.0
    d = jnp.clip(r / cutoff, 0.0, 1.0)
    env = (1.0 - (p + 1) * (p + 2) / 2 * d ** p
           + p * (p + 2) * d ** (p + 1)
           - p * (p + 1) / 2 * d ** (p + 2))
    return basis * env[:, None] * (r > 0)[:, None]


# ==========================================================================
# Config / parameters
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 32          # channels per irrep degree
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    radial_hidden: int = 64
    remat: bool = True          # edge-chunk remat: [E, C, m] path
                                # messages are recomputed in backward,
                                # never stored (254 GiB/chip -> chunk-
                                # local on the 123M-edge ogb cell)
    edge_chunk: int = 1 << 18   # edges per scanned message chunk (the
                                # chunk backward keeps ~2 tensors per
                                # coupling path live: 22 × [chunk,32,5]
                                # f32 ≈ 3.7 GB at 2^18)
    dist_axes: tuple = ()       # shard_map mode: node/edge arrays are
                                # per-shard; each layer all-gathers
                                # feats and reduce-scatters messages
                                # over these mesh axes (one collective
                                # pair per LAYER, not per chunk — see
                                # DESIGN.md §5)
    dtype: object = jnp.float32


def init(rng, cfg: NequIPConfig) -> dict:
    paths = coupling_paths(cfg.l_max)
    n_l = cfg.l_max + 1
    c = cfg.d_hidden
    layers = []
    rngs = jax.random.split(rng, cfg.n_layers + 2)
    for li in range(cfg.n_layers):
        r = jax.random.split(rngs[li], 4 + n_l)
        lp = {
            # radial MLP: rbf -> hidden -> per-path per-channel weights
            "radial": {
                "w1": C.normal_init(r[0], (cfg.n_rbf, cfg.radial_hidden),
                                    cfg.n_rbf ** -0.5, cfg.dtype),
                "b1": jnp.zeros((cfg.radial_hidden,), cfg.dtype),
                "w2": C.normal_init(r[1],
                                    (cfg.radial_hidden, len(paths) * c),
                                    cfg.radial_hidden ** -0.5, cfg.dtype),
            },
            # per-degree self-interaction (channel mixing, m untouched)
            "self": [C.normal_init(r[2 + l], (c, c), c ** -0.5, cfg.dtype)
                     for l in range(n_l)],
            # gate scalars for l>0 irreps, produced from l=0 channels
            "gate_w": C.normal_init(r[2 + n_l], (c, (n_l - 1) * c),
                                    c ** -0.5, cfg.dtype),
            "gate_b": jnp.zeros(((n_l - 1) * c,), cfg.dtype),
        }
        layers.append(lp)
    # stack layers [L, ...] so the forward can lax.scan over them (the
    # canonical depth pattern: per-step full-size buffers are freed)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed": C.normal_init(rngs[-2], (cfg.n_species, c), 1.0, cfg.dtype),
        "layers": stacked,
        "head": {
            "w1": C.normal_init(rngs[-1], (c, c), c ** -0.5, cfg.dtype),
            "b1": jnp.zeros((c,), cfg.dtype),
            "w2": jnp.zeros((c, 1), cfg.dtype) + 1e-2,
        },
    }


# ==========================================================================
# Forward
# ==========================================================================

def _interaction(lp: dict, cfg: NequIPConfig, feats: list, sh: list,
                 rbf: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                 edge_mask: jnp.ndarray, num_nodes: int) -> list:
    """One NequIP convolution + self-interaction + gate.

    Messages are computed per EDGE CHUNK under ``lax.scan`` + remat: the
    [E, C, m] per-path message tensors (≈5 GB/chip/path on the
    123M-edge ogb cell) exist only chunk-locally, forward and backward;
    the scan carries the [V, C, m] accumulators."""
    paths = coupling_paths(cfg.l_max)
    tables = gaunt_tables(cfg.l_max)
    c = cfg.d_hidden
    n_l = cfg.l_max + 1
    e = src.shape[0]

    chunk = min(cfg.edge_chunk, e)
    nchunk = -(-e // chunk)
    pad = nchunk * chunk - e

    def pad_e(x, fill=0):
        if pad == 0:
            return x
        widths = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    src_c = pad_e(src).reshape(nchunk, chunk)
    dst_c = pad_e(dst).reshape(nchunk, chunk)
    mask_c = pad_e(edge_mask).reshape(nchunk, chunk)
    rbf_c = pad_e(rbf).reshape(nchunk, chunk, -1)
    sh_c = [pad_e(y).reshape(nchunk, chunk, -1) for y in sh]

    # distributed mode: gather the full node features ONCE per layer;
    # chunk gathers/scatters are then shard-local, and the accumulated
    # partial messages reduce-scatter back to node shards afterwards
    if cfg.dist_axes:
        feats_full = [jax.lax.all_gather(f, cfg.dist_axes, axis=0,
                                         tiled=True) for f in feats]
        v_total = feats_full[0].shape[0]
    else:
        feats_full = feats
        v_total = num_nodes

    def chunk_body(msgs, xs):
        s_, d_, em_, rb_, *ys = xs
        h = jax.nn.silu(rb_ @ lp["radial"]["w1"] + lp["radial"]["b1"])
        rw = (h @ lp["radial"]["w2"]).reshape(chunk, len(paths), c)
        rw = rw * em_[:, None, None]
        for pi, (l1, l2, l3) in enumerate(paths):
            g = tables[(l1, l2, l3)].astype(feats[0].dtype)
            x_src = feats_full[l1][s_]                       # [ch, C, m1]
            # m[e,c,m3] = Σ_{m1,m2} x·y·g, modulated by radial weight
            m = jnp.einsum("eca,eb,abm->ecm", x_src, ys[l2], g)
            m = m * rw[:, pi, :, None]
            msgs = [ms + C.scatter_sum(m, d_, v_total) if li == l3
                    else ms for li, ms in enumerate(msgs)]
        return msgs, None

    if cfg.remat:
        chunk_body = jax.checkpoint(
            chunk_body, policy=jax.checkpoint_policies.nothing_saveable)

    msgs0 = [jnp.zeros((v_total, c, 2 * l + 1), feats[0].dtype)
             for l in range(n_l)]
    msgs, _ = jax.lax.scan(chunk_body, msgs0,
                           (src_c, dst_c, mask_c, rbf_c, *sh_c))
    if cfg.dist_axes:
        # sum partials across edge shards, keep only the local node rows
        msgs = [jax.lax.psum_scatter(m, cfg.dist_axes,
                                     scatter_dimension=0, tiled=True)
                for m in msgs]

    # self-interaction (channel mix per degree) + residual
    out = []
    for l in range(n_l):
        upd = jnp.einsum("vcm,cd->vdm", msgs[l], lp["self"][l])
        out.append(feats[l] + upd)

    # gate nonlinearity: SiLU on scalars; l>0 scaled by sigmoid(gates)
    scalars = out[0][..., 0]                                  # [V, C]
    gates = jax.nn.sigmoid(scalars @ lp["gate_w"] + lp["gate_b"])
    gates = gates.reshape(num_nodes, n_l - 1, c)
    gated = [out[0].at[..., 0].set(jax.nn.silu(scalars))]
    for l in range(1, n_l):
        gated.append(out[l] * gates[:, l - 1, :, None])
    return gated


def forward(params: dict, batch: dict, cfg: NequIPConfig) -> jnp.ndarray:
    """batch: positions [V,3], species [V], src/dst [E], graph_ids [V],
    num_graphs (static via shape of batch["energy"]). Returns per-graph
    energies [G]."""
    pos = batch["positions"].astype(cfg.dtype)
    src, dst = batch["src"], batch["dst"]
    v = pos.shape[0]
    num_graphs = batch["energy"].shape[0] if "energy" in batch else \
        int(batch["graph_ids"].max()) + 1

    if cfg.dist_axes:
        # node arrays are per-shard; edges carry GLOBAL node ids
        pos_full = jax.lax.all_gather(pos, cfg.dist_axes, axis=0,
                                      tiled=True)
    else:
        pos_full = pos
    vec = pos_full[src] - pos_full[dst]                       # [E, 3]
    r = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-18)
    unit = vec / jnp.maximum(r, 1e-9)[:, None]
    in_cut = (r > 0) & (r < cfg.cutoff)
    edge_mask = in_cut.astype(cfg.dtype)
    if "edge_mask" in batch:
        edge_mask = edge_mask * batch["edge_mask"].astype(cfg.dtype)
    sh = spherical_harmonics(unit, cfg.l_max)
    rbf = bessel_basis(r, cfg.n_rbf, cfg.cutoff)

    c = cfg.d_hidden
    feats = [jnp.take(params["embed"], batch["species"], axis=0)[..., None]]
    for l in range(1, cfg.l_max + 1):
        feats.append(jnp.zeros((v, c, 2 * l + 1), cfg.dtype))

    def layer_body(feats_t, lp):
        out = _interaction(lp, cfg, list(feats_t), sh, rbf, src, dst,
                           edge_mask, v)
        return tuple(out), None

    if cfg.remat:
        # per-layer remat: the all-gathered feats_full and the full-size
        # message accumulators are rebuilt in backward; the scan saves
        # only the (shard-local) per-layer input feats
        layer_body = jax.checkpoint(
            layer_body, policy=jax.checkpoint_policies.nothing_saveable)
    feats_t, _ = jax.lax.scan(layer_body, tuple(feats),
                              params["layers"])
    feats = list(feats_t)

    # invariant readout: per-atom energy -> per-graph sum
    s = feats[0][..., 0]
    e_atom = (jax.nn.silu(s @ params["head"]["w1"] + params["head"]["b1"])
              @ params["head"]["w2"])[:, 0]
    if "node_mask" in batch:
        e_atom = e_atom * batch["node_mask"].astype(e_atom.dtype)
    energy = jax.ops.segment_sum(e_atom, batch["graph_ids"],
                                 num_segments=num_graphs)
    if cfg.dist_axes:
        energy = jax.lax.psum(energy, cfg.dist_axes)   # shard partials
    return energy


def forces(params: dict, batch: dict, cfg: NequIPConfig) -> jnp.ndarray:
    """Exact conservative forces F = -∂E_total/∂positions."""
    def e_total(pos):
        return forward(params, {**batch, "positions": pos}, cfg).sum()
    return -jax.grad(e_total)(batch["positions"].astype(cfg.dtype))


def loss_fn(params: dict, batch: dict, cfg: NequIPConfig) -> jnp.ndarray:
    """Energy MSE (per graph)."""
    pred = forward(params, batch, cfg)
    err = (pred - batch["energy"].astype(pred.dtype))
    return jnp.mean(err * err)


def param_spec(cfg: NequIPConfig, fsdp, tp: str = "model") -> dict:
    """Tiny parameter count — replicate; the graph (nodes/edges) shards."""
    return _replicated_spec(cfg)


def _replicated_spec(cfg: NequIPConfig) -> dict:
    n_l = cfg.l_max + 1
    layer = {       # leaves are layer-stacked [L, ...]
        "radial": {"w1": P(None, None, None), "b1": P(None, None),
                   "w2": P(None, None, None)},
        "self": [P(None, None, None) for _ in range(n_l)],
        "gate_w": P(None, None, None),
        "gate_b": P(None, None),
    }
    return {
        "embed": P(None, None),
        "layers": layer,
        "head": {"w1": P(None, None), "b1": P(None), "w2": P(None, None)},
    }


def batch_spec(fsdp) -> dict:
    return {"positions": P(fsdp, None), "species": P(fsdp),
            "src": P(fsdp), "dst": P(fsdp), "graph_ids": P(fsdp),
            "energy": P(None)}
