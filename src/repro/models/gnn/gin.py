"""GIN (Graph Isomorphism Network) with learnable epsilon — graph or
node classification. Assigned config: 5 layers, d_hidden=64, sum
aggregator, TU-dataset style graph classification on molecule batches.

BatchNorm (the paper's choice) is replaced by LayerNorm for clean
distributed semantics (no cross-shard batch statistics); documented in
DESIGN.md.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_in: int = 16
    d_hidden: int = 64
    n_classes: int = 2
    graph_level: bool = True
    num_graphs: int = 128           # static graph count per batch
    dtype: object = jnp.float32


def init(rng, cfg: GINConfig) -> dict:
    rngs = jax.random.split(rng, cfg.n_layers * 2 + 1)
    layers = []
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        layers.append({
            "eps": jnp.zeros((), cfg.dtype),
            "mlp1": C.linear_params(rngs[2 * i], d_prev, cfg.d_hidden,
                                    cfg.dtype),
            "mlp2": C.linear_params(rngs[2 * i + 1], cfg.d_hidden,
                                    cfg.d_hidden, cfg.dtype),
            "ln": jnp.ones((cfg.d_hidden,), cfg.dtype),
        })
        d_prev = cfg.d_hidden
    return {"layers": layers,
            "head": C.linear_params(rngs[-1], d_prev, cfg.n_classes,
                                    cfg.dtype)}


def forward(params: dict, batch: dict, cfg: GINConfig) -> jnp.ndarray:
    x = batch["x"].astype(cfg.dtype)
    src, dst = batch["src"], batch["dst"]
    v = x.shape[0]
    for lp in params["layers"]:
        agg = C.scatter_sum(x[src], dst, v)
        h = (1.0 + lp["eps"]) * x + agg
        h = jax.nn.relu(C.linear(lp["mlp1"], h))
        h = C.linear(lp["mlp2"], h)
        # LayerNorm (distributed-friendly stand-in for BN)
        mu = h.mean(-1, keepdims=True)
        var = ((h - mu) ** 2).mean(-1, keepdims=True)
        x = lp["ln"] * (h - mu) * jax.lax.rsqrt(var + 1e-5)
        x = jax.nn.relu(x)
    if cfg.graph_level:
        pooled = jax.ops.segment_sum(x, batch["graph_ids"],
                                     num_segments=cfg.num_graphs)
        return C.linear(params["head"], pooled)
    return C.linear(params["head"], x)


def loss_fn(params: dict, batch: dict, cfg: GINConfig) -> jnp.ndarray:
    logits = forward(params, batch, cfg)
    return C.nll_loss(logits, batch["y"])


def param_spec(cfg: GINConfig, fsdp, tp="model") -> dict:
    def lin():
        return {"w": P(None, None), "b": P(None)}
    return {
        "layers": [{"eps": P(), "mlp1": lin(), "mlp2": lin(),
                    "ln": P(None)} for _ in range(cfg.n_layers)],
        "head": lin(),
    }


def batch_spec(fsdp, graph_level: bool = True) -> dict:
    sp = {"src": P(fsdp), "dst": P(fsdp), "x": P(fsdp, None),
          "y": P(fsdp)}
    if graph_level:
        sp["graph_ids"] = P(fsdp)
    return sp
