"""GatedGCN (Bresson & Laurent) — edge-gated message passing with
residuals and edge-feature updates. Assigned config: 16 layers,
d_hidden=70 (benchmarking-GNNs setup).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str
    n_layers: int = 16
    d_in: int = 32
    d_edge_in: int = 8
    d_hidden: int = 70
    n_classes: int = 6
    remat: bool = True          # scan over the 16 layers + per-layer
                                # remat: the [E, d] edge states of all
                                # layers otherwise stay live through the
                                # backward (143 GiB/chip on ogb_products)
    dtype: object = jnp.float32


def init(rng, cfg: GatedGCNConfig) -> dict:
    r = jax.random.split(rng, cfg.n_layers * 5 + 3)
    layers = []
    for i in range(cfg.n_layers):
        base = 5 * i
        layers.append({
            "U": C.linear_params(r[base], cfg.d_hidden, cfg.d_hidden,
                                 cfg.dtype),
            "V": C.linear_params(r[base + 1], cfg.d_hidden, cfg.d_hidden,
                                 cfg.dtype),
            "A": C.linear_params(r[base + 2], cfg.d_hidden, cfg.d_hidden,
                                 cfg.dtype),
            "B": C.linear_params(r[base + 3], cfg.d_hidden, cfg.d_hidden,
                                 cfg.dtype),
            "Ce": C.linear_params(r[base + 4], cfg.d_hidden, cfg.d_hidden,
                                  cfg.dtype),
            "ln_h": jnp.ones((cfg.d_hidden,), cfg.dtype),
            "ln_e": jnp.ones((cfg.d_hidden,), cfg.dtype),
        })
    # stack layers [L, ...] for lax.scan over depth
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed_h": C.linear_params(r[-3], cfg.d_in, cfg.d_hidden,
                                   cfg.dtype),
        "embed_e": C.linear_params(r[-2], cfg.d_edge_in, cfg.d_hidden,
                                   cfg.dtype),
        "layers": stacked,
        "head": C.linear_params(r[-1], cfg.d_hidden, cfg.n_classes,
                                cfg.dtype),
    }


def _ln(x, g):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + 1e-5)


def forward(params: dict, batch: dict, cfg: GatedGCNConfig) -> jnp.ndarray:
    src, dst = batch["src"], batch["dst"]
    h = C.linear(params["embed_h"], batch["x"].astype(cfg.dtype))
    e = C.linear(params["embed_e"], batch["edge_attr"].astype(cfg.dtype))
    v = h.shape[0]

    def layer(carry, lp):
        h, e = carry
        e_new = (C.linear(lp["A"], h)[dst] + C.linear(lp["B"], h)[src]
                 + C.linear(lp["Ce"], e))
        e = e + jax.nn.relu(_ln(e_new, lp["ln_e"]))
        eta = jax.nn.sigmoid(e)
        msg = eta * C.linear(lp["V"], h)[src]
        den = C.scatter_sum(eta, dst, v) + 1e-6
        agg = C.scatter_sum(msg, dst, v) / den
        h_new = C.linear(lp["U"], h) + agg
        h = h + jax.nn.relu(_ln(h_new, lp["ln_h"]))
        return (h, e), None

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    (h, e), _ = jax.lax.scan(layer, (h, e), params["layers"])
    return C.linear(params["head"], h)


def loss_fn(params: dict, batch: dict, cfg: GatedGCNConfig) -> jnp.ndarray:
    logits = forward(params, batch, cfg)
    return C.nll_loss(logits, batch["y"], batch.get("node_mask"))


def param_spec(cfg: GatedGCNConfig, fsdp, tp="model") -> dict:
    def lin(stacked=False):
        if stacked:
            return {"w": P(None, None, None), "b": P(None, None)}
        return {"w": P(None, None), "b": P(None)}
    return {
        "embed_h": lin(), "embed_e": lin(),
        "layers": {k: lin(stacked=True)
                   for k in ("U", "V", "A", "B", "Ce")}
                  | {"ln_h": P(None, None), "ln_e": P(None, None)},
        "head": lin(),
    }


def batch_spec(fsdp) -> dict:
    return {"src": P(fsdp), "dst": P(fsdp), "x": P(fsdp, None),
            "edge_attr": P(fsdp, None), "y": P(fsdp),
            "node_mask": P(fsdp)}
