"""Shared GNN machinery: edge-index message passing via segment ops.

JAX sparse is BCOO-only; message passing here is gather(src) ->
transform -> ``jax.ops.segment_sum`` scatter(dst), exactly the pattern
the ``segment_reduce`` Pallas kernel accelerates on TPU (DESIGN.md §3).
All functions take a ``batch`` dict with static-shape arrays:

  src, dst   int32 [E]      (message edges; padded edges may point at a
                             dummy node masked via ``edge_mask``)
  x          float  [V, d]  node features
  edge_attr  float  [E, de] (optional)
  y          labels (node-level [V] or graph-level [G])
  graph_ids  int32 [V]      (block-diagonal batches; optional)
  node_mask  bool [V]       (optional: valid nodes)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init


def scatter_sum(values: jnp.ndarray, dst: jnp.ndarray,
                num_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(values, dst, num_segments=num_nodes)


def scatter_mean(values: jnp.ndarray, dst: jnp.ndarray,
                 num_nodes: int) -> jnp.ndarray:
    s = scatter_sum(values, dst, num_nodes)
    deg = jax.ops.segment_sum(jnp.ones((values.shape[0],), values.dtype),
                              dst, num_segments=num_nodes)
    return s / jnp.maximum(deg, 1.0)[:, None]


def scatter_softmax(scores: jnp.ndarray, dst: jnp.ndarray,
                    num_nodes: int) -> jnp.ndarray:
    """Edge-softmax over incoming edges per destination node."""
    mx = jax.ops.segment_max(scores, dst, num_segments=num_nodes)
    ex = jnp.exp(scores - mx[dst])
    den = jax.ops.segment_sum(ex, dst, num_segments=num_nodes)
    return ex / jnp.maximum(den[dst], 1e-9)


def linear_params(rng, din: int, dout: int, dtype=jnp.float32,
                  bias: bool = True) -> dict:
    r1, _ = jax.random.split(rng)
    p = {"w": normal_init(r1, (din, dout), din ** -0.5, dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def nll_loss(logits: jnp.ndarray, labels: jnp.ndarray,
             mask: jnp.ndarray | None = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
