"""GraphSAGE (mean aggregator) — node classification.

Config (assigned): 2 layers, d_hidden=128, sample sizes 25-10 (the
sampler lives in ``repro.graphs.sampler``; this model consumes either a
full graph or sampled blocks — both are edge lists).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    dtype: object = jnp.float32


def init(rng, cfg: SAGEConfig) -> dict:
    rngs = jax.random.split(rng, cfg.n_layers * 2 + 1)
    layers = []
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        layers.append({
            "w_self": C.linear_params(rngs[2 * i], d_prev, cfg.d_hidden,
                                      cfg.dtype),
            "w_neigh": C.linear_params(rngs[2 * i + 1], d_prev,
                                       cfg.d_hidden, cfg.dtype),
        })
        d_prev = cfg.d_hidden
    return {"layers": layers,
            "head": C.linear_params(rngs[-1], d_prev, cfg.n_classes,
                                    cfg.dtype)}


def forward(params: dict, batch: dict, cfg: SAGEConfig) -> jnp.ndarray:
    x = batch["x"].astype(cfg.dtype)
    src, dst = batch["src"], batch["dst"]
    v = x.shape[0]
    for i, lp in enumerate(params["layers"]):
        neigh = C.scatter_mean(x[src], dst, v)
        x = C.linear(lp["w_self"], x) + C.linear(lp["w_neigh"], neigh)
        x = jax.nn.relu(x)
        # L2 normalize (GraphSAGE §3.1)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True),
                            1e-6)
    return C.linear(params["head"], x)


def forward_sampled(params: dict, batch: dict, cfg: SAGEConfig
                    ) -> jnp.ndarray:
    """Layered-block forward (DGL-style): layer i aggregates over the
    sampler's block-i edges (``src_i``/``dst_i``, local node ids into the
    shared frontier array). Seeds occupy the first rows; outputs are read
    through ``node_mask``."""
    x = batch["x"].astype(cfg.dtype)
    v = x.shape[0]
    for i, lp in enumerate(params["layers"]):
        src, dst = batch[f"src_{i}"], batch[f"dst_{i}"]
        neigh = C.scatter_mean(x[src], dst, v)
        x = C.linear(lp["w_self"], x) + C.linear(lp["w_neigh"], neigh)
        x = jax.nn.relu(x)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True),
                            1e-6)
    return C.linear(params["head"], x)


def loss_fn(params: dict, batch: dict, cfg: SAGEConfig) -> jnp.ndarray:
    fwd = forward_sampled if "src_0" in batch else forward
    logits = fwd(params, batch, cfg)
    return C.nll_loss(logits, batch["y"], batch.get("node_mask"))


def param_spec(cfg: SAGEConfig, fsdp, tp="model") -> dict:
    """Hidden dims are tiny — replicate params, shard the graph."""
    def lin(spec_w):
        return {"w": spec_w, "b": P(None)}
    return {
        "layers": [{"w_self": lin(P(None, None)),
                    "w_neigh": lin(P(None, None))}
                   for _ in range(cfg.n_layers)],
        "head": lin(P(None, None)),
    }


def batch_spec(fsdp) -> dict:
    # nodes and edges sharded over the data axes; XLA inserts the
    # all-reduce for cross-shard segment sums
    return {"src": P(fsdp), "dst": P(fsdp), "x": P(fsdp, None),
            "y": P(fsdp), "node_mask": P(fsdp)}
