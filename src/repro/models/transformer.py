"""Unified LM transformer covering the five assigned LM architectures.

One config-driven implementation provides:
  * GQA attention with optional QKV bias (qwen2.5),
  * alternating local(sliding-window)+global layers, logit soft-capping,
    post-norms and embedding scaling (gemma2),
  * MLA — multi-head latent attention with low-rank Q/KV compression and
    decoupled RoPE (minicpm3),
  * MoE FFN via ``repro.models.moe`` (grok-1, phi3.5-moe).

Layers are stacked and ``lax.scan``-ed (for the ``local_global`` pattern
the scan unit is a (local, global) *pair*), so compile time and HLO size
are O(1) in depth — a requirement for the 64-layer dry-run cells.

Everything is pure functions over parameter pytrees; shardings live in
``param_spec`` / ``batch_spec`` below and are consumed by the launcher.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.moe import MoEConfig, moe_apply, moe_params
from repro.models.sharding import constrain


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window: int = 0                   # sliding window width (local layers)
    layer_pattern: str = "global"     # "global" | "local_global"
    attention: str = "gqa"            # "gqa" | "mla"
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    post_norm: bool = False           # gemma2-style post-norms
    embed_scale: bool = False         # multiply embedding by sqrt(D)
    tie_embed: bool = False           # lm_head = embed.T (gemma2)
    act: str = "silu"
    dtype: Any = jnp.bfloat16
    remat: bool = True
    seq_parallel: bool = True   # shard scan-saved residuals over 'model'
                                # (Megatron-SP). Refuted for qwen2.5:
                                # GSPMD re-gathers cost more than the
                                # carries save (see EXPERIMENTS §Perf)

    @property
    def n_stack(self) -> int:
        if self.layer_pattern == "local_global":
            assert self.n_layers % 2 == 0
            return self.n_layers // 2
        return self.n_layers

    @property
    def padded_vocab(self) -> int:
        """Embedding/head rows padded to a multiple of 256 so the vocab
        dim shards evenly over the 16-way tensor axis (extra logits are
        never targeted; standard practice). The *logical* vocab is
        unchanged."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        if self.attention == "mla":
            return self.n_heads * (self.mla.qk_nope_dim
                                   + self.mla.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def o_in_dim(self) -> int:
        if self.attention == "mla":
            return self.n_heads * self.mla.v_head_dim
        return self.n_heads * self.head_dim


# ==========================================================================
# Parameter construction
# ==========================================================================

def _attn_params(rng, cfg: LMConfig) -> dict:
    d = cfg.d_model
    s = d ** -0.5
    if cfg.attention == "mla":
        m = cfg.mla
        r = jax.random.split(rng, 5)
        return {
            "q_a": L.normal_init(r[0], (d, m.q_lora_rank), s, cfg.dtype),
            "q_norm": jnp.zeros((m.q_lora_rank,), cfg.dtype),
            "q_b": L.normal_init(
                r[1], (m.q_lora_rank, cfg.q_dim),
                m.q_lora_rank ** -0.5, cfg.dtype),
            "kv_a": L.normal_init(
                r[2], (d, m.kv_lora_rank + m.qk_rope_dim), s, cfg.dtype),
            "kv_norm": jnp.zeros((m.kv_lora_rank,), cfg.dtype),
            "kv_b": L.normal_init(
                r[3], (m.kv_lora_rank,
                       cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)),
                m.kv_lora_rank ** -0.5, cfg.dtype),
            "wo": L.normal_init(r[4], (cfg.o_in_dim, d),
                                cfg.o_in_dim ** -0.5, cfg.dtype),
        }
    r = jax.random.split(rng, 4)
    kv_dim = cfg.n_kv_heads * cfg.head_dim
    p = {
        "wq": L.normal_init(r[0], (d, cfg.q_dim), s, cfg.dtype),
        "wk": L.normal_init(r[1], (d, kv_dim), s, cfg.dtype),
        "wv": L.normal_init(r[2], (d, kv_dim), s, cfg.dtype),
        "wo": L.normal_init(r[3], (cfg.q_dim, d),
                            cfg.q_dim ** -0.5, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), cfg.dtype)
        p["bk"] = jnp.zeros((kv_dim,), cfg.dtype)
        p["bv"] = jnp.zeros((kv_dim,), cfg.dtype)
    return p


def _layer_params(rng, cfg: LMConfig) -> dict:
    r_attn, r_ffn = jax.random.split(rng)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": _attn_params(r_attn, cfg),
    }
    if cfg.moe is not None:
        p["moe"] = moe_params(r_ffn, cfg.d_model, cfg.moe, cfg.dtype)
    else:
        p["mlp"] = L.gated_mlp_params(r_ffn, cfg.d_model, cfg.d_ff,
                                      cfg.dtype)
    if cfg.post_norm:
        p["ln1_post"] = jnp.zeros((cfg.d_model,), cfg.dtype)
        p["ln2_post"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    return p


def init(rng, cfg: LMConfig) -> dict:
    r_embed, r_blocks, r_head = jax.random.split(rng, 3)

    def one_block(r):
        if cfg.layer_pattern == "local_global":
            rl, rg = jax.random.split(r)
            return {"local": _layer_params(rl, cfg),
                    "global": _layer_params(rg, cfg)}
        return _layer_params(r, cfg)

    block_rngs = jax.random.split(r_blocks, cfg.n_stack)
    blocks = jax.vmap(one_block)(block_rngs)
    out = {
        "embed": L.normal_init(r_embed, (cfg.padded_vocab, cfg.d_model),
                               0.02, cfg.dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embed:
        out["lm_head"] = L.normal_init(r_head,
                                       (cfg.d_model, cfg.padded_vocab),
                                       cfg.d_model ** -0.5, cfg.dtype)
    return out


def param_count(cfg: LMConfig) -> int:
    import math
    params = jax.eval_shape(lambda r: init(r, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(x.shape) for x in jax.tree.leaves(params))


# ==========================================================================
# Forward pass
# ==========================================================================

def _gqa_project_kv(p: dict, cfg: LMConfig, x: jnp.ndarray,
                    positions: jnp.ndarray
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> roped k, v: [B, S, Hkv, dh]."""
    b, s, _ = x.shape
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _gqa_attention(p: dict, cfg: LMConfig, x: jnp.ndarray,
                   positions: jnp.ndarray, window: int,
                   kv_override=None, kv_mask=None, k_positions=None
                   ) -> jnp.ndarray:
    """x: [B, S, D]. kv_override: (k, v) from a decode cache (already
    roped); ``positions`` may be [S] or per-request [B, S]."""
    b, s, d = x.shape
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    if kv_override is None:
        k, v = _gqa_project_kv(p, cfg, x, positions)
        k_positions = positions
    else:
        k, v = kv_override
    out = L.multi_head_attention(
        q, k, v, q_positions=positions, k_positions=k_positions,
        window=window, attn_softcap=cfg.attn_softcap, kv_mask=kv_mask)
    return out.reshape(b, s, cfg.q_dim) @ p["wo"]


def _mla_project(p: dict, cfg: LMConfig, x: jnp.ndarray,
                 positions: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (normed latent ckv [B,S,r], roped k_rope
    [B,S,rope]) — exactly what the MLA decode cache stores."""
    m = cfg.mla
    ckv_full = x @ p["kv_a"]                        # [B,S,kv_lora+rope]
    ckv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    ckv = L.rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions,
                          cfg.rope_theta)[:, :, 0, :]  # [B,S,rope]
    return ckv, k_rope


def _mla_attention(p: dict, cfg: LMConfig, x: jnp.ndarray,
                   positions: jnp.ndarray,
                   cache_override=None, kv_mask=None, k_positions=None
                   ) -> jnp.ndarray:
    """MLA: low-rank compressed Q/KV with decoupled RoPE (DeepSeek-V2
    style). ``cache_override``: (ckv, k_rope) decode cache — k/v are
    re-expanded from the cached latent each step (the cache-lean
    variant; the absorbed-matmul variant is a §Perf item)."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    cq = L.rms_norm(x @ p["q_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["q_b"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    if cache_override is None:
        ckv, k_rope = _mla_project(p, cfg, x, positions)
        k_positions = positions
    else:
        ckv, k_rope = cache_override                # pre-normed / pre-roped
    k_rope = k_rope[:, :, None, :]                  # [B,Sk,1,rope]
    kv = (ckv @ p["kv_b"]).reshape(
        ckv.shape[0], ckv.shape[1], h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(
            k_rope, k_nope.shape[:3] + (m.qk_rope_dim,))], axis=-1)
    out = L.multi_head_attention(
        q, k, v, q_positions=positions, k_positions=k_positions,
        window=0, attn_softcap=cfg.attn_softcap,
        sm_scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5, kv_mask=kv_mask)
    return out.reshape(b, s, cfg.o_in_dim) @ p["wo"]


def _ffn(p: dict, cfg: LMConfig, x: jnp.ndarray
         ) -> tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.moe is not None:
        return moe_apply(p["moe"], x, cfg.moe)
    return L.gated_mlp_apply(p["mlp"], x, cfg.act), jnp.zeros(
        (), jnp.float32)


def _layer_apply(p: dict, cfg: LMConfig, x: jnp.ndarray,
                 positions: jnp.ndarray, window: int
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps, plus_one=cfg.post_norm)
    if cfg.attention == "mla":
        a = _mla_attention(p["attn"], cfg, h, positions)
    else:
        a = _gqa_attention(p["attn"], cfg, h, positions, window)
    if cfg.post_norm:
        a = L.rms_norm(a, p["ln1_post"], cfg.norm_eps, plus_one=True)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps, plus_one=cfg.post_norm)
    f, aux = _ffn(p, cfg, h)
    if cfg.post_norm:
        f = L.rms_norm(f, p["ln2_post"], cfg.norm_eps, plus_one=True)
    return x + f, aux


def forward_hidden(params: dict, tokens: jnp.ndarray, cfg: LMConfig
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: [B, S] -> (final hidden states [B, S, D], aux_loss)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", None, None)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)

    def block_fn(x, bp):
        if cfg.layer_pattern == "local_global":
            x, aux1 = _layer_apply(bp["local"], cfg, x, positions,
                                   cfg.window)
            x, aux2 = _layer_apply(bp["global"], cfg, x, positions, 0)
            return x, aux1 + aux2
        return _layer_apply(bp, cfg, x, positions, 0 if cfg.window == 0
                            else cfg.window)

    if cfg.remat:
        block_fn = jax.checkpoint(
            block_fn, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(x, bp):
        x, aux = block_fn(x, bp)
        if cfg.seq_parallel:
            # sequence-parallel residual storage (Megatron-SP): the
            # scan-saved [B, S, D] carries shard over the tensor axis
            # between layers — 16x less carry memory; XLA re-gathers
            # inside the block where attention needs the full sequence
            x = constrain(x, "batch", "tp", None)
        return x, aux

    x, auxes = jax.lax.scan(scan_body, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps,
                   plus_one=cfg.post_norm)
    return x, auxes.sum()


def forward(params: dict, tokens: jnp.ndarray, cfg: LMConfig
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: [B, S] -> (logits [B, S, V], aux_loss)."""
    x, aux = forward_hidden(params, tokens, cfg)
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = x @ head
    logits = constrain(logits, "batch", None, "tp")
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


def loss_fn(params: dict, batch: dict, cfg: LMConfig,
            seq_chunk: int = 512) -> jnp.ndarray:
    """batch: {"tokens": [B,S+1] int32} — next-token CE via the
    seq-chunked head+loss: the [B,S,V] fp32 logits never materialize
    (layers.chunked_lm_loss)."""
    tokens = batch["tokens"]
    x, aux = forward_hidden(params, tokens[:, :-1], cfg)
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    ce = L.chunked_lm_loss(x, head, tokens[:, 1:],
                           final_softcap=cfg.final_softcap,
                           seq_chunk=min(seq_chunk, x.shape[1]))
    return ce + aux


def model_flops_per_token(cfg: LMConfig) -> float:
    """Analytic MODEL_FLOPS/token = 6·N_active (+ attention terms are
    reported separately in the roofline tables)."""
    n = param_count(cfg)
    if cfg.moe is not None:
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        expert = 3 * cfg.d_model * cfg.moe.d_ff_expert
        n_active = n - cfg.n_layers * (e - k) * expert
    else:
        n_active = n
    return 6.0 * n_active


# ==========================================================================
# KV-cache serving path (prefill + decode)
# ==========================================================================
#
# Requests are RIGHT-padded to the prompt buffer; every position's slot
# equals its sequence index (full caches) or index % window (ring caches
# for gemma2's local layers). Right-padding means the plain causal mask
# is already per-request correct during prefill: padding keys sit at
# positions >= the request length, and no real query position ever
# attends forward. At decode, per-request positions ([B, 1]) rope the
# query, and stored per-slot positions mask the cache — a stale slot is
# overwritten on exactly the step its position would first become
# causally visible (see serving/engine.py for the proof sketch).

def _layer_cache_struct(cfg: LMConfig, batch: int, buf: int, window: int
                        ) -> dict:
    n = min(window, buf) if window > 0 else buf
    if cfg.attention == "mla":
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, n, m.kv_lora_rank), cfg.dtype),
            "kr": jnp.zeros((batch, n, m.qk_rope_dim), cfg.dtype),
        }
    return {
        "k": jnp.zeros((batch, n, cfg.n_kv_heads, cfg.head_dim),
                       cfg.dtype),
        "v": jnp.zeros((batch, n, cfg.n_kv_heads, cfg.head_dim),
                       cfg.dtype),
    }


def init_cache(cfg: LMConfig, batch: int, buf: int) -> dict:
    """Decode cache for ``batch`` request slots of ``buf`` positions.

    Layer entries are stacked [n_stack, ...] so the decode step scans
    them alongside the stacked block params. ``pos`` arrays hold the
    sequence position stored in each slot (-1 = empty).
    """
    def stack(struct_fn):
        return jax.tree.map(
            lambda leaf: jnp.broadcast_to(
                leaf, (cfg.n_stack,) + leaf.shape).copy(),
            struct_fn)

    if cfg.layer_pattern == "local_global":
        entry = {
            "local": stack(_layer_cache_struct(cfg, batch, buf,
                                               cfg.window)),
            "global": stack(_layer_cache_struct(cfg, batch, buf, 0)),
        }
        pos = {
            "pos": jnp.full((batch, buf), -1, jnp.int32),
            "pos_local": jnp.full((batch, min(cfg.window, buf)), -1,
                                  jnp.int32),
        }
    else:
        entry = stack(_layer_cache_struct(cfg, batch, buf, 0))
        pos = {"pos": jnp.full((batch, buf), -1, jnp.int32)}
    return {"layers": entry, **pos}


def _write_full(buf_arr, new, start):
    """Write new [B, S, ...] at slots [start, start+S)."""
    return jax.lax.dynamic_update_slice_in_dim(buf_arr, new, start, axis=1)


def _write_ring(buf_arr, new, positions):
    """Scatter new [B, S, ...] at per-request slots positions %% W.

    Negative positions are DROPPED (scattered out of bounds): right-padded
    prefill garbage must not be written at all — slot g %% W is shared with
    real position g - W, so a masked-but-written garbage key would evict a
    real key that is still inside the sliding window.
    """
    w = buf_arr.shape[1]
    slots = jnp.where(positions >= 0, positions % w, w)   # w = OOB -> drop
    b = buf_arr.shape[0]
    bi = jnp.arange(b, dtype=jnp.int32)[:, None]
    return buf_arr.at[bi, slots].set(new.astype(buf_arr.dtype),
                                     mode="drop")


def _ring_prefill_pos(prefill_len: int, width: int, batch: int
                      ) -> jnp.ndarray:
    """Fallback prefill write positions for a ring of ``width`` slots when
    the caller supplied no per-request lengths: the last ``width`` buffer
    positions, everything earlier dropped (-1)."""
    idx = jnp.arange(prefill_len, dtype=jnp.int32)[None, :]
    pos = jnp.where(idx >= prefill_len - width, idx, -1)
    return jnp.broadcast_to(pos, (batch, prefill_len))


def _attn_cached(p, cfg: LMConfig, h, positions, window, lc, k_pos,
                 prefill_len: int, ring_pos=None):
    """Attention through the cache. ``prefill_len`` > 0: prefill mode
    (positions [S] = arange, write slots [0, S)); else decode (positions
    [B, 1], per-request scatter). Returns (attn_out, new_layer_cache).

    Prefill *attends with the fresh full-length k/v* and only WRITES the
    cache: a ring cache holds just the last W positions, but an early
    prefill query needs keys older than that — reading back through the
    cache would be wrong (and for full caches, fresh k/v skips the
    read-back of empty padded slots).

    ``ring_pos`` ([B, P] int32, -1 = drop) gives the per-request cache
    write positions during a ring prefill: for a right-padded request of
    real length L only positions [L - W, L) are written, so padding
    garbage can never evict a real key whose position is still inside
    the sliding window."""
    ring = window > 0
    if cfg.attention == "mla":
        ckv_new, kr_new = _mla_project(p["attn"], cfg, h, positions)
        if prefill_len > 0:
            if ring:
                if ring_pos is None:
                    ring_pos = _ring_prefill_pos(
                        prefill_len, lc["ckv"].shape[1], h.shape[0])
                lc = {"ckv": _write_ring(lc["ckv"], ckv_new, ring_pos),
                      "kr": _write_ring(lc["kr"], kr_new, ring_pos)}
            else:
                lc = {"ckv": _write_full(lc["ckv"], ckv_new, 0),
                      "kr": _write_full(lc["kr"], kr_new, 0)}
            out = _mla_attention(p["attn"], cfg, h, positions,
                                 cache_override=(ckv_new, kr_new),
                                 k_positions=positions)
            return out, lc
        writer = _write_ring if ring else \
            (lambda b_, n_, pos_: b_.at[
                jnp.arange(b_.shape[0])[:, None], pos_].set(
                    n_.astype(b_.dtype)))
        lc = {"ckv": writer(lc["ckv"], ckv_new, positions),
              "kr": writer(lc["kr"], kr_new, positions)}
        out = _mla_attention(p["attn"], cfg, h, positions,
                             cache_override=(lc["ckv"], lc["kr"]),
                             k_positions=k_pos)
        return out, lc

    k_new, v_new = _gqa_project_kv(p["attn"], cfg, h, positions)
    if prefill_len > 0:
        if ring:
            if ring_pos is None:
                ring_pos = _ring_prefill_pos(
                    prefill_len, lc["k"].shape[1], h.shape[0])
            lc = {"k": _write_ring(lc["k"], k_new, ring_pos),
                  "v": _write_ring(lc["v"], v_new, ring_pos)}
        else:
            lc = {"k": _write_full(lc["k"], k_new, 0),
                  "v": _write_full(lc["v"], v_new, 0)}
        out = _gqa_attention(p["attn"], cfg, h, positions, window,
                             kv_override=(k_new, v_new),
                             k_positions=positions)
        return out, lc
    if ring:
        lc = {"k": _write_ring(lc["k"], k_new, positions),
              "v": _write_ring(lc["v"], v_new, positions)}
    else:
        bi = jnp.arange(h.shape[0])[:, None]
        lc = {"k": lc["k"].at[bi, positions].set(
                  k_new.astype(lc["k"].dtype)),
              "v": lc["v"].at[bi, positions].set(
                  v_new.astype(lc["v"].dtype))}
    out = _gqa_attention(p["attn"], cfg, h, positions, window,
                         kv_override=(lc["k"], lc["v"]),
                         k_positions=k_pos)
    return out, lc


def _layer_apply_cached(p, cfg: LMConfig, x, positions, window, lc,
                        k_pos, prefill_len: int, ring_pos=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps, plus_one=cfg.post_norm)
    a, lc = _attn_cached(p, cfg, h, positions, window, lc, k_pos,
                         prefill_len, ring_pos)
    if cfg.post_norm:
        a = L.rms_norm(a, p["ln1_post"], cfg.norm_eps, plus_one=True)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps, plus_one=cfg.post_norm)
    f, _ = _ffn(p, cfg, h)
    if cfg.post_norm:
        f = L.rms_norm(f, p["ln2_post"], cfg.norm_eps, plus_one=True)
    return x + f, lc


def forward_with_cache(params: dict, tokens: jnp.ndarray, cfg: LMConfig,
                       cache: dict, positions: jnp.ndarray,
                       valid_len: jnp.ndarray | None = None
                       ) -> tuple[jnp.ndarray, dict]:
    """Cache-threaded forward.

    Prefill: tokens [B, P], positions = arange(P) (1D). ``valid_len``
    ([B] int32, optional) gives per-request true prompt lengths for
    RIGHT-padded prefill: ring (sliding-window) caches then write only
    positions [len_b - W, len_b) per request, so padding garbage at
    positions >= len_b can never evict a real in-window key (slot g %% W
    collides with position g - W). Without it, every request is assumed
    full-length (the old behavior — correct only when lengths == P).
    Decode:  tokens [B, 1], positions [B, 1] (per-request).
    Returns (logits [B, S, V], updated cache).
    """
    prefill_len = tokens.shape[1] if positions.ndim == 1 else 0
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", None, None)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    new_pos = dict(cache)
    ring_pos = None
    if prefill_len > 0:
        p_idx = jnp.arange(prefill_len, dtype=jnp.int32)
        pos_buf = _write_full(cache["pos"],
                              jnp.broadcast_to(p_idx, tokens.shape), 0)
        k_pos_global = pos_buf
        if valid_len is None:
            vl = jnp.full((tokens.shape[0], 1), prefill_len, jnp.int32)
        else:
            vl = jnp.asarray(valid_len, jnp.int32).reshape(-1, 1)
        if "pos_local" in cache:
            w = cache["pos_local"].shape[1]
            idx_b = jnp.broadcast_to(p_idx, tokens.shape)
            ring_pos = jnp.where((idx_b >= vl - w) & (idx_b < vl),
                                 idx_b, -1)
            pos_local = _write_ring(cache["pos_local"], ring_pos,
                                    ring_pos)
            new_pos["pos_local"] = pos_local
            k_pos_local = pos_local
        elif cfg.window > 0:
            # uniform-window models keep a full-size cache (one slot per
            # position, no eviction) — only mask the padding writes
            idx_b = jnp.broadcast_to(p_idx, tokens.shape)
            ring_pos = jnp.where(idx_b < vl, idx_b, -1)
        new_pos["pos"] = pos_buf
    else:
        bi = jnp.arange(tokens.shape[0])[:, None]
        pos_buf = cache["pos"].at[bi, positions].set(positions)
        new_pos["pos"] = pos_buf
        k_pos_global = pos_buf
        if "pos_local" in cache:
            pos_local = _write_ring(cache["pos_local"], positions,
                                    positions)
            new_pos["pos_local"] = pos_local
            k_pos_local = pos_local

    def body(x, xs):
        bp, lc = xs
        if cfg.layer_pattern == "local_global":
            x, lc_l = _layer_apply_cached(
                bp["local"], cfg, x, positions, cfg.window, lc["local"],
                k_pos_local, prefill_len, ring_pos)
            x, lc_g = _layer_apply_cached(
                bp["global"], cfg, x, positions, 0, lc["global"],
                k_pos_global, prefill_len)
            return x, {"local": lc_l, "global": lc_g}
        x, lc = _layer_apply_cached(bp, cfg, x, positions, cfg.window,
                                    lc, k_pos_global, prefill_len,
                                    ring_pos)
        return x, lc

    x, new_layers = jax.lax.scan(body, x, (params["blocks"],
                                           cache["layers"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps,
                   plus_one=cfg.post_norm)
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = x @ head
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    new_pos["layers"] = new_layers
    return logits, new_pos


# ==========================================================================
# Sharding specs (FSDP over data axes × TP over 'model')
# ==========================================================================

def param_spec(cfg: LMConfig, fsdp: Any, tp: str = "model") -> dict:
    """PartitionSpec pytree matching ``init``'s structure.

    ``fsdp``: axis name (or tuple) the parameter d_model/d_ff dims are
    ZeRO-3 sharded over; ``tp``: the tensor-parallel axis (heads / ffn /
    vocab dims).
    """
    def attn_spec():
        if cfg.attention == "mla":
            return {
                "q_a": P(None, fsdp, None),
                "q_norm": P(None, None),
                "q_b": P(None, fsdp, tp),
                "kv_a": P(None, fsdp, None),
                "kv_norm": P(None, None),
                "kv_b": P(None, fsdp, tp),
                "wo": P(None, tp, fsdp),
            }
        s = {
            "wq": P(None, fsdp, tp),
            "wk": P(None, fsdp, tp),
            "wv": P(None, fsdp, tp),
            "wo": P(None, tp, fsdp),
        }
        if cfg.qkv_bias:
            s.update({"bq": P(None, tp), "bk": P(None, tp),
                      "bv": P(None, tp)})
        return s

    def layer_spec():
        sp = {
            "ln1": P(None, None),
            "ln2": P(None, None),
            "attn": attn_spec(),
        }
        if cfg.moe is not None:
            e = cfg.moe.num_experts
            if e % 16 == 0:
                # expert parallelism over the 16-way tp axis
                sp["moe"] = {
                    "router": P(None, fsdp, None),
                    "w_gate": P(None, tp, fsdp, None),
                    "w_up": P(None, tp, fsdp, None),
                    "w_down": P(None, tp, None, fsdp),
                }
            else:
                # tensor parallelism inside each expert (grok: 8 experts)
                sp["moe"] = {
                    "router": P(None, fsdp, None),
                    "w_gate": P(None, None, fsdp, tp),
                    "w_up": P(None, None, fsdp, tp),
                    "w_down": P(None, None, tp, fsdp),
                }
        else:
            sp["mlp"] = {
                "w_gate": P(None, fsdp, tp),
                "w_up": P(None, fsdp, tp),
                "w_down": P(None, tp, fsdp),
            }
        if cfg.post_norm:
            sp["ln1_post"] = P(None, None)
            sp["ln2_post"] = P(None, None)
        return sp

    block = layer_spec()
    if cfg.layer_pattern == "local_global":
        block = {"local": layer_spec(), "global": layer_spec()}
    out = {
        "embed": P(tp, fsdp),
        "blocks": block,
        "final_norm": P(None),
    }
    if not cfg.tie_embed:
        out["lm_head"] = P(fsdp, tp)
    return out


def batch_spec(fsdp: Any) -> dict:
    return {"tokens": P(fsdp, None)}
