"""DCN-v2 (arXiv:2008.13535) — deep & cross network for CTR / ranking.

Assigned config: 13 dense features, 26 sparse features, embed_dim=16,
3 cross layers, MLP tower 1024-1024-512, cross interaction.

The sparse hot path is the **embedding lookup**. JAX has no native
EmbeddingBag or CSR sparse: lookups here are ``jnp.take`` over a single
*fused* table (all 26 feature tables concatenated row-wise, per-feature
row offsets added to the indices), and multi-hot bags reduce with
``jax.ops.segment_sum`` — this IS part of the system (kernel_taxonomy
§RecSys); the TPU fast path is the ``embedding_bag`` Pallas kernel.

A fused table makes row-sharding uniform: ``P('model', None)`` shards the
one [total_rows, 16] array across the tensor axis, and every lookup is a
single sharded gather (XLA inserts the index all-gather / result
all-to-all), instead of 26 differently-shaped gathers.

Cross network (DCN-v2, full-rank W):
    x_{l+1} = x_0 ⊙ (W_l x_l + b_l) + x_l
runs in parallel with the deep MLP tower; their concatenation feeds the
final logit (the paper's "parallel" structure). Loss is BCE.

``retrieval_scores`` scores one query against N candidates with a single
batched matmul (the ``retrieval_cand`` shape: 1 × 1M candidates).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


# Criteo-like per-feature table sizes (hashed); the full config's 26
# tables sum to ~54M rows x 16 dims = ~3.5 GB fp32 (row-sharded 16-way).
CRITEO_TABLE_SIZES = (
    4_000_000, 25_000, 15_000, 7_000, 19_000, 4, 7_000, 1_500, 60,
    3_500_000, 500_000, 200_000, 11, 2_000, 10_000, 60, 4, 1_000, 15,
    4_000_000, 2_500_000, 4_000_000, 500_000, 10_000, 80, 30,
)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross: int = 3
    mlp: tuple = (1024, 1024, 512)
    table_sizes: tuple = CRITEO_TABLE_SIZES
    hotness: int = 1            # indices per bag (multi-hot when > 1)
    dtype: object = jnp.float32

    @property
    def padded_table_sizes(self) -> tuple:
        """Per-feature rows padded to a multiple of 16 so the fused
        table's row dim shards evenly over the 16-way tensor axis."""
        return tuple(((s + 15) // 16) * 16 for s in self.table_sizes)

    @property
    def total_rows(self) -> int:
        return int(sum(self.padded_table_sizes))

    @property
    def row_offsets(self) -> np.ndarray:
        """Start row of each feature's slice in the fused table."""
        sizes = self.padded_table_sizes
        return np.concatenate(
            [[0], np.cumsum(sizes[:-1])]).astype(np.int64)

    @property
    def d_interact(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


# ==========================================================================
# EmbeddingBag (jnp.take + segment_sum — the JAX-native sparse substrate)
# ==========================================================================

def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray,
                  bag_ids: jnp.ndarray, num_bags: int,
                  combine: str = "sum") -> jnp.ndarray:
    """General EmbeddingBag: rows = take(table, indices); bags reduce via
    segment_sum over ``bag_ids`` (sorted). [nnz] -> [num_bags, dim]."""
    rows = jnp.take(table, indices, axis=0)
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=num_bags)
    if combine == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones((indices.shape[0],), rows.dtype), bag_ids,
            num_segments=num_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def fused_lookup(table: jnp.ndarray, sparse_idx: jnp.ndarray,
                 row_offsets: jnp.ndarray, combine: str = "sum"
                 ) -> jnp.ndarray:
    """Fused-table lookup. sparse_idx: [B, F] (one-hot) or [B, F, H]
    (multi-hot); returns [B, F, dim]. Per-feature row offsets are added so
    all features read the single fused table."""
    if sparse_idx.ndim == 2:
        flat = sparse_idx + row_offsets[None, :]
        return jnp.take(table, flat, axis=0)            # [B, F, dim]
    b, f, h = sparse_idx.shape
    flat = (sparse_idx + row_offsets[None, :, None]).reshape(-1)
    bag_ids = jnp.arange(b * f, dtype=jnp.int32).repeat(h)
    out = embedding_bag(table, flat, bag_ids, b * f, combine)
    return out.reshape(b, f, -1)


# ==========================================================================
# Parameters
# ==========================================================================

def init(rng, cfg: RecsysConfig) -> dict:
    r_tab, r_cross, r_mlp, r_head, r_bn = jax.random.split(rng, 5)
    d = cfg.d_interact
    cross_rngs = jax.random.split(r_cross, cfg.n_cross)
    return {
        "table": L.normal_init(
            r_tab, (cfg.total_rows, cfg.embed_dim),
            cfg.embed_dim ** -0.5, cfg.dtype),
        "dense_norm": {"w": jnp.ones((cfg.n_dense,), cfg.dtype),
                       "b": jnp.zeros((cfg.n_dense,), cfg.dtype)},
        "cross": [{"w": L.normal_init(r, (d, d), d ** -0.5, cfg.dtype),
                   "b": jnp.zeros((d,), cfg.dtype)}
                  for r in cross_rngs],
        "mlp": L.mlp_params(r_mlp, [d, *cfg.mlp], cfg.dtype),
        "head": L.normal_init(r_head, (d + cfg.mlp[-1], 1),
                              (d + cfg.mlp[-1]) ** -0.5, cfg.dtype),
    }


def param_count(cfg: RecsysConfig) -> int:
    params = jax.eval_shape(lambda r: init(r, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ==========================================================================
# Forward
# ==========================================================================

def interact(params: dict, batch: dict, cfg: RecsysConfig) -> jnp.ndarray:
    """dense [B, 13] + sparse_idx [B, 26(, H)] -> x0 [B, d_interact]."""
    dense = batch["dense"].astype(cfg.dtype)
    dense = dense * params["dense_norm"]["w"] + params["dense_norm"]["b"]
    emb = fused_lookup(params["table"], batch["sparse_idx"],
                       jnp.asarray(cfg.row_offsets))
    return jnp.concatenate(
        [dense, emb.reshape(emb.shape[0], -1)], axis=-1)


def forward(params: dict, batch: dict, cfg: RecsysConfig) -> jnp.ndarray:
    """Returns logits [B]."""
    x0 = interact(params, batch, cfg)
    # cross network: x_{l+1} = x0 * (W x_l + b) + x_l
    x = x0
    for cl in params["cross"]:
        x = x0 * (x @ cl["w"] + cl["b"]) + x
    deep = L.mlp_apply(params["mlp"], x0)
    deep = jax.nn.relu(deep)
    both = jnp.concatenate([x, deep], axis=-1)
    return (both @ params["head"])[:, 0]


def loss_fn(params: dict, batch: dict, cfg: RecsysConfig) -> jnp.ndarray:
    """Binary cross-entropy on click labels."""
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_scores(params: dict, batch: dict, cfg: RecsysConfig,
                     candidate_ids: jnp.ndarray) -> jnp.ndarray:
    """Score ONE query against N candidates with a single matmul.

    The query runs the full DCN tower; each candidate contributes its
    embedding row (feature 0's table slice); score = <query_repr, cand>
    after a learned projection — a batched dot, not a loop.
    """
    x0 = interact(params, batch, cfg)                 # [1, d]
    x = x0
    for cl in params["cross"]:
        x = x0 * (x @ cl["w"] + cl["b"]) + x
    deep = jax.nn.relu(L.mlp_apply(params["mlp"], x0))
    q = jnp.concatenate([x, deep], axis=-1)           # [1, d + mlp[-1]]
    # project query into embed space with the head slice, then dot
    cand = jnp.take(params["table"], candidate_ids, axis=0)  # [N, dim]
    q_proj = q @ params["head"] @ jnp.ones((1, cfg.embed_dim),
                                           q.dtype)   # [1, dim]
    return (cand @ q_proj[0]).astype(jnp.float32)     # [N]


# ==========================================================================
# Sharding
# ==========================================================================

def param_spec(cfg: RecsysConfig, fsdp, tp: str = "model") -> dict:
    """Embedding table row-sharded over the tensor axis; dense tower
    replicated (tiny) with the MLP's wide dims sharded over tp."""
    return {
        "table": P(tp, None),
        "dense_norm": {"w": P(None), "b": P(None)},
        "cross": [{"w": P(None, None), "b": P(None)}
                  for _ in range(cfg.n_cross)],
        "mlp": {"ws": [P(None, tp), P(tp, None), P(None, None)],
                "bs": [P(tp), P(None), P(None)]},
        "head": P(None, None),
    }


def batch_spec(fsdp) -> dict:
    return {"dense": P(fsdp, None), "sparse_idx": P(fsdp, None),
            "label": P(fsdp)}
