"""Shared neural-net layers (pure-function style: params are pytrees).

No flax/haiku — parameters are plain dicts of jnp arrays, created by
``*_params`` functions and consumed by ``*_apply`` functions, so that
layer stacks can be ``jax.lax.scan``-ed over stacked parameter pytrees
(compile time O(1) in depth — required for the 64-layer dry-runs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain


def uniform_init(rng, shape, scale, dtype):
    return jax.random.uniform(rng, shape, dtype, -scale, scale)


def normal_init(rng, shape, std, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm; ``plus_one`` uses the (1+w) parameterization (gemma)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (xf * w).astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [B, S, H, d]; positions: [S] (shared across batch) or [B, S]
    (per-request, used by the decode path where right-padded requests sit
    at different positions). Rotates (even, odd) halves — the
    'half-rotation' LLaMA/HF convention."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                         # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..,S,d/2]
    if positions.ndim == 1:
        cos = jnp.cos(angles)[None, :, None, :]
        sin = jnp.sin(angles)[None, :, None, :]
    else:
        cos = jnp.cos(angles)[:, :, None, :]
        sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (XLA path; the Pallas flash kernel is the TPU fast path)
# --------------------------------------------------------------------------

def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap > 0.0 else x


def attention_scores_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                          window) -> jnp.ndarray:
    """Causal (+ optional sliding ``window``) mask. ``window`` may be a
    traced scalar (0 = full attention) so alternating local/global layers
    can share one scanned body.

    Positions may be [S] (shared) -> mask [Sq, Sk], or [B, S]
    (per-request decode) -> mask [B, Sq, Sk]. Negative k positions mark
    empty cache slots and are always masked.
    """
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    causal = q >= k
    w = jnp.asarray(window, jnp.int32)
    local = jnp.where(w > 0, (q - k) < w, True)
    return causal & local & (k >= 0)


_FLASH_THRESHOLD = 1024      # Sq*Sk above which the blocked path is used


def _attention_dense(q, k, v, *, q_positions, k_positions, window,
                     attn_softcap, scale, kv_mask):
    """Direct S×S-scores path (decode steps, small tests)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    # bf16 operands, f32 accumulation: an explicit astype(f32) on k/v
    # gets hoisted above the layer scan at decode, materializing the
    # WHOLE [L, B, S, H, dh] cache in f32 (observed 4 GiB/chip buffers)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    # pin batch sharding: GSPMD otherwise replicates the S×S scores over
    # batch when it picks head sharding (observed 16 GiB/chip; DESIGN §6)
    s = constrain(s, "batch", None, None, None, None)
    s = softcap(s, attn_softcap)
    mask = attention_scores_mask(q_positions, k_positions, window)
    if mask.ndim == 2:                                   # [Sq, Sk]
        mask = mask[None]                                # -> [1|B, Sq, Sk]
    if kv_mask is not None:
        mask = mask & kv_mask[:, None, :]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = constrain(out, "batch", None, None, None, None)
    return out.reshape(b, sq, hq, v.shape[-1]).astype(q.dtype)


def _attention_blocked(q, k, v, *, q_positions, k_positions, window,
                       attn_softcap, scale, kv_mask,
                       block_k: int = 512):
    """Blocked online-softmax attention (XLA path of the flash kernel).

    ``lax.scan`` over kv blocks with running (m, l, acc) statistics: the
    S×S score matrix never materializes — peak per-step memory is one
    [B, Hkv, G, Sq, block_k] tile. ``jax.checkpoint`` on the block body
    makes the backward recompute tiles instead of saving them (the
    flash-backward memory profile). Numerically identical to the dense
    path (same fp32 accumulation; tested to 1e-5)."""
    b, sq, hq, d = q.shape
    dv = v.shape[-1]            # MLA: v head dim != qk head dim
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    nblk = -(-sk // block_k)
    pad = nblk * block_k - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if k_positions.ndim == 1:
        kpos = jnp.pad(k_positions, (0, pad), constant_values=-1)
        kpos_blocks = kpos.reshape(nblk, block_k)
    else:
        kpos = jnp.pad(k_positions, ((0, 0), (0, pad)),
                       constant_values=-1)
        kpos_blocks = kpos.reshape(b, nblk, block_k).swapaxes(0, 1)
    kvm_blocks = None
    if kv_mask is not None:
        kvm = jnp.pad(kv_mask, ((0, 0), (0, pad)))
        kvm_blocks = kvm.reshape(b, nblk, block_k).swapaxes(0, 1)
    k_blocks = kp.reshape(b, nblk, block_k, hkv, d).swapaxes(0, 1)
    v_blocks = vp.reshape(b, nblk, block_k, hkv, dv).swapaxes(0, 1)
    # pin EVERY loop-carried/loop-read tensor's layout: otherwise GSPMD
    # re-shards between kv-block steps ("involuntary full remat"
    # warnings), inserting per-block all-gathers ×blocks×layers×accum
    k_blocks = constrain(k_blocks, None, "batch", None, None, None)
    v_blocks = constrain(v_blocks, None, "batch", None, None, None)

    qg = q.reshape(b, sq, hkv, g, d)       # model dtype; dots accum f32
    qg = constrain(qg, "batch", None, None, None, None)

    def body(carry, xs):
        m_run, l_run, acc = carry
        if kvm_blocks is not None:
            kb, vb, kpos_b, kvm_b = xs
        else:
            kb, vb, kpos_b = xs
            kvm_b = None
        # bf16 operands, f32 accumulation (MXU-native); p is cast to
        # bf16 for the pv matmul (standard flash practice) — halves the
        # per-block HBM traffic vs f32 operands
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        s = constrain(s, "batch", None, None, None, None)
        s = softcap(s, attn_softcap)
        mask = attention_scores_mask(q_positions, kpos_b, window)
        if mask.ndim == 2:
            mask = mask[None]
        if kvm_b is not None:
            mask = mask & kvm_b[:, None, :]
        s = jnp.where(mask[:, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        m_new = constrain(m_new, "batch", None, None, None)
        l_new = constrain(l_new, "batch", None, None, None)
        acc = constrain(acc, "batch", None, None, None, None)
        return (m_new, l_new, acc), None

    m0 = constrain(jnp.full((b, hkv, g, sq), -1e30, jnp.float32),
                   "batch", None, None, None)
    l0 = constrain(jnp.zeros((b, hkv, g, sq), jnp.float32),
                   "batch", None, None, None)
    a0 = constrain(jnp.zeros((b, hkv, g, sq, dv), jnp.float32),
                   "batch", None, None, None, None)
    xs = (k_blocks, v_blocks, kpos_blocks)
    if kvm_blocks is not None:
        xs = xs + (kvm_blocks,)
    (m_f, l_f, acc), _ = jax.lax.scan(
        jax.checkpoint(body, policy=jax.checkpoint_policies.
                       nothing_saveable),
        (m0, l0, a0), xs)
    l_f = jnp.where(l_f == 0.0, 1.0, l_f)       # fully-masked rows
    out = acc / l_f[..., None]                   # [B,Hkv,G,Sq,dv]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dv)
    return out.astype(q.dtype)


def multi_head_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         q_positions: jnp.ndarray,
                         k_positions: jnp.ndarray,
                         window=0, attn_softcap: float = 0.0,
                         sm_scale: float | None = None,
                         kv_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """GQA attention. q: [B,Sq,Hq,d]; k, v: [B,Sk,Hkv,d]; Hq % Hkv == 0.

    ``kv_mask`` ([B, Sk] bool) masks unfilled KV-cache slots at decode.
    Long sequences take the blocked online-softmax path (no S×S buffer);
    decode (Sq=1) and small shapes take the dense path.
    """
    sq, sk = q.shape[1], k.shape[1]
    d = q.shape[-1]
    scale = sm_scale if sm_scale is not None else d ** -0.5
    kw = dict(q_positions=q_positions, k_positions=k_positions,
              window=window, attn_softcap=attn_softcap, scale=scale,
              kv_mask=kv_mask)
    if sq > 1 and sq * sk > _FLASH_THRESHOLD ** 2:
        return _attention_blocked(q, k, v, **kw)
    return _attention_dense(q, k, v, **kw)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def gated_mlp_apply(params: dict, x: jnp.ndarray,
                    act: str = "silu") -> jnp.ndarray:
    """SwiGLU / GeGLU feed-forward."""
    gate = x @ params["w_gate"]
    up = x @ params["w_up"]
    a = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    return (a * up) @ params["w_down"]


def gated_mlp_params(rng, d_model: int, d_ff: int, dtype) -> dict:
    r1, r2, r3 = jax.random.split(rng, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "w_gate": normal_init(r1, (d_model, d_ff), s_in, dtype),
        "w_up": normal_init(r2, (d_model, d_ff), s_in, dtype),
        "w_down": normal_init(r3, (d_ff, d_model), s_out, dtype),
    }


def mlp_apply(params: dict, x: jnp.ndarray, act: str = "relu"
              ) -> jnp.ndarray:
    """Plain MLP tower: list of (w, b) with activation between layers."""
    n = len(params["ws"])
    for i, (w, b) in enumerate(zip(params["ws"], params["bs"])):
        x = x @ w + b
        if i < n - 1:
            x = jax.nn.relu(x) if act == "relu" else jax.nn.silu(x)
    return x


def mlp_params(rng, dims: list[int], dtype) -> dict:
    ws, bs = [], []
    rngs = jax.random.split(rng, len(dims) - 1)
    for r, din, dout in zip(rngs, dims[:-1], dims[1:]):
        ws.append(normal_init(r, (din, dout), din ** -0.5, dtype))
        bs.append(jnp.zeros((dout,), dtype))
    return {"ws": ws, "bs": bs}


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------

def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token-level CE; logits [*, V] any dtype (upcast inside)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def chunked_lm_loss(x: jnp.ndarray, head: jnp.ndarray,
                    labels: jnp.ndarray, *, final_softcap: float = 0.0,
                    seq_chunk: int = 512) -> jnp.ndarray:
    """Memory-lean LM cross-entropy: the [B, S, V] fp32 logits tensor
    never materializes. ``lax.scan`` over sequence chunks computes each
    chunk's logits -> per-token NLL and discards them; ``jax.checkpoint``
    on the chunk body makes the backward recompute chunk logits instead
    of saving them. Peak extra memory = one [B, chunk, V] tile.

    x: final hidden states [B, S, D]; head: [D, V]; labels: [B, S].
    """
    b, s, dm = x.shape
    nchunk = -(-s // seq_chunk)
    pad = nchunk * seq_chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=-1)
    xc = x.reshape(b, nchunk, seq_chunk, dm).swapaxes(0, 1)
    lc = labels.reshape(b, nchunk, seq_chunk).swapaxes(0, 1)

    def chunk_nll(carry, xs):
        xchunk, lchunk = xs                     # [B, C, D], [B, C]
        logits = (xchunk @ head).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "tp")
        logits = softcap(logits, final_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lchunk, 0)
        gold = jnp.take_along_axis(logits, safe[..., None],
                                   axis=-1)[..., 0]
        valid = (lchunk >= 0).astype(jnp.float32)
        return (carry[0] + ((logz - gold) * valid).sum(),
                carry[1] + valid.sum()), None

    body = jax.checkpoint(
        chunk_nll, policy=jax.checkpoint_policies.nothing_saveable)
    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    return total / jnp.maximum(count, 1.0)
