"""Logical activation-sharding constraints.

GSPMD propagation alone mis-shards large intermediates (observed: gemma2
train_4k attention scores replicated over batch — 16 GiB/chip). Models
therefore pin the batch dim of key activations with
``with_sharding_constraint``, using *logical* names resolved against a
launcher-configured axis mapping. When no mapping is configured (CPU
tests, single-device runs) constraints are identity — model code never
branches on mesh topology.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_local = threading.local()


def set_logical_axes(mapping: dict | None) -> None:
    """mapping: logical name -> mesh axis (or tuple), e.g.
    {"batch": ("pod", "data"), "tp": "model"}."""
    _local.mapping = mapping


def get_logical_axes() -> dict | None:
    return getattr(_local, "mapping", None)


@contextlib.contextmanager
def logical_axes(mapping: dict | None):
    prev = get_logical_axes()
    set_logical_axes(mapping)
    try:
        yield
    finally:
        set_logical_axes(prev)


def constrain(x, *logical_dims):
    """Pin ``x``'s sharding: one logical name (or None) per dim.

    ``None`` dims stay UNCONSTRAINED — propagation may still shard them
    (e.g. heads over 'model'); pinning them to replicated would forbid
    that. Use the name ``"rep"`` to force replication of a dim."""
    mapping = get_logical_axes()
    if mapping is None:
        return x

    def resolve(d):
        if d is None:
            return P.UNCONSTRAINED
        if d == "rep":
            return None
        return mapping.get(d, P.UNCONSTRAINED)

    spec = P(*(resolve(d) for d in logical_dims))
    return jax.lax.with_sharding_constraint(x, spec)
