"""DeviceGraph — the device-resident graph substrate (DESIGN.md §8).

Every execution mode above ``repro.core.rounds`` used to thread graphs
as ad-hoc ``(edges, num_nodes, true_edges)`` tuples with host-side numpy
at the seams (policy feature extraction, service insert coalescing,
distributed partitioning). ``DeviceGraph`` replaces those tuples with
ONE registered pytree that every layer consumes:

  * ``edges``      — on-device int32 [E, 2] COO (possibly padded with
                     (0, 0) no-op self loops);
  * ``num_nodes``  — static |V| (a jit cache key);
  * ``true_edges`` — the unpadded edge count, static int *or* traced
                     int32 scalar (work counters bill true edges only);
  * ``plan``       — the attached ``SegmentationPlan`` (static), keyed
                     on the paper's s = 2|E|/|V| heuristic over the
                     TRUE edge count, covering the stored (padded)
                     edge array;
  * CSR offsets    — built lazily on device via sort + searchsorted
                     (``csr()``), cached on the instance.

Static fields ride in the pytree aux data, so a DeviceGraph crosses
``jax.jit`` boundaries directly and two graphs of one shape bucket hit
one compile. All device-shaping helpers (``pad_pow2``, ``concat``,
``pad_rows``) are jit-backed: under ``jax.transfer_guard("disallow")``
the steady-state service path runs them without a single implicit
host transfer (eager ``jnp.zeros`` would materialize a host constant).

Padding invariant: rows past ``true_edges`` are (0, 0) self loops —
hook no-ops for every engine — and are never billed (see
``rounds.WorkCounters``).

``EdgeLog`` (DESIGN.md §9) extends the substrate to fully-dynamic
workloads: a device-resident append/tombstone log (alive mask, pow2
capacity buckets, sort-based undirected delete matching) whose
``compact_alive`` restores the prefix-padding invariant so the
segmentation machinery and the fused kernel keep working over a log
that has holes.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.batch import next_pow2
from repro.core.segmentation import (SegmentationPlan, adaptive_num_segments,
                                     plan_segmentation)

_MIN_PAD_ROWS = 8


def validate_edge_bounds(edges: np.ndarray, num_nodes: int) -> None:
    """Raise unless every endpoint lies in [0, num_nodes) — the ONE
    validation rule every host-ingress path shares (registry coerce,
    service admission/rebind). Callers pass a HOST array; device-
    resident paths skip validation by contract (a sync would defeat
    them)."""
    edges = np.asarray(edges)
    if edges.size and (edges.min() < 0 or edges.max() >= num_nodes):
        raise ValueError(f"edge endpoint out of range [0, {num_nodes})")


def measure_degree_skew(edges: np.ndarray, num_nodes: int) -> float:
    """max_degree / mean_degree over the undirected degree sequence —
    the policy's skew feature (~1 on regular/road-like graphs, large on
    power-law/kron-like ones). HOST arrays only: it runs once at
    ``from_edges`` ingest, where the edges are on host anyway; graphs
    that arrive device-resident skip it (skew stays None) rather than
    pay a transfer."""
    edges = np.asarray(edges)
    if edges.size == 0 or num_nodes <= 0:
        return 1.0
    deg = np.bincount(
        np.concatenate([edges[:, 0], edges[:, 1]]), minlength=num_nodes)
    mean = 2.0 * edges.shape[0] / num_nodes
    return float(deg.max() / max(mean, 1e-9))


@functools.partial(jax.jit, static_argnames=("rows",))
def _pad_rows_jit(edges: jnp.ndarray, *, rows: int) -> jnp.ndarray:
    """Append ``rows`` (0, 0) no-op rows on device (jitted so it stays
    transfer-free under ``jax.transfer_guard``)."""
    return jnp.concatenate(
        [edges, jnp.zeros((rows, 2), edges.dtype)], axis=0)


@functools.partial(jax.jit, static_argnames=("num_nodes",))
def _build_csr_jit(edges: jnp.ndarray, *, num_nodes: int):
    """On-device CSR offsets: sort edges by source, then binary-search
    the row starts (no host bincount/cumsum round trip)."""
    src = edges[:, 0]
    order = jnp.argsort(src, stable=True)
    sorted_src = src[order]
    neighbors = edges[order, 1]
    offsets = jnp.searchsorted(
        sorted_src, jnp.arange(num_nodes + 1, dtype=jnp.int32))
    return offsets.astype(jnp.int32), neighbors


@jax.tree_util.register_pytree_node_class
class DeviceGraph:
    """Device-resident COO graph + segmentation plan (one pytree)."""

    def __init__(self, edges, num_nodes: int, true_edges,
                 plan: SegmentationPlan, name: str = "graph",
                 degree_skew: float | None = None):
        self.edges = edges                     # int32 [E, 2], device
        self.num_nodes = int(num_nodes)        # static
        self.true_edges = true_edges           # static int | traced scalar
        self.plan = plan                       # static
        self.name = name
        # static metadata: max_degree / mean_degree, measured once at
        # host ingest (None when the edges arrived device-resident — a
        # host pass would violate transfer discipline). Policy feature
        # for the sampled routing rule; rides in the pytree aux.
        self.degree_skew = degree_skew
        self._csr = None                       # lazy (offsets, neighbors)

    # -- pytree protocol ---------------------------------------------------

    def tree_flatten(self):
        if self.true_edges_static is not None:
            return ((self.edges,),
                    (self.num_nodes, self.true_edges_static, self.plan,
                     self.name, self.degree_skew))
        return ((self.edges, self.true_edges),
                (self.num_nodes, None, self.plan, self.name,
                 self.degree_skew))

    @classmethod
    def tree_unflatten(cls, aux, children):
        num_nodes, true_static, plan, name, degree_skew = aux
        if true_static is not None:
            (edges,) = children
            return cls(edges, num_nodes, true_static, plan, name=name,
                       degree_skew=degree_skew)
        edges, true_edges = children
        return cls(edges, num_nodes, true_edges, plan, name=name,
                   degree_skew=degree_skew)

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_edges(cls, edges, num_nodes: int, *, true_edges=None,
                   num_segments: int | None = None,
                   name: str = "graph", device=None) -> "DeviceGraph":
        """The raw-array shim: accepts host numpy / lists (explicitly
        device_put) or already-device jnp arrays (left in place). Host
        ingest also measures ``degree_skew`` (free while the array is
        on host; device-resident arrays keep it None). ``device=``
        COMMITS the edges to one device (the fleet's per-device
        pinning; None keeps today's default placement)."""
        degree_skew = None
        if isinstance(edges, jnp.ndarray):
            edges = edges.astype(jnp.int32).reshape(-1, 2)
            if device is not None:
                edges = jax.device_put(edges, device)
        else:
            host = np.asarray(edges, np.int32).reshape(-1, 2)
            t = true_edges if isinstance(true_edges, (int, np.integer)) \
                else host.shape[0]
            degree_skew = measure_degree_skew(host[:int(t)],
                                              int(num_nodes))
            edges = jax.device_put(host, device)
        e_stored = int(edges.shape[0])
        if true_edges is None:
            true_edges = e_stored
        plan = _plan_for(e_stored, int(num_nodes), true_edges, num_segments)
        return cls(edges, int(num_nodes), true_edges, plan, name=name,
                   degree_skew=degree_skew)

    @classmethod
    def from_host(cls, graph, *, num_segments: int | None = None
                  ) -> "DeviceGraph":
        """From a host ``repro.graphs.format.Graph`` (one device_put)."""
        return cls.from_edges(graph.edges, graph.num_nodes,
                              num_segments=num_segments,
                              name=getattr(graph, "name", "graph"))

    # -- static metadata (policy features — zero host round-trips) ---------

    @property
    def true_edges_static(self) -> int | None:
        """The true edge count when known statically, else None."""
        if isinstance(self.true_edges, (int, np.integer)):
            return int(self.true_edges)
        return None

    @property
    def num_edges(self) -> int:
        """Best static edge count: true if static, else the stored
        (padded) row count."""
        t = self.true_edges_static
        return t if t is not None else int(self.edges.shape[0])

    @property
    def density(self) -> float:
        """The paper's segmentation key 2|E|/|V| from static metadata."""
        return 2.0 * self.num_edges / max(self.num_nodes, 1)

    def true_edges_device(self) -> jnp.ndarray:
        """The true edge count as a device scalar (explicit transfer —
        legal under ``transfer_guard('disallow')``)."""
        if isinstance(self.true_edges, jnp.ndarray):
            return self.true_edges
        return jax.device_put(np.int32(self.true_edges))

    # -- device-side shaping -----------------------------------------------

    def pad_rows(self, target: int) -> "DeviceGraph":
        """Pad the stored edge array with (0, 0) no-ops to ``target``
        rows (device-side, jitted). ``true_edges`` is preserved."""
        e = int(self.edges.shape[0])
        if target <= e:
            return self
        edges = _pad_rows_jit(self.edges, rows=target - e)
        plan = _plan_for(target, self.num_nodes, self.true_edges, None)
        return DeviceGraph(edges, self.num_nodes, self.true_edges, plan,
                           name=self.name, degree_skew=self.degree_skew)

    def pad_pow2(self, min_rows: int = _MIN_PAD_ROWS) -> "DeviceGraph":
        """Pad to the next power-of-two row count (floored at
        ``min_rows``) — the shape-bucket rule of ``repro.core.batch``
        (same ``next_pow2``, so both layers share jit-cache buckets),
        letting a stream of ragged batches hit a handful of entries."""
        e = int(self.edges.shape[0])
        return self.pad_rows(next_pow2(max(e, min_rows)))

    @classmethod
    def concat(cls, graphs: Sequence["DeviceGraph"],
               name: str | None = None) -> "DeviceGraph":
        """Device-side concatenation of same-|V| graphs (the service's
        insert-coalescing primitive — replaces host ``np.concatenate``).
        Every part needs a STATIC true count; counts sum statically.

        Parts with static padding are trimmed first so the result keeps
        the prefix invariant (first ``true_edges`` rows are real) that
        per-segment billing and the fused kernel's edge masking rely on.
        """
        graphs = list(graphs)
        if not graphs:
            raise ValueError("concat needs at least one DeviceGraph")
        if len({g.num_nodes for g in graphs}) != 1:
            raise ValueError("concat requires identical num_nodes, got "
                             f"{[g.num_nodes for g in graphs]}")
        if len(graphs) == 1:
            return graphs[0]
        parts, trues = [], []
        for g in graphs:
            s = g.true_edges_static
            if s is None:
                # a traced-count part MAY be padded, and its pads would
                # land in the interior where the kernel's mask reads
                # them as real — refuse rather than silently corrupt
                raise ValueError(
                    "concat needs static true_edges on every part "
                    "(prefix-padding invariant)")
            if s < int(g.edges.shape[0]):
                parts.append(g.edges[:s])      # static slice, device op
            else:
                parts.append(g.edges)
            trues.append(s)
        edges = jnp.concatenate(parts, axis=0)
        true = int(sum(trues))
        plan = _plan_for(int(edges.shape[0]), graphs[0].num_nodes, true,
                         None)
        # degree-skew None-join rule: a part without a measured skew
        # (device-side ingest skips the host measurement) must not
        # erase another part's known value — routing on a silently
        # dropped skew flips method="auto" mid-session. Unknown parts
        # are ignored; known parts join by max (skew is a max-over-mean
        # statistic, and the union's skew is at least each part's
        # numerator over a no-smaller edge count scaled by parts —
        # max-of-known is the conservative router-facing bound);
        # all-unknown stays None.
        skews = [g.degree_skew for g in graphs if g.degree_skew is not None]
        skew = max(skews) if skews else None
        return cls(edges, graphs[0].num_nodes, true, plan,
                   name=name or graphs[0].name, degree_skew=skew)

    def shard(self, mesh: Mesh, axis_names: tuple[str, ...] = ("data",)
              ) -> "DeviceGraph":
        """Shard the edge list over the mesh's ``axis_names`` (padding
        with (0, 0) no-ops so non-divisible edge counts split evenly).
        The result is what ``core.distributed.make_distributed_cc``
        consumes."""
        n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
        e = int(self.edges.shape[0])
        per = max(1, (e + n_shards - 1) // n_shards)
        padded = self.pad_rows(per * n_shards)
        spec = P(axis_names if len(axis_names) > 1 else axis_names[0],
                 None)
        edges = jax.device_put(padded.edges, NamedSharding(mesh, spec))
        return DeviceGraph(edges, self.num_nodes, padded.true_edges,
                           padded.plan, name=self.name,
                           degree_skew=self.degree_skew)

    # -- lazy on-device CSR ------------------------------------------------

    def csr(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(offsets int32 [V+1], neighbors int32 [E]) built on device
        via sort + searchsorted, cached. Built over the stored edge
        array; padded (0, 0) rows surface as extra 0->0 entries, so
        callers that need exact degrees should slice to
        ``true_edges_static`` first (``trim()``)."""
        if self._csr is None:
            self._csr = _build_csr_jit(self.edges,
                                       num_nodes=self.num_nodes)
        return self._csr

    def trim(self) -> "DeviceGraph":
        """Drop padded rows (requires a static true count). Metadata —
        ``degree_skew`` in particular — is PRESERVED: the trimmed graph
        is the same edge set, so rebuilding through ``from_edges`` (a
        device-array ingest, which cannot re-measure) would silently
        erase a measured skew and flip ``method="auto"`` routing after
        a shard/trim round trip."""
        t = self.true_edges_static
        if t is None:
            raise ValueError("trim() needs a static true_edges")
        if t == int(self.edges.shape[0]):
            return self
        plan = _plan_for(t, self.num_nodes, t, None)
        return DeviceGraph(self.edges[:t], self.num_nodes, t, plan,
                           name=self.name, degree_skew=self.degree_skew)

    def __repr__(self) -> str:
        t = self.true_edges_static
        return (f"DeviceGraph(|V|={self.num_nodes}, "
                f"|E|={self.edges.shape[0]}"
                + (f", true={t}" if t is not None
                   and t != self.edges.shape[0] else "")
                + f", s={self.plan.num_segments}, name={self.name!r})")


# ---------------------------------------------------------------------------
# EdgeLog — the fully-dynamic edge substrate (DESIGN.md §9)
# ---------------------------------------------------------------------------

def undirected_group_ids(pairs: jnp.ndarray) -> jnp.ndarray:
    """int32 [N] group id per row of an int [N, 2] pair array; two rows
    get the same id iff they denote the same UNDIRECTED edge ((u, v)
    and (v, u) collapse). Pure int32 — a min*|V|+max key encoding would
    overflow int32 at |V| > ~46k and this container has no x64 —
    via a lexicographic two-pass stable sort + boundary cumsum."""
    lo = jnp.minimum(pairs[:, 0], pairs[:, 1]).astype(jnp.int32)
    hi = jnp.maximum(pairs[:, 0], pairs[:, 1]).astype(jnp.int32)
    o1 = jnp.argsort(hi, stable=True)               # secondary key
    o2 = jnp.argsort(lo[o1], stable=True)           # primary key (stable)
    order = o1[o2]
    slo, shi = lo[order], hi[order]
    new_group = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         ((slo[1:] != slo[:-1]) | (shi[1:] != shi[:-1])).astype(jnp.int32)])
    gid_sorted = jnp.cumsum(new_group).astype(jnp.int32)
    return jnp.zeros(pairs.shape[0], jnp.int32).at[order].set(gid_sorted)


def tombstone_mask(edges: jnp.ndarray, alive: jnp.ndarray,
                   dels: jnp.ndarray, d_true: jnp.ndarray
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply a delete batch to an alive mask (pure jnp — composes into
    the caller's jit). A delete of undirected edge {u, v} is
    orientation-blind and kills EVERY alive copy (set semantics over a
    multiset log; duplicates die together). Rows of ``dels`` at index
    >= ``d_true`` are padding and match nothing. Returns
    ``(new_alive, killed)`` where ``killed`` marks the log rows this
    batch actually retired.

    O((E + D) log(E + D)) sort-based matching, no [E, D] broadcast."""
    e, d = edges.shape[0], dels.shape[0]
    gid = undirected_group_ids(jnp.concatenate([edges, dels], axis=0))
    real_del = jnp.arange(d) < d_true               # padding matches nothing
    del_present = jnp.zeros((e + d,), jnp.bool_).at[gid[e:]].max(real_del)
    killed = del_present[gid[:e]] & alive
    return alive & ~killed, killed


def compact_alive(edges: jnp.ndarray, alive: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gather alive rows to a (0, 0)-padded prefix (pure jnp). Restores
    the prefix-padding invariant every engine relies on — per-segment
    true-count billing and the fused kernel's edge masking both read
    "first ``true`` rows are real". Returns ``(edges, true_count)``
    with ``true_count`` a traced int32 scalar."""
    packed, true, _ = compact_alive_perm(edges, alive)
    return packed, true


def compact_alive_perm(edges: jnp.ndarray, alive: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``compact_alive`` + the old→new row permutation. Compaction
    renumbers edge slots, and any holder of log-row indices — the
    maintained spanning forest's ``parent_eidx`` — must remap through
    it or silently point at the wrong (or a dead) edge. Returns
    ``(packed, true_count, perm)`` with ``perm[i]`` the compacted
    position of old row ``i``, or -1 if the row was dead."""
    e = alive.shape[0]
    order = jnp.argsort(~alive, stable=True)        # alive rows first
    packed = jnp.where(alive[order][:, None], edges[order], 0)
    perm = jnp.zeros((e,), jnp.int32).at[order].set(
        jnp.arange(e, dtype=jnp.int32))
    perm = jnp.where(alive, perm, -1)
    return packed, jnp.sum(alive).astype(jnp.int32), perm


@jax.jit
def _log_delete_jit(edges, alive, dels, d_true):
    return tombstone_mask(edges, alive, dels, d_true)


@jax.jit
def _compact_perm_jit(edges, alive):
    packed, true, perm = compact_alive_perm(edges, alive)
    new_alive = jnp.arange(alive.shape[0], dtype=jnp.int32) < true
    return packed, new_alive, perm, true


@jax.jit
def _append_jit(edges, alive, block, true_count, rows):
    """Write a pow2-padded ``block`` at row offset ``rows``, marking
    its first ``true_count`` rows alive and scrubbing the rest to
    (0, 0). BOTH the offset and the true count are TRACED device
    scalars — a static offset would recompile once per append cursor
    value, a static count once per batch size; this way a long-lived
    stream hits one entry per (capacity, block) pow2 shape pair."""
    p = block.shape[0]
    mask = jnp.arange(p, dtype=jnp.int32) < true_count
    block = jnp.where(mask[:, None], block, 0)
    zero = jnp.zeros((), jnp.int32)
    edges = jax.lax.dynamic_update_slice(edges, block, (rows, zero))
    alive = jax.lax.dynamic_update_slice(alive, mask, (rows,))
    return edges, alive


@functools.partial(jax.jit, static_argnames=("target",))
def _grow_jit(edges, alive, *, target):
    pad = target - edges.shape[0]
    edges = jnp.concatenate([edges, jnp.zeros((pad, 2), edges.dtype)])
    alive = jnp.concatenate([alive, jnp.zeros((pad,), jnp.bool_)])
    return edges, alive


class EdgeLog:
    """Device-resident append/tombstone edge log — the substrate of
    fully-dynamic connectivity (DESIGN.md §9).

    * ``edges`` [cap, 2] int32 on device; rows beyond the append cursor
      are (0, 0) and dead;
    * ``alive`` [cap] bool on device — the tombstone mask. Inserts set
      it, deletes clear it; how many rows a delete batch actually
      killed is known only on device (the steady-state tick never
      syncs it);
    * capacity grows by the power-of-two bucket rule of
      ``repro.core.batch`` (``next_pow2``), so a stream of appends hits
      a handful of jit cache entries — the same shape-bucket discipline
      the batched engine and the service's query microbatcher use.

    The log deliberately does NOT compact on delete: tombstoning is
    O(E log D) with zero allocation churn, and every consumer masks by
    ``alive`` anyway. ``compact()`` (sort-to-prefix + (0, 0) scrub via
    ``compact_alive``) restores the prefix invariant on demand — the
    bulk-rebuild path and ``view()`` use it so the segmentation plan
    and the fused kernel see well-formed prefix padding.
    """

    def __init__(self, num_nodes: int, *, capacity: int = 64):
        self.num_nodes = int(num_nodes)
        cap = next_pow2(max(capacity, 8))
        self.edges = jnp.zeros((cap, 2), jnp.int32)
        self.alive = jnp.zeros((cap,), jnp.bool_)
        self.rows = 0                   # host append cursor (static sizes)

    @property
    def capacity(self) -> int:
        return int(self.edges.shape[0])

    def num_alive_device(self) -> jnp.ndarray:
        """Alive edge count as a device scalar (no sync)."""
        return jnp.sum(self.alive).astype(jnp.int32)

    @property
    def num_alive(self) -> int:
        """Alive edge count (syncs; introspection only)."""
        return int(self.num_alive_device())

    def append(self, delta: "DeviceGraph") -> None:
        """Append a delta's TRUE rows (device-side; needs a static true
        count, like ``DeviceGraph.concat``). The write lands as a
        pow2-padded block whose tail is scrubbed dead in-jit, so
        ragged batch sizes share compile entries; capacity grows by
        pow2 buckets and leaves headroom for the padded block (the
        cursor still advances by the TRUE count — the next append
        overwrites the dead tail)."""
        t = delta.true_edges_static
        if t is None:
            raise ValueError("EdgeLog.append needs a static true_edges "
                             "(prefix-padding invariant)")
        if delta.num_nodes != self.num_nodes:
            raise ValueError(f"delta num_nodes {delta.num_nodes} != "
                             f"{self.num_nodes}")
        if t == 0:
            return
        p = next_pow2(max(t, _MIN_PAD_ROWS))
        if self.rows + p > self.capacity:     # headroom for the block
            self.edges, self.alive = _grow_jit(
                self.edges, self.alive, target=next_pow2(self.rows + p))
        stored = int(delta.edges.shape[0])
        block = delta.edges[:p] if stored >= p \
            else _pad_rows_jit(delta.edges, rows=p - stored)
        # explicit device_puts: legal under
        # jax.transfer_guard("disallow"), unlike implicit host-scalar
        # jit arguments
        self.edges, self.alive = _append_jit(
            self.edges, self.alive, block,
            jax.device_put(np.int32(t)),
            jax.device_put(np.int32(self.rows)))
        self.rows += t

    def delete(self, dels: jnp.ndarray, d_true) -> jnp.ndarray:
        """Standalone tombstone application (the registry's bulk-rebuild
        delete route — the scoped-recompute route fuses
        ``tombstone_mask`` into the DynamicCC delete jit instead).
        Returns the killed mask (device; never synced here)."""
        self.alive, killed = _log_delete_jit(
            self.edges, self.alive, jnp.asarray(dels, jnp.int32),
            jnp.asarray(d_true, jnp.int32))
        return killed

    def view(self) -> "DeviceGraph":
        """The alive edge set as a compacted DeviceGraph (traced true
        count; prefix invariant restored on device). This is what the
        bulk-rebuild path feeds to the static engines."""
        packed, true = compact_alive(self.edges, self.alive)
        plan = _plan_for(self.capacity, self.num_nodes, true, None)
        return DeviceGraph(packed, self.num_nodes, true, plan, name="log")

    def compact(self) -> jnp.ndarray:
        """In-place compaction: pack alive rows to the prefix, scrub the
        tail, and pull the append cursor back to the alive count (ONE
        host sync, for the cursor — this is a maintenance operation,
        not a steady-state tick). Returns the old→new row permutation
        (int32 [cap], -1 for retired rows) so holders of log-row
        indices — ``DynamicCC``'s maintained ``parent_eidx`` — can
        remap; dropping it on the floor is the seeded bug
        ``fixture.stale_forest_idx`` demonstrates."""
        self.edges, self.alive, perm, true = _compact_perm_jit(
            self.edges, self.alive)
        self.rows = int(true)
        return perm

    def __repr__(self) -> str:
        return (f"EdgeLog(|V|={self.num_nodes}, cap={self.capacity}, "
                f"rows={self.rows})")


def _plan_for(e_stored: int, num_nodes: int, true_edges,
              num_segments: int | None) -> SegmentationPlan:
    """Plan over the STORED row count, with the paper's s = 2|E|/|V|
    heuristic evaluated on the TRUE count when it is static (padding
    must not inflate the segment count)."""
    if num_segments is None:
        heur = true_edges if isinstance(true_edges, (int, np.integer)) \
            else e_stored
        num_segments = adaptive_num_segments(int(heur), num_nodes)
    return plan_segmentation(e_stored, num_nodes, num_segments)


def as_device_graph(graph, num_nodes: int | None = None, *,
                    num_segments: int | None = None) -> DeviceGraph:
    """Coerce any accepted graph spelling to a DeviceGraph:

      * a ``DeviceGraph`` — returned as-is (``num_segments`` override
        rebuilds the plan only);
      * a host ``Graph`` (anything with ``.edges``/``.num_nodes``);
      * raw ``(edges, num_nodes)`` arrays — the compatibility shim.
    """
    if isinstance(graph, DeviceGraph):
        if num_segments is not None and \
                num_segments != graph.plan.num_segments:
            plan = plan_segmentation(int(graph.edges.shape[0]),
                                     graph.num_nodes, num_segments)
            return DeviceGraph(graph.edges, graph.num_nodes,
                               graph.true_edges, plan, name=graph.name,
                               degree_skew=graph.degree_skew)
        return graph
    if hasattr(graph, "edges") and hasattr(graph, "num_nodes"):
        return DeviceGraph.from_edges(graph.edges, graph.num_nodes,
                                      num_segments=num_segments,
                                      name=getattr(graph, "name", "graph"))
    if num_nodes is None:
        raise ValueError("raw edge arrays need an explicit num_nodes")
    return DeviceGraph.from_edges(graph, num_nodes,
                                  num_segments=num_segments)
