"""Edge partitioning for distributed CC / GNN (host side).

The paper's segmentation is *temporal* (edge segments processed in
sequence on one device). Across a mesh it becomes *spatial*: edges are
partitioned over chips, each chip runs adaptive CC locally, and the
replicated parent array is merged with an elementwise ``min`` all-reduce
(monotone scatter-min commutes with elementwise min — see DESIGN.md §5).
"""
from __future__ import annotations

import numpy as np

from repro.graphs.format import Graph


def partition_edges(graph: Graph, num_parts: int, mode: str = "block"
                    ) -> np.ndarray:
    """Return edges reshaped to [num_parts, E/num_parts, 2] (padded with
    (0,0) no-op self loops).

    ``block``: contiguous slices (locality-friendly for sorted edge lists).
    ``hash``: by hash of min endpoint (degree-balancing for power-law).
    """
    edges = graph.edges
    e = edges.shape[0]
    per = (e + num_parts - 1) // num_parts
    pad = per * num_parts - e
    if mode == "hash":
        key = (edges.min(axis=1).astype(np.uint32) * np.uint32(2654435761)
               ) % np.uint32(num_parts)
        order = np.argsort(key, kind="stable")
        edges = edges[order]
    elif mode != "block":
        raise ValueError(f"unknown partition mode {mode!r}")
    if pad:
        edges = np.concatenate(
            [edges, np.zeros((pad, 2), dtype=edges.dtype)], axis=0)
    return edges.reshape(num_parts, per, 2)


def boundary_vertices(parts: np.ndarray) -> np.ndarray:
    """Vertices appearing in more than one partition (merge frontier)."""
    num_parts = parts.shape[0]
    seen = {}
    for p in range(num_parts):
        for v in np.unique(parts[p].reshape(-1)):
            seen.setdefault(int(v), set()).add(p)
    return np.array(sorted(v for v, ps in seen.items() if len(ps) > 1),
                    dtype=np.int32)
