"""GraphSAGE-style fanout neighbor sampler (host side, static shapes).

``minibatch_lg`` (Reddit-scale sampled training) requires a real neighbor
sampler: given CSR adjacency, seed nodes and per-layer fanouts, emit a
block of sampled edges per layer with *static* shapes (padded with
self-edges) so the training step jits once.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graphs.format import CSR


@dataclasses.dataclass
class SampledBlock:
    """One message-passing layer block: edges from sampled neighbors
    (src) into destination nodes (dst). All node ids are *global*."""
    src: np.ndarray        # int32 [n_dst * fanout]
    dst: np.ndarray        # int32 [n_dst * fanout]
    dst_nodes: np.ndarray  # int32 [n_dst] — the nodes updated this layer


@dataclasses.dataclass
class MiniBatch:
    blocks: list[SampledBlock]      # ordered input-layer -> output-layer
    input_nodes: np.ndarray         # nodes whose features must be gathered
    seed_nodes: np.ndarray          # the batch's target nodes


def sample_neighbors(csr: CSR, nodes: np.ndarray, fanout: int,
                     rng: np.random.Generator) -> SampledBlock:
    """Uniform with-replacement fanout sampling; isolated nodes fall back
    to self-edges (a no-op message under mean aggregation with self)."""
    n = nodes.shape[0]
    src = np.empty((n, fanout), dtype=np.int32)
    for i, v in enumerate(nodes):
        lo, hi = csr.indptr[v], csr.indptr[v + 1]
        deg = hi - lo
        if deg == 0:
            src[i] = v
        else:
            sel = rng.integers(0, deg, size=fanout)
            src[i] = csr.indices[lo + sel]
    dst = np.repeat(nodes.astype(np.int32), fanout)
    return SampledBlock(src=src.reshape(-1), dst=dst,
                        dst_nodes=nodes.astype(np.int32))


def sample_minibatch(csr: CSR, seeds: np.ndarray,
                     fanouts: Sequence[int],
                     rng: np.random.Generator) -> MiniBatch:
    """Layered sampling (outermost layer first in ``fanouts``), DGL-style:
    the layer-k block updates the frontier of layer k+1."""
    blocks: list[SampledBlock] = []
    frontier = np.asarray(seeds, dtype=np.int32)
    # sample from the output layer inward
    for fanout in reversed(list(fanouts)):
        blk = sample_neighbors(csr, frontier, fanout, rng)
        blocks.append(blk)
        frontier = np.unique(np.concatenate([blk.src, frontier]))
    blocks.reverse()
    return MiniBatch(blocks=blocks, input_nodes=frontier,
                     seed_nodes=np.asarray(seeds, dtype=np.int32))


class MiniBatchLoader:
    """Deterministic, seeded, epoch-shuffling minibatch stream with a
    bounded prefetch queue (straggler mitigation: the sampler runs ahead
    of the device step by up to ``prefetch`` batches)."""

    def __init__(self, csr: CSR, train_nodes: np.ndarray, batch_size: int,
                 fanouts: Sequence[int], seed: int = 0, prefetch: int = 2):
        self.csr = csr
        self.train_nodes = np.asarray(train_nodes, dtype=np.int32)
        self.batch_size = batch_size
        self.fanouts = list(fanouts)
        self.seed = seed
        self.prefetch = prefetch

    def epoch(self, epoch_idx: int):
        rng = np.random.default_rng((self.seed, epoch_idx))
        order = rng.permutation(self.train_nodes)
        for i in range(0, len(order) - self.batch_size + 1, self.batch_size):
            seeds = order[i:i + self.batch_size]
            yield sample_minibatch(self.csr, seeds, self.fanouts, rng)
