from repro.graphs.format import Graph, build_csr
from repro.graphs.device import DeviceGraph, as_device_graph
from repro.graphs import generators
