from repro.graphs.format import Graph, build_csr
from repro.graphs import generators
