from repro.graphs.format import Graph, build_csr
from repro.graphs.device import DeviceGraph, EdgeLog, as_device_graph
from repro.graphs import generators
