"""Graph containers and host-side format conversion (COO <-> CSR).

JAX has no CSR/CSC sparse support (BCOO only) — message passing in this
framework is implemented via edge-index gather + ``segment_sum`` scatter
(see ``repro.models.gnn``), and CSR here is a *host-side* structure used
by the neighbor sampler and the CC preprocessing pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class CSR:
    indptr: np.ndarray   # int64 [V+1]
    indices: np.ndarray  # int32 [E]

    @property
    def num_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]


def build_csr(edges: np.ndarray, num_nodes: int,
              symmetrize: bool = True) -> CSR:
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    src, dst = edges[:, 0], edges[:, 1]
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(indptr=indptr, indices=dst.astype(np.int32))


@dataclasses.dataclass
class Graph:
    """COO graph. ``edges`` stores each undirected edge once."""

    edges: np.ndarray                      # int32 [E, 2]
    num_nodes: int
    node_feat: Optional[np.ndarray] = None  # [V, d] float32
    edge_feat: Optional[np.ndarray] = None  # [E, d_e] float32
    labels: Optional[np.ndarray] = None      # [V] int32 (targets)
    name: str = "graph"

    def __post_init__(self):
        self.edges = np.asarray(self.edges, dtype=np.int32).reshape(-1, 2)

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def avg_degree(self) -> float:
        return 2.0 * self.num_edges / max(self.num_nodes, 1)

    def degrees(self) -> np.ndarray:
        deg = np.bincount(self.edges.reshape(-1).astype(np.int64),
                          minlength=self.num_nodes)
        return deg

    @property
    def max_degree(self) -> int:
        return int(self.degrees().max(initial=0))

    def to_csr(self, symmetrize: bool = True) -> CSR:
        return build_csr(self.edges, self.num_nodes, symmetrize=symmetrize)

    def symmetrized_edges(self) -> np.ndarray:
        """Both directions of every edge — the GNN message-passing view."""
        return np.concatenate([self.edges, self.edges[:, ::-1]], axis=0)

    def pad_edges(self, multiple: int) -> "Graph":
        """Pad the edge list with (0, 0) self loops to a static multiple
        (self loops are hook/message no-ops)."""
        e = self.num_edges
        target = ((e + multiple - 1) // multiple) * multiple
        if target == e:
            return self
        pad = np.zeros((target - e, 2), dtype=np.int32)
        return dataclasses.replace(
            self, edges=np.concatenate([self.edges, pad], axis=0))

    def permute_nodes(self, perm: np.ndarray) -> "Graph":
        """Relabel vertices by ``perm`` (tests: CC must be equivariant)."""
        perm = np.asarray(perm, dtype=np.int32)
        new = dataclasses.replace(self, edges=perm[self.edges])
        if self.node_feat is not None:
            inv = np.empty_like(perm)
            inv[perm] = np.arange(perm.size, dtype=np.int32)
            new.node_feat = self.node_feat[inv]
        return new

    def stats(self) -> dict:
        deg = self.degrees()
        return {
            "name": self.name,
            "nodes": self.num_nodes,
            "edges": self.num_edges,
            "avg_degree": round(self.avg_degree, 2),
            "max_degree": int(deg.max(initial=0)),
            "size_mb": round(self.edges.nbytes / 2**20, 2),
        }
