"""Synthetic graph generators.

The paper evaluates on four graph classes (Table I): road maps (usa-osm,
euro-osm — avg degree ~2, huge diameter), social networks
(soc-live-journal — avg degree ~14, power law), and synthetic Kronecker
(kron-logn21 — avg degree ~87, heavy power law). The datasets are not
redistributable here, so the benchmarks run on *scaled stand-ins* matched
on the structural property the adaptive heuristic keys on: the average
degree (plus diameter regime / skew). Full-size shape specs live in
``repro.configs.cc_graphs`` for the dry-run.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.format import Graph


def _rng(seed) -> np.random.Generator:
    return np.random.default_rng(seed)


def grid_road(side: int, extra_prob: float = 0.05, seed: int = 0,
              name: str = "road") -> Graph:
    """2D grid + sparse diagonal shortcuts: road-network stand-in
    (avg degree ≈ 2, O(side) diameter)."""
    rng = _rng(seed)
    ids = np.arange(side * side, dtype=np.int64).reshape(side, side)
    right = np.stack([ids[:, :-1].reshape(-1), ids[:, 1:].reshape(-1)], 1)
    down = np.stack([ids[:-1, :].reshape(-1), ids[1:, :].reshape(-1)], 1)
    edges = np.concatenate([right, down], axis=0)
    # drop a fraction of edges so avg degree lands near the 2.0-2.4 regime
    keep = rng.random(edges.shape[0]) > 0.35
    edges = edges[keep]
    n_extra = int(extra_prob * side * side)
    if n_extra:
        diag = np.stack([ids[:-1, :-1].reshape(-1), ids[1:, 1:].reshape(-1)], 1)
        sel = rng.choice(diag.shape[0], size=min(n_extra, diag.shape[0]),
                         replace=False)
        edges = np.concatenate([edges, diag[sel]], axis=0)
    return Graph(edges=edges, num_nodes=side * side, name=name)


def random_uniform(num_nodes: int, num_edges: int, seed: int = 0,
                   name: str = "uniform") -> Graph:
    rng = _rng(seed)
    edges = rng.integers(0, num_nodes, size=(num_edges, 2), dtype=np.int64)
    return Graph(edges=edges, num_nodes=num_nodes, name=name)


def rmat(scale: int, edge_factor: int, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0, name: str = "rmat") -> Graph:
    """R-MAT / Kronecker generator (Graph500 defaults) — power-law
    stand-in for kron-logn21 / soc-live-journal."""
    rng = _rng(seed)
    n = 1 << scale
    e = n * edge_factor
    src = np.zeros(e, dtype=np.int64)
    dst = np.zeros(e, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(e)
        go_right = (r >= a) & (r < ab)          # top-right quadrant
        go_down = (r >= ab) & (r < abc)         # bottom-left
        go_diag = r >= abc                       # bottom-right
        src += ((go_down | go_diag) << bit).astype(np.int64)
        dst += ((go_right | go_diag) << bit).astype(np.int64)
    edges = np.stack([src, dst], axis=1)
    return Graph(edges=edges, num_nodes=n, name=name)


def star(num_nodes: int, center: int = 0) -> Graph:
    others = np.array([i for i in range(num_nodes) if i != center],
                      dtype=np.int64)
    edges = np.stack([np.full_like(others, center), others], axis=1)
    return Graph(edges=edges, num_nodes=num_nodes, name="star")


def chain(num_nodes: int) -> Graph:
    idx = np.arange(num_nodes - 1, dtype=np.int64)
    return Graph(edges=np.stack([idx, idx + 1], 1), num_nodes=num_nodes,
                 name="chain")


def disjoint_cliques(num_cliques: int, clique_size: int,
                     seed: int = 0) -> Graph:
    blocks = []
    for k in range(num_cliques):
        base = k * clique_size
        i, j = np.triu_indices(clique_size, k=1)
        blocks.append(np.stack([i + base, j + base], axis=1))
    edges = np.concatenate(blocks, axis=0)
    return Graph(edges=edges, num_nodes=num_cliques * clique_size,
                 name="cliques")


def molecule_batch(num_graphs: int, nodes_per_graph: int,
                   edges_per_graph: int, d_feat: int = 16,
                   seed: int = 0) -> Graph:
    """Block-diagonal batch of small random molecules (GIN/NequIP shape)."""
    rng = _rng(seed)
    blocks, feats = [], []
    for g in range(num_graphs):
        base = g * nodes_per_graph
        # random connected-ish: a spanning chain + random extras
        idx = np.arange(nodes_per_graph - 1, dtype=np.int64)
        chain_e = np.stack([idx, idx + 1], 1)
        extra = rng.integers(0, nodes_per_graph,
                             size=(max(edges_per_graph - len(chain_e), 0), 2),
                             dtype=np.int64)
        blocks.append(np.concatenate([chain_e, extra], axis=0) + base)
        feats.append(rng.standard_normal((nodes_per_graph, d_feat)))
    return Graph(
        edges=np.concatenate(blocks, axis=0),
        num_nodes=num_graphs * nodes_per_graph,
        node_feat=np.concatenate(feats, axis=0).astype(np.float32),
        name="molecules")


# --------------------------------------------------------------------------
# Table I stand-ins (scaled; matched on avg degree / structure class)
# --------------------------------------------------------------------------

TABLE1_FULL = {
    # name: (nodes, edges, avg_degree, class)
    "usa-osm": (24_000_000, 58_000_000, 2.41, "road"),
    "euro-osm-karls": (174_000_000, 348_000_000, 2.00, "road"),
    "soc-live-journal": (5_000_000, 69_000_000, 14.23, "social"),
    "kron-logn21": (2_000_000, 182_000_000, 86.82, "kron"),
}


def table1_scaled(name: str, scale: float = 1 / 256, seed: int = 0) -> Graph:
    """Scaled stand-in for a Table I graph, same avg-degree regime."""
    if name not in TABLE1_FULL:
        raise KeyError(f"unknown graph {name!r}; have {list(TABLE1_FULL)}")
    nodes, edges, deg, klass = TABLE1_FULL[name]
    if klass == "road":
        side = max(8, int((nodes * scale) ** 0.5))
        return grid_road(side, extra_prob=0.02, seed=seed, name=name)
    if klass == "social":
        sc = max(10, int(np.log2(max(nodes * scale, 2))))
        return rmat(sc, edge_factor=max(2, int(deg / 2)), a=0.45, b=0.22,
                    c=0.22, seed=seed, name=name)
    # kron
    sc = max(10, int(np.log2(max(nodes * scale, 2))))
    return rmat(sc, edge_factor=max(2, int(deg / 2)), seed=seed, name=name)
