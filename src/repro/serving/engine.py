"""Batched LM serving: prefill/decode with slot-based continuous batching.

Correctness model (right-padding; see models/transformer.py):

* Requests are right-padded into a fixed prompt buffer; the plain causal
  mask is per-request correct during prefill, because padding keys live
  at positions the real queries never attend to.
* At decode, request ``b`` generates at position ``len_b + t`` — written
  into slot ``position`` (full cache) or ``position % W`` (ring). A
  stale slot (prefill garbage at index g >= len_b) only becomes causally
  visible when the query reaches position g — the exact step at which
  the new token is written into slot g (g % W) *before* attention runs,
  so garbage is never attended. Stored per-slot positions drive the
  causal/window mask; -1 marks empty slots.

``Engine`` implements **continuous batching**: a fixed number of slots;
finished requests release their slot mid-flight and a queued request is
prefilled into it (a [1, P] prefill jit + cache splice) while the other
slots keep decoding — no global drain between requests.

The engine is jit-compiled per (batch_slots, prompt_buf, cache_buf)
triple; production serving lowers the same ``decode_step`` under mesh
shardings (launch/dryrun.py's ``serve_step`` cells).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


# --------------------------------------------------------------------------
# jitted kernels (static: cfg identity, shapes)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill(params, tokens, cache, valid_len, cfg):
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    logits, cache = T.forward_with_cache(params, tokens, cfg, cache,
                                         positions, valid_len=valid_len)
    return logits, cache


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(2,))
def _decode(params, tokens, cache, positions, cfg):
    logits, cache = T.forward_with_cache(params, tokens[:, None], cfg,
                                         cache, positions[:, None])
    return logits[:, 0], cache


@functools.partial(jax.jit, donate_argnums=(0,))
def _splice(batch_cache, one_cache, slot):
    """Copy a single-request cache into slot ``slot`` of the batch cache."""
    def put(b, o):
        if b.ndim >= 2 and o.shape[0] == b.shape[0]:   # stacked layer leaf
            # layer-stacked leaves: [L, 1, ...] -> write [L, slot, ...]
            return jax.lax.dynamic_update_slice_in_dim(
                b, o.astype(b.dtype), slot, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(
            b, o.astype(b.dtype), slot, axis=0)

    # pos arrays are [B, S]; layer leaves are [L, B, S, ...]
    out = {}
    for key, val in batch_cache.items():
        if key == "layers":
            out[key] = jax.tree.map(
                lambda b, o: jax.lax.dynamic_update_slice_in_dim(
                    b, o.astype(b.dtype), slot, axis=1), val,
                one_cache[key])
        else:
            out[key] = jax.lax.dynamic_update_slice_in_dim(
                val, one_cache[key].astype(val.dtype), slot, axis=0)
    return out


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_top_p(logits: jnp.ndarray, rng, p: float = 0.9,
                 temp: float = 1.0) -> jnp.ndarray:
    """Nucleus sampling (vectorized over the batch)."""
    logits = logits / max(temp, 1e-6)
    sorted_idx = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, sorted_idx, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    mask = jnp.cumsum(probs, axis=-1) - probs > p
    sorted_logits = jnp.where(mask, -1e30, sorted_logits)
    choice = jax.random.categorical(rng, sorted_logits, axis=-1)
    return jnp.take_along_axis(sorted_idx, choice[:, None],
                               axis=-1)[:, 0].astype(jnp.int32)


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # int32 [len]
    max_new: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Continuous-batching serving engine.

    Slots decode in lockstep (one fused decode step per tick); empty or
    finished slots are refilled from the queue via single-request
    prefill + cache splice. Per-request positions make mixed-progress
    slots correct.
    """

    def __init__(self, params, cfg: T.LMConfig, *, slots: int = 4,
                 prompt_buf: int = 64, cache_buf: int = 256,
                 eos_id: int = -1):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.prompt_buf = prompt_buf
        self.cache_buf = cache_buf
        self.eos_id = eos_id
        self.cache = T.init_cache(cfg, slots, cache_buf)
        self.active: list[Optional[Request]] = [None] * slots
        self.lengths = np.zeros(slots, np.int32)    # tokens in cache
        self.last_token = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self._uid = 0

    def submit(self, prompt, max_new: int = 32) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_new))
        return self._uid

    # -- internals ---------------------------------------------------------

    def _admit(self):
        """Fill free slots from the queue (prefill + splice)."""
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            assert plen <= self.prompt_buf, "prompt exceeds buffer"
            toks = np.zeros((1, self.prompt_buf), np.int32)
            toks[0, :plen] = req.prompt
            one_cache = T.init_cache(self.cfg, 1, self.cache_buf)
            logits, one_cache = _prefill(self.params, jnp.asarray(toks),
                                         one_cache,
                                         jnp.asarray([plen], jnp.int32),
                                         self.cfg)
            # mark slots beyond the real prompt as empty again
            pos = np.array(one_cache["pos"])
            pos[0, plen:self.prompt_buf] = -1
            one_cache = {**one_cache, "pos": jnp.asarray(pos)}
            if "pos_local" in one_cache:
                pl = np.array(one_cache["pos_local"])
                pl[pl >= plen] = -1
                one_cache = {**one_cache, "pos_local": jnp.asarray(pl)}
            self.cache = _splice(self.cache, one_cache, s)
            self.active[s] = req
            self.lengths[s] = plen
            self.last_token[s] = int(greedy(logits[:, plen - 1])[0])
            req.out_tokens.append(int(self.last_token[s]))

    def _retire(self):
        for s, req in enumerate(self.active):
            if req is None:
                continue
            hit_eos = req.out_tokens and req.out_tokens[-1] == self.eos_id
            if len(req.out_tokens) >= req.max_new or hit_eos or \
                    self.lengths[s] + 1 >= self.cache_buf:
                req.done = True
                self.active[s] = None

    def step(self) -> None:
        """One engine tick: admit, decode every active slot, retire."""
        self._admit()
        if not any(r is not None for r in self.active):
            return
        tokens = jnp.asarray(self.last_token)
        positions = jnp.asarray(self.lengths)
        logits, self.cache = _decode(self.params, tokens, self.cache,
                                     positions, self.cfg)
        nxt = np.asarray(greedy(logits))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.lengths[s] += 1
            self.last_token[s] = nxt[s]
            req.out_tokens.append(int(nxt[s]))
        self._retire()

    def run(self) -> list[Request]:
        """Drain queue + slots; returns all completed requests."""
        finished: list[Request] = []
        seen: set[int] = set()
        all_reqs = list(self.queue)
        while self.queue or any(r is not None for r in self.active):
            self.step()
            for r in all_reqs:
                if r.done and r.uid not in seen:
                    seen.add(r.uid)
                    finished.append(r)
        return finished


def generate(params, cfg: T.LMConfig, prompts: np.ndarray,
             max_new: int = 16, cache_buf: int = 0) -> np.ndarray:
    """Simple batched greedy generation (no continuous batching):
    prompts [B, P] right-padded with -1."""
    b, p = prompts.shape
    lengths = np.asarray((prompts >= 0).sum(axis=1), np.int32)
    toks = np.where(prompts >= 0, prompts, 0).astype(np.int32)
    buf = cache_buf or (p + max_new)
    cache = T.init_cache(cfg, b, buf)
    logits, cache = _prefill(params, jnp.asarray(toks), cache,
                             jnp.asarray(lengths), cfg)
    # void padding slots
    pos = np.array(cache["pos"])
    for i in range(b):
        pos[i, lengths[i]:p] = -1
    cache = {**cache, "pos": jnp.asarray(pos)}
    if "pos_local" in cache:
        pl = np.array(cache["pos_local"])
        for i in range(b):
            pl[i][pl[i] >= lengths[i]] = -1
        cache = {**cache, "pos_local": jnp.asarray(pl)}

    last = np.asarray(greedy(
        jnp.take_along_axis(logits, jnp.asarray(lengths - 1)[:, None, None],
                            axis=1)[:, 0]))
    out = [last]
    positions = lengths.copy()
    for _ in range(max_new - 1):
        logits1, cache = _decode(params, jnp.asarray(last), cache,
                                 jnp.asarray(positions), cfg)
        last = np.asarray(greedy(logits1))
        out.append(last)
        positions += 1
    return np.stack(out, axis=1)
