"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (tests see 1 CPU device; only dryrun.py forces
512 host devices via XLA_FLAGS before any jax import).

Axes:
  * ``pod``   — inter-pod data parallelism (2 pods in the multi-pod
                dry-run; scaling to N pods is this one tuple).
  * ``data``  — intra-pod data parallelism / FSDP shard axis.
  * ``model`` — tensor/expert parallelism.

All shardings in the framework are written against axis *names*; the
``fsdp_axes`` helper returns the data-parallel axis group for either
mesh so model code never branches on pod count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def fsdp_axes(multi_pod: bool = False):
    """The axis group batch/FSDP dims shard over."""
    return ("pod", "data") if multi_pod else ("data",)


def all_axes(multi_pod: bool = False):
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def make_cpu_mesh(n: int = 1, axis: str = "data"):
    """Single-host test mesh over whatever devices exist."""
    devs = jax.devices()[:n]
    return jax.sharding.Mesh(devs, (axis,))
