import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first
# init. Only the dry-run sees 512 placeholder devices; tests and
# benchmarks see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh, print memory/cost analysis, and append the
roofline record to a JSONL results file.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --arch all                 # every cell
  python -m repro.launch.dryrun --arch all --multi-pod     # 2×16×16
  python -m repro.launch.dryrun --arch cc-adaptive --shape usa-osm

Each cell runs ``jit(step).lower(...).compile()`` — a sharding mismatch,
compile-time OOM, or unsupported collective is a BUG in the framework
and fails the run. Results: benchmarks/results/dryrun_<mesh>.jsonl.
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_path: str | None = None, verbose: bool = True) -> dict:
    import jax  # deferred: after XLA_FLAGS
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell, lower_cell
    from repro.roofline import analysis as RA

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.perf_counter()
    cell = build_cell(arch, shape, mesh, multi_pod=multi_pod)
    lowered = lower_cell(cell, mesh)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    model_flops = _model_flops(arch, shape)
    text = compiled.as_text()
    roof = RA.analyze(compiled, arch=arch, shape=shape, chips=chips,
                      model_flops=model_flops, hlo_text=text)
    mem = RA.memory_summary(compiled)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": cell.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": roof.as_dict(),
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape} on {rec['mesh']}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  memory/chip: {mem.get('total_gib', '?')} GiB "
              f"(args {mem.get('argument_size_in_bytes', 0)/2**30:.2f} + "
              f"temp {mem.get('temp_size_in_bytes', 0)/2**30:.2f})")
        print(f"  roofline: compute {roof.t_compute*1e3:.2f} ms | "
              f"memory {roof.t_memory*1e3:.2f} ms | "
              f"collective {roof.t_collective*1e3:.2f} ms "
              f"-> {roof.bottleneck}-bound")
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def _model_flops(arch: str, shape: str) -> float:
    """Analytic MODEL_FLOPS for the cell (global, per step)."""
    from repro.configs import get_arch
    if arch == "cc-adaptive":
        return 0.0
    mod = get_arch(arch)
    if mod.FAMILY == "lm":
        from repro.models.transformer import model_flops_per_token
        from repro.configs.lm_common import SHAPE_DEFS
        cfg = mod.make_config()
        d = SHAPE_DEFS[shape]
        f_tok = model_flops_per_token(cfg)
        if d["kind"] == "train":
            return f_tok * d["batch"] * d["seq"]
        if d["kind"] == "prefill":
            return f_tok / 3.0 * d["batch"] * d["seq"]   # fwd only: 2N
        return f_tok / 3.0 * d["batch"]                  # one token
    if mod.FAMILY == "recsys":
        import math
        from repro.models import recsys as R
        cfg = mod.make_config()
        d = mod.SHAPE_DEFS[shape]
        dense_params = R.param_count(cfg) - cfg.total_rows * cfg.embed_dim
        mult = 6.0 if mod.step_kind(shape) == "train" else 2.0
        return mult * dense_params * d["batch"]
    return 0.0       # GNN: recorded via HLO flops only


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="arch id | 'all' | 'cc-adaptive'")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, all_cells, get_arch
    from repro.configs import cc_graphs

    if args.arch == "all":
        cells = [(a, s, r) for a, s, r in all_cells()]
        cells += [("cc-adaptive", s, None) for s in cc_graphs.SHAPES]
    elif args.arch == "cc-adaptive":
        shapes = [args.shape] if args.shape else list(cc_graphs.SHAPES)
        cells = [("cc-adaptive", s, None) for s in shapes]
    else:
        mod = get_arch(args.arch)
        shapes = [args.shape] if args.shape else list(mod.SHAPES)
        cells = [(args.arch, s, mod.skip_reason(s)) for s in shapes]

    done = set()
    if args.skip_existing and args.out:
        try:
            with open(args.out) as f:
                for line in f:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
        except FileNotFoundError:
            pass

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    failures = []
    for arch, shape, skip in cells:
        if skip:
            print(f"[dryrun] SKIP {arch} × {shape}: {skip}")
            continue
        if (arch, shape, mesh_name) in done:
            print(f"[dryrun] cached {arch} × {shape}")
            continue
        try:
            run_cell(arch, shape, args.multi_pod, out_path=args.out)
        except Exception as e:   # noqa: BLE001
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("[dryrun] all cells passed")


if __name__ == "__main__":
    main()
