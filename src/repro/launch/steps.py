"""Per-(arch × shape) step builders: the functions the dry-run lowers
and the launcher runs.

``build_cell(arch_id, shape, mesh, multi_pod)`` returns a ``Cell`` with
the step callable, example ShapeDtypeStruct arguments, and the
NamedSharding trees for inputs — everything ``jax.jit(...).lower()``
needs. The same builders back the real training launcher (train.py) so
the dry-run lowers EXACTLY what would run on hardware.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.launch.mesh import all_axes, fsdp_axes
from repro.models.sharding import logical_axes
from repro.train import train_state
from repro.train.optimizer import AdamWConfig, adamw


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step: Callable                 # the function to lower
    args: tuple                    # ShapeDtypeStruct pytrees
    in_shardings: tuple            # NamedSharding pytrees (same structure)
    donate: tuple = ()
    logical: dict = dataclasses.field(default_factory=dict)


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _model_api(arch_id: str, shape: str):
    """(model module, config) for an (arch, shape) cell."""
    mod = get_arch(arch_id)
    if mod.FAMILY == "lm":
        from repro.models import transformer as M
        return M, mod.make_config()
    if mod.FAMILY == "recsys":
        from repro.models import recsys as M
        return M, mod.make_config()
    # gnn: config depends on the shape (feature dims / classes)
    if arch_id == "nequip":
        from repro.models.gnn import nequip as M
    elif arch_id == "gatedgcn":
        from repro.models.gnn import gatedgcn as M
    elif arch_id == "graphsage-reddit":
        from repro.models.gnn import graphsage as M
    elif arch_id == "gin-tu":
        from repro.models.gnn import gin as M
    else:
        raise KeyError(arch_id)
    return M, mod.make_config(shape)


def _state_structs(M, cfg, opt):
    """ShapeDtypeStruct TrainState (no allocation)."""
    def build():
        params = M.init(jax.random.PRNGKey(0), cfg)
        return train_state.create(params, opt)
    return jax.eval_shape(build)


def _state_pspecs(M, cfg, state_struct, fsdp, tp="model"):
    pspec = M.param_spec(cfg, fsdp, tp)
    opt_spec = {k: pspec for k in state_struct["opt"]}
    return {"params": pspec, "opt": opt_spec, "step": P()}


def _moment_dtype(arch_id):
    mod = get_arch(arch_id)
    return getattr(mod, "MOMENT_DTYPE", None)


# --------------------------------------------------------------------------
# LM cache sharding
# --------------------------------------------------------------------------

def _cache_pspec(cfg, cache_struct, fsdp, mesh, tp="model"):
    """PartitionSpec tree for an LM KV cache: batch (slot) dim over the
    data axes, sequence dim over 'model' (kv-head counts sit below the
    16-way tensor axis, so the seq dim is the shardable bulk — a 32k×128
    qwen cache is 17 GB/chip batch-only but 1.1 GB batch×seq). Dims that
    don't divide their axes replicate (batch=1 long-context)."""
    import math
    fs = fsdp if isinstance(fsdp, tuple) else (fsdp,)
    n_fs = math.prod(mesh.shape[a] for a in fs)
    n_tp = mesh.shape[tp]

    def leaf_spec(leaf):
        nd = len(leaf.shape)
        if nd == 2:                      # pos arrays [B, S]
            b_ax = fs if leaf.shape[0] % n_fs == 0 else None
            s_ax = tp if leaf.shape[1] % n_tp == 0 else None
            return P(b_ax, s_ax)
        # layer-stacked leaves [L, B, S, ...]
        b_ax = fs if leaf.shape[1] % n_fs == 0 else None
        s_ax = tp if leaf.shape[2] % n_tp == 0 else None
        return P(None, b_ax, s_ax, *(None,) * (nd - 3))

    return jax.tree.map(leaf_spec, cache_struct)


# --------------------------------------------------------------------------
# Family builders
# --------------------------------------------------------------------------

def _build_lm(arch_id, shape, mesh, fsdp) -> Cell:
    mod = get_arch(arch_id)
    M, cfg = _model_api(arch_id, shape)
    kind = mod.step_kind(shape)
    specs = mod.input_specs(shape)
    fs = fsdp

    if kind == "train":
        opt = adamw(AdamWConfig(lr=3e-4, moment_dtype=_moment_dtype(
            arch_id)))
        state_struct = _state_structs(M, cfg, opt)
        state_spec = _state_pspecs(M, cfg, state_struct, fs)
        loss = functools.partial(_lm_loss, M=M, cfg=cfg)
        # gradient accumulation keeps live activations ≈ 4 seq/chip
        # per microbatch (16 GB/chip HBM budget; DESIGN §6)
        accum = getattr(get_arch(arch_id), "ACCUM_STEPS", 4)
        # archs with bf16 moments (grok: 314B params vs 4 TB pod HBM)
        # also accumulate grads in bf16
        step = train_state.make_train_step(
            loss, opt, accum_steps=accum,
            accum_dtype=_moment_dtype(arch_id))
        batch_spec = M.batch_spec(fs)
        return Cell(arch_id, shape, kind, step,
                    args=(state_struct, specs["batch"]),
                    in_shardings=(_named(mesh, state_spec),
                                  _named(mesh, batch_spec)),
                    donate=(0,))

    params_struct = jax.eval_shape(
        lambda: M.init(jax.random.PRNGKey(0), cfg))
    pspec = M.param_spec(cfg, fs)
    if kind == "prefill":
        def step(params, tokens, cache):
            positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
            return M.forward_with_cache(params, tokens, cfg, cache,
                                        positions)
        cache_struct = specs["cache"]
        cspec = _cache_pspec(cfg, cache_struct, fs, mesh)
        return Cell(arch_id, shape, kind, step,
                    args=(params_struct, specs["tokens"], cache_struct),
                    in_shardings=(_named(mesh, pspec),
                                  NamedSharding(mesh, P(fs, None)),
                                  _named(mesh, cspec)),
                    donate=(2,))

    def step(params, tokens, positions, cache):
        return M.forward_with_cache(params, tokens[:, None], cfg, cache,
                                    positions[:, None])

    import math
    n_fs = math.prod(mesh.shape[a] for a in fs)
    cache_struct = specs["cache"]
    cspec = _cache_pspec(cfg, cache_struct, fs, mesh)
    tok_spec = P(fs) if specs["tokens"].shape[0] % n_fs == 0 else P()
    return Cell(arch_id, shape, kind, step,
                args=(params_struct, specs["tokens"],
                      specs["positions"], cache_struct),
                in_shardings=(_named(mesh, pspec),
                              NamedSharding(mesh, tok_spec),
                              NamedSharding(mesh, tok_spec),
                              _named(mesh, cspec)),
                donate=(3,))


def _lm_loss(params, batch, *, M, cfg):
    return M.loss_fn(params, batch, cfg)


def _build_gnn_shardmap(arch_id, shape, mesh, fsdp) -> Cell:
    """NequIP under ``shard_map``: nodes AND edges sharded; each layer
    all-gathers feats and reduce-scatters messages (one collective pair
    per layer — GSPMD's per-chunk reshards cost 224 s collective time on
    the ogb cell). Gradients psum uniformly: node-side compute runs on
    node shards, edge-side on edge shards, so every shard's grad is a
    partial sum. The same spatial-sharding design as the paper's
    distributed CC (DESIGN.md §5)."""
    import dataclasses as dc
    from jax.experimental.shard_map import shard_map

    mod = get_arch(arch_id)
    M, cfg0 = _model_api(arch_id, shape)
    cfg = dc.replace(cfg0, dist_axes=fsdp)
    specs = mod.input_specs(shape)
    opt = adamw(AdamWConfig(lr=1e-3))
    state_struct = _state_structs(M, cfg0, opt)

    batch_struct = specs["batch"]
    n_nodes = batch_struct["positions"].shape[0]

    def batch_pspec(key, leaf):
        if key in ("src", "dst"):
            return P(fsdp)                      # edge shards
        if leaf.shape[0] == n_nodes:
            return P(fsdp, *(None,) * (len(leaf.shape) - 1))
        return P(*(None,) * len(leaf.shape))    # graph-level: replicate

    bspec = {k: batch_pspec(k, v) for k, v in batch_struct.items()}

    def local_grads(params, batch_local):
        def loss(p):
            return M.loss_fn(p, batch_local, cfg)
        l, g = jax.value_and_grad(loss)(params)
        g = jax.tree.map(lambda x: jax.lax.psum(x, fsdp), g)
        return jax.lax.pmean(l, fsdp), g

    grad_fn = shard_map(
        local_grads, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), state_struct["params"]),
                  bspec),
        out_specs=(P(), jax.tree.map(lambda _: P(),
                                     state_struct["params"])),
        check_rep=False)

    from repro.train.optimizer import apply_updates

    def step(state, batch):
        loss, grads = grad_fn(state["params"], batch)
        updates, new_opt, gnorm = opt.update(
            grads, state["opt"], state["params"], state["step"])
        new_params = apply_updates(state["params"], updates)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1},
                {"loss": loss, "grad_norm": gnorm})

    state_spec = jax.tree.map(lambda _: P(), state_struct)
    return Cell(arch_id, shape, "train", step,
                args=(state_struct, batch_struct),
                in_shardings=(_named(mesh, state_spec),
                              _named(mesh, bspec)),
                donate=(0,))


def _build_gnn(arch_id, shape, mesh, fsdp) -> Cell:
    if arch_id == "nequip":
        return _build_gnn_shardmap(arch_id, shape, mesh, fsdp)
    mod = get_arch(arch_id)
    M, cfg = _model_api(arch_id, shape)
    specs = mod.input_specs(shape)
    opt = adamw(AdamWConfig(lr=1e-3))
    state_struct = _state_structs(M, cfg, opt)
    state_spec = _state_pspecs(M, cfg, state_struct, fsdp)

    def loss(params, batch):
        return M.loss_fn(params, batch, cfg)

    step = train_state.make_train_step(loss, opt)

    import math
    # GNNs don't use tensor parallelism (hidden dims are tiny) — fold
    # the 'model' axis into the edge/node sharding for 256/512-way
    # graph parallelism; fall back to fsdp-only, then replicated, when
    # a dim doesn't divide (molecule-scale graphs).
    wide = tuple(fsdp) + ("model",)
    n_wide = math.prod(mesh.shape[a] for a in wide)
    n_fsdp = math.prod(mesh.shape[a] for a in fsdp)

    def batch_leaf_spec(leaf):
        if leaf.shape[0] % n_wide == 0:
            ax = wide
        elif leaf.shape[0] % n_fsdp == 0:
            ax = fsdp
        else:
            return P(*(None,) * len(leaf.shape))
        if len(leaf.shape) == 2:
            return P(ax, None)
        return P(ax)

    bspec = jax.tree.map(batch_leaf_spec, specs["batch"])
    return Cell(arch_id, shape, "train", step,
                args=(state_struct, specs["batch"]),
                in_shardings=(_named(mesh, state_spec),
                              _named(mesh, bspec)),
                donate=(0,))


def _build_recsys(arch_id, shape, mesh, fsdp) -> Cell:
    mod = get_arch(arch_id)
    M, cfg = _model_api(arch_id, shape)
    kind = mod.step_kind(shape)
    specs = mod.input_specs(shape)
    bspec = M.batch_spec(fsdp)

    if kind == "train":
        opt = adamw(AdamWConfig(lr=1e-3))
        state_struct = _state_structs(M, cfg, opt)
        state_spec = _state_pspecs(M, cfg, state_struct, fsdp)

        def loss(params, batch):
            return M.loss_fn(params, batch, cfg)

        step = train_state.make_train_step(loss, opt)
        return Cell(arch_id, shape, kind, step,
                    args=(state_struct, specs["batch"]),
                    in_shardings=(_named(mesh, state_spec),
                                  _named(mesh, bspec)),
                    donate=(0,))

    params_struct = jax.eval_shape(
        lambda: M.init(jax.random.PRNGKey(0), cfg))
    pspec = M.param_spec(cfg, fsdp)
    if kind == "serve":
        def step(params, batch):
            return M.forward(params, batch, cfg)
        return Cell(arch_id, shape, kind, step,
                    args=(params_struct, specs["batch"]),
                    in_shardings=(_named(mesh, pspec),
                                  _named(mesh, bspec)))

    # retrieval: 1 query × 1M candidates
    def step(params, batch, candidate_ids):
        return M.retrieval_scores(params, batch, cfg, candidate_ids)

    bspec1 = jax.tree.map(lambda _: P(), bspec)   # batch=1: replicate
    return Cell(arch_id, shape, kind, step,
                args=(params_struct, specs["batch"],
                      specs["candidate_ids"]),
                in_shardings=(_named(mesh, pspec),
                              _named(mesh, bspec1),
                              NamedSharding(mesh, P(fsdp))))


def _build_cc(shape, mesh, multi_pod) -> Cell:
    """The paper's distributed CC on a Table I graph (full size)."""
    from repro.configs import cc_graphs
    # AOT lowering needs the raw edges-level jitted entry (fn.on_edges)
    # over ShapeDtypeStructs; the Solver facade only exposes the
    # concrete-plan path.  # analysis: ok[pallas-ast]
    from repro.core.distributed import build_distributed_cc
    import numpy as np

    from repro.core.segmentation import plan_segmentation
    from repro.graphs.device import DeviceGraph

    specs = cc_graphs.input_specs(shape)
    axes = all_axes(multi_pod)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    e = specs["edges"].shape[0]
    per = (e + n_shards - 1) // n_shards
    padded = jax.ShapeDtypeStruct((per * n_shards, 2), jnp.int32)
    # abstract DeviceGraph: shape/plan metadata only, no real edges
    dg = DeviceGraph(padded, specs["num_nodes"], e,
                     plan_segmentation(per * n_shards,
                                       specs["num_nodes"]))
    fn = build_distributed_cc(dg, mesh, axis_names=axes)
    # lower the raw edges-level entry point over the ShapeDtypeStruct
    return Cell("cc-adaptive", shape, "cc", fn.on_edges, args=(padded,),
                in_shardings=(NamedSharding(mesh, P(axes, None)),))


def build_cell(arch_id: str, shape: str, mesh: Mesh,
               multi_pod: bool = False) -> Cell:
    fs = fsdp_axes(multi_pod)
    logical = {"batch": fs, "tp": "model"}
    if arch_id == "cc-adaptive":
        cell = _build_cc(shape, mesh, multi_pod)
    else:
        mod = get_arch(arch_id)
        if mod.FAMILY == "lm":
            cell = _build_lm(arch_id, shape, mesh, fs)
        elif mod.FAMILY == "gnn":
            cell = _build_gnn(arch_id, shape, mesh, fs)
        else:
            cell = _build_recsys(arch_id, shape, mesh, fs)
    cell.logical = logical
    return cell


def lower_cell(cell: Cell, mesh: Mesh):
    """jit + lower (no compile) under the mesh, with the cell's logical
    activation-sharding axes bound (models pin batch dims of large
    intermediates through repro.models.sharding.constrain)."""
    fn = cell.step
    jitted = jax.jit(fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate)
    with mesh, logical_axes(cell.logical or None):
        return jitted.lower(*cell.args)
