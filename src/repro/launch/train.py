"""End-to-end training launcher: ``--arch <id> [--shape <s>]``.

Assembles the SAME step the dry-run lowers (launch/steps.py) with the
real substrate: deterministic data pipeline (+ prefetch), jitted
sharded step, async atomic checkpointing, restart-on-failure, and the
step-time watchdog. On this CPU container it runs the *smoke* config of
the chosen architecture end-to-end (the full config is exercised by the
dry-run); on hardware the ``--full`` flag selects the production config
under the production mesh — the code path is identical.

Examples:
  python -m repro.launch.train --arch gemma2-2b --steps 100
  python -m repro.launch.train --arch dcn-v2 --steps 200 --ckpt /tmp/ck
  python -m repro.launch.train --arch gin-tu --steps 50 --fail-at 20
"""
from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import pipeline as dp
from repro.train import train_state
from repro.train.fault_tolerance import (SimulatedFailure, StepWatchdog,
                                         run_with_restarts)
from repro.train.optimizer import AdamWConfig, adamw, cosine_schedule


def _smoke_stream(arch_id: str, cfg, seed: int, batch: int):
    """(start_step -> iterator) for the arch's family, smoke-sized."""
    mod = get_arch(arch_id)
    if mod.FAMILY == "lm":
        def make(start):
            return dp.make_stream(dp.lm_batches, seed, batch, 32,
                                  cfg.vocab, start_step=start)
        return make
    if mod.FAMILY == "recsys":
        def make(start):
            return dp.make_stream(dp.recsys_batches, seed, batch,
                                  cfg.n_dense, cfg.table_sizes,
                                  start_step=start)
        return make

    if arch_id == "nequip":
        def make(start):
            def gen():
                step = start
                while True:
                    yield dp.molecule_energy_batch(
                        seed, step, num_graphs=8, nodes_per=8,
                        edges_per=12, n_species=cfg.n_species)
                    step += 1
            return dp.Prefetcher(gen())
        return make

    def make(start):
        def gen():
            step = start
            while True:
                b = dp.graph_node_batch(seed, step, num_nodes=64,
                                        num_edges=128, d_feat=cfg.d_in,
                                        n_classes=cfg.n_classes)
                if arch_id == "gatedgcn":
                    rng = np.random.default_rng((seed, step, 1))
                    b["edge_attr"] = rng.standard_normal(
                        (b["src"].shape[0], cfg.d_edge_in)
                    ).astype(np.float32)
                if arch_id == "gin-tu" and cfg.graph_level:
                    b["graph_ids"] = (np.arange(64) %
                                      cfg.num_graphs).astype(np.int32)
                    rng = np.random.default_rng((seed, step, 2))
                    b["y"] = rng.integers(
                        0, cfg.n_classes, cfg.num_graphs).astype(np.int32)
                yield b
                step += 1
        return dp.Prefetcher(gen())
    return make


def _model_api(arch_id: str):
    mod = get_arch(arch_id)
    if mod.FAMILY == "lm":
        from repro.models import transformer as M
        return M
    if mod.FAMILY == "recsys":
        from repro.models import recsys as M
        return M
    if arch_id == "nequip":
        from repro.models.gnn import nequip as M
    elif arch_id == "gatedgcn":
        from repro.models.gnn import gatedgcn as M
    elif arch_id == "graphsage-reddit":
        from repro.models.gnn import graphsage as M
    else:
        from repro.models.gnn import gin as M
    return M


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a SimulatedFailure at this step (tests "
                         "the restart path)")
    args = ap.parse_args(argv)

    mod = get_arch(args.arch)
    M = _model_api(args.arch)
    cfg = mod.make_smoke_config()
    opt = adamw(AdamWConfig(
        lr=cosine_schedule(args.lr, warmup=10, total=args.steps)))

    def loss(params, batch):
        return M.loss_fn(params, {k: jnp.asarray(v)
                                  for k, v in batch.items()}, cfg)

    raw_step = jax.jit(train_state.make_train_step(loss, opt),
                       donate_argnums=(0,))
    failed = {"done": False}

    def step_fn(state, batch):
        s = int(state["step"])
        if args.fail_at and s == args.fail_at and not failed["done"]:
            failed["done"] = True
            raise SimulatedFailure(f"injected failure at step {s}")
        return raw_step(state, batch)

    def init_state():
        params = M.init(jax.random.PRNGKey(args.seed), cfg)
        return train_state.create(params, opt)

    ckpt_dir = args.ckpt or os.path.join("/tmp", f"ck_{args.arch}")
    losses = []
    report = run_with_restarts(
        init_state_fn=init_state,
        step_fn=step_fn,
        stream_fn=_smoke_stream(args.arch, cfg, args.seed, args.batch),
        total_steps=args.steps,
        ckpt_dir=ckpt_dir,
        ckpt_every=args.ckpt_every,
        watchdog=StepWatchdog(),
        on_metrics=lambda s, m: losses.append(
            (s, float(np.asarray(m["loss"])))),
    )
    first = np.mean([v for _, v in losses[:10]])
    last = np.mean([v for _, v in losses[-10:]])
    print(f"[train] {args.arch}: {report.steps_run} steps, "
          f"{report.restarts} restarts, loss {first:.4f} -> {last:.4f}, "
          f"slow steps flagged: {len(report.slow_steps)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
