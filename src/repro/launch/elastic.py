"""Elastic scaling: restore any checkpoint onto any mesh.

Checkpoints are mesh-agnostic (full arrays + manifest; train/checkpoint
.py), so rescaling = ``restore(..., sharding_tree=shardings_for(new
mesh))``. This module provides the launcher-side pieces:

  * ``reshard_plan`` — given a TrainState structure and a target mesh,
    build the NamedSharding tree (params/moments share the model's
    param_spec; step replicated);
  * ``rescale`` — restore a checkpoint under a new mesh/pod count;
  * ``ElasticController`` — decides when to rescale: consumes the step
    watchdog's slow-step events and a healthy-host count (in a real
    deployment, fed by the cluster manager; here injected by tests) and
    emits the new data-parallel width.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.train import checkpoint as ckpt_lib


def reshard_plan(state_struct, mesh: Mesh, param_spec_tree):
    def named(spec):
        return NamedSharding(mesh, spec)

    opt_spec = {k: jax.tree.map(named, param_spec_tree)
                for k in state_struct["opt"]}
    return {
        "params": jax.tree.map(named, param_spec_tree),
        "opt": opt_spec,
        "step": named(P()),
    }


def rescale(ckpt_dir: str, state_struct, mesh: Mesh, param_spec_tree,
            step: Optional[int] = None):
    """Restore the newest (or given) checkpoint onto ``mesh`` — the
    elastic-rescale path: a checkpoint taken on 512 chips restores onto
    256 (or 1 CPU device) unchanged."""
    plan = reshard_plan(state_struct, mesh, param_spec_tree)
    return ckpt_lib.restore(ckpt_dir, like=state_struct, step=step,
                            sharding_tree=plan)


@dataclasses.dataclass
class ElasticController:
    """Policy: drop to the largest power-of-two healthy data-parallel
    width; rescale up again when hosts return. Hysteresis via
    ``min_steps_between`` so transient stragglers don't thrash."""

    dp_width: int
    min_steps_between: int = 100
    _last_change: int = -10**9

    def decide(self, step: int, healthy_hosts: int,
               slow_streak: int = 0) -> Optional[int]:
        """Returns a new dp width, or None to keep the current one."""
        if step - self._last_change < self.min_steps_between:
            return None
        target = 1
        while target * 2 <= healthy_hosts:
            target *= 2
        if slow_streak >= 3 and target >= 2:
            target //= 2          # a persistent straggler: shed a host
        if target != self.dp_width and target >= 1:
            self._last_change = step
            self.dp_width = target
            return target
        return None
