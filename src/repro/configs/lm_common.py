"""Shared LM shape set + input-spec builders.

LM transformer shapes are seq_len × global_batch. ``decode_*`` /
``long_*`` lower ``serve_step`` (one new token against a KV cache of
seq_len), not ``train_step``; ``prefill_*`` lowers the prompt pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_DEFS = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def step_kind(shape: str) -> str:
    return SHAPE_DEFS[shape]["kind"]


def lm_skip_reason(shape: str, cfg: T.LMConfig) -> str | None:
    if shape == "long_500k" and cfg.window == 0:
        return ("pure full-attention arch: 524k decode needs "
                "sub-quadratic attention state (see DESIGN.md "
                "§Arch-applicability)")
    return None


def cache_struct(cfg: T.LMConfig, batch: int, buf: int):
    """ShapeDtypeStruct pytree of the decode cache (no allocation)."""
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, buf))


def input_specs(shape: str, cfg: T.LMConfig) -> dict:
    d = SHAPE_DEFS[shape]
    s, b = d["seq"], d["batch"]
    i32 = jnp.int32
    if d["kind"] == "train":
        return {"batch": {"tokens": jax.ShapeDtypeStruct((b, s + 1), i32)}}
    if d["kind"] == "prefill":
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "cache": cache_struct(cfg, b, s),
        }
    # decode: one token against a cache of `seq` positions
    return {
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "positions": jax.ShapeDtypeStruct((b,), i32),
        "cache": cache_struct(cfg, b, s),
    }
