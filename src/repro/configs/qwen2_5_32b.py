"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA with QKV bias. [hf:Qwen/Qwen2.5-*; hf]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as LC
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2.5-32b"
FAMILY = "lm"
SHAPES = LC.SHAPES
ACCUM_STEPS = 16    # 1 seq/chip/microbatch: 40-head flash tiles are 2×
                    # gemma's — 4-way accum leaves 32 GiB/chip, 8-way
                    # 17.3 GiB (measured); 16-way fits the 16 GB budget


def make_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=27648, vocab=152064, qkv_bias=True,
        rope_theta=1_000_000.0, dtype=jnp.bfloat16, remat=True,
        seq_parallel=False)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab=128, qkv_bias=True,
        dtype=jnp.float32, remat=False)


def step_kind(shape: str) -> str:
    return LC.step_kind(shape)


def skip_reason(shape: str):
    return LC.lm_skip_reason(shape, make_config())


def input_specs(shape: str) -> dict:
    return LC.input_specs(shape, make_config())
