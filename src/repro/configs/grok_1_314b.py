"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]

8 experts < 16-way model axis ⇒ tensor parallelism INSIDE each expert
(d_ff sharded over 'model') instead of expert parallelism — see
transformer.param_spec. Optimizer moments are kept in bf16 so state fits
the 16 GB/chip budget (DESIGN.md §5)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as LC
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "grok-1-314b"
FAMILY = "lm"
SHAPES = LC.SHAPES

MOMENT_DTYPE = jnp.bfloat16     # consumed by launch/train.py
ACCUM_STEPS = 16    # 1 seq/chip/microbatch: the 64-layer scan saves
                    # [L, B_local, S, 6144] residuals per microbatch —
                    # 4-way accum leaves 55 GiB/chip (measured)


def make_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        head_dim=128, d_ff=32768, vocab=131072,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32768,
                      capacity_factor=1.25),
        dtype=jnp.bfloat16, remat=True)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab=128,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96),
        dtype=jnp.float32, remat=False)


def step_kind(shape: str) -> str:
    return LC.step_kind(shape)


def skip_reason(shape: str):
    return LC.lm_skip_reason(shape, make_config())


def input_specs(shape: str) -> dict:
    return LC.input_specs(shape, make_config())
