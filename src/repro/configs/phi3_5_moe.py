"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8)
d_ff_expert=6400 vocab=32064, MoE 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]

16 experts == the 16-way model axis ⇒ expert parallelism (1 expert/rank,
all-to-all dispatch) — see transformer.param_spec."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as LC
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"
FAMILY = "lm"
SHAPES = LC.SHAPES


def make_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=6400, vocab=32064,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400,
                      capacity_factor=1.25),
        dtype=jnp.bfloat16, remat=True)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab=128,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96),
        dtype=jnp.float32, remat=False)


def step_kind(shape: str) -> str:
    return LC.step_kind(shape)


def skip_reason(shape: str):
    return LC.lm_skip_reason(shape, make_config())


def input_specs(shape: str) -> dict:
    return LC.input_specs(shape, make_config())
