"""gatedgcn [gnn]: 16 layers, d_hidden=70, gated aggregator.
[arXiv:2003.00982; paper]"""
from __future__ import annotations

from repro.configs import gnn_common as GC
from repro.models.gnn.gatedgcn import GatedGCNConfig

ARCH_ID = "gatedgcn"
FAMILY = "gnn"
SHAPES = GC.SHAPES

D_EDGE = 8      # edge-feature width (benchmarking-gnns convention)


def make_config(shape: str = "full_graph_sm") -> GatedGCNConfig:
    d = GC.SHAPE_DEFS[shape]
    return GatedGCNConfig(name=ARCH_ID, n_layers=16,
                          d_in=d["d_feat"], d_edge_in=D_EDGE,
                          d_hidden=70, n_classes=d["n_classes"])


def make_smoke_config() -> GatedGCNConfig:
    return GatedGCNConfig(name=ARCH_ID + "-smoke", n_layers=3, d_in=16,
                          d_edge_in=8, d_hidden=32, n_classes=4)


def step_kind(shape: str) -> str:
    return GC.step_kind(shape)


def skip_reason(shape: str):
    return None


def input_specs(shape: str) -> dict:
    return GC.feature_gnn_specs(shape, layered=False, d_edge=D_EDGE)
