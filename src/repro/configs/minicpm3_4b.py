"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention, q_lora=768, kv_lora=256, decoupled RoPE).
[hf:openbmb/MiniCPM3-4B; hf]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as LC
from repro.models.transformer import LMConfig, MLAConfig

ARCH_ID = "minicpm3-4b"
FAMILY = "lm"
SHAPES = LC.SHAPES


def make_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
        head_dim=64, d_ff=6400, vocab=73448, attention="mla",
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                      qk_rope_dim=32, v_head_dim=64),
        dtype=jnp.bfloat16, remat=True)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=160, vocab=128, attention="mla",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        dtype=jnp.float32, remat=False)


def step_kind(shape: str) -> str:
    return LC.step_kind(shape)


def skip_reason(shape: str):
    return LC.lm_skip_reason(shape, make_config())


def input_specs(shape: str) -> dict:
    return LC.input_specs(shape, make_config())
