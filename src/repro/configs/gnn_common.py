"""Shared GNN shape set + input-spec builders.

Four shapes (assignment):
  full_graph_sm   V=2,708   E=10,556      d_feat=1,433  (cora-scale)
  minibatch_lg    V=232,965 E=114,615,892 seeds=1,024 fanout 15-10
                  d_feat=602 (reddit-scale; REAL neighbor sampler feeds
                  static-shape blocks — see graphs/sampler.py)
  ogb_products    V=2,449,029 E=61,859,140 d_feat=100
  molecule        128 graphs × 30 nodes × 64 edges (block-diagonal)

Edge lists are symmetrized (both directions) for message passing; the
static edge count below is therefore 2E. For ``minibatch_lg``:
GraphSAGE consumes layered blocks (one block per layer, DGL-style);
deeper archs (GIN/GatedGCN/NequIP) consume the sampled subgraph's edge
union per layer (GraphSAINT-style subgraph sampling — documented
adaptation, DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")

def _pad32(n: int) -> int:
    """Round up to a multiple of 512 so node/edge dims shard evenly over
    the FULL 2×16×16 mesh (GNN cells use the otherwise-idle 'model' axis
    for extra edge parallelism); padding rows are masked / (0,0)
    self-loop edges (message no-ops)."""
    return ((n + 511) // 512) * 512


# static shapes per cell (logical sizes in comments; padded for sharding)
SHAPE_DEFS = {
    "full_graph_sm": dict(kind="train", v=_pad32(2708),
                          e_sym=_pad32(2 * 10556),
                          d_feat=1433, n_classes=7, graphs=1),
    "minibatch_lg": dict(kind="train", seeds=1024, fanouts=(15, 10),
                         d_feat=602, n_classes=41,
                         # frontier sizes (padded, dedup-free static):
                         n1=1024 * 11, n0=1024 * 11 * 16,
                         e0=1024 * 11 * 15, e1=1024 * 10, graphs=1),
    "ogb_products": dict(kind="train", v=_pad32(2449029),
                         e_sym=_pad32(2 * 61859140),
                         d_feat=100, n_classes=47, graphs=1),
    "molecule": dict(kind="train", graphs=128, nodes_per=30,
                     edges_per=64, d_feat=16, n_classes=2,
                     v=128 * 30, e_sym=2 * 128 * 64),
}


def step_kind(shape: str) -> str:
    return "train"


def feature_gnn_specs(shape: str, layered: bool = False,
                      n_layers: int = 2, d_edge: int = 0,
                      graph_level: bool = False) -> dict:
    """Input specs for feature-based GNNs (SAGE / GIN / GatedGCN)."""
    d = SHAPE_DEFS[shape]
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    if shape == "minibatch_lg":
        if layered:
            b = {
                "x": S((d["n0"], d["d_feat"]), f32),
                "src_0": S((d["e0"],), i32), "dst_0": S((d["e0"],), i32),
                "src_1": S((d["e1"],), i32), "dst_1": S((d["e1"],), i32),
                "y": S((d["n0"],), i32),
                "node_mask": S((d["n0"],), f32),
            }
        else:
            e_union = d["e0"] + d["e1"]
            b = {
                "x": S((d["n0"], d["d_feat"]), f32),
                "src": S((e_union,), i32), "dst": S((e_union,), i32),
                "y": S((d["n0"],), i32),
                "node_mask": S((d["n0"],), f32),
            }
            if d_edge:
                b["edge_attr"] = S((e_union, d_edge), f32)
        return {"batch": b}
    v, e = d["v"], d["e_sym"]
    y_len = d["graphs"] if (shape == "molecule" and graph_level) else v
    b = {
        "x": S((v, d["d_feat"]), f32),
        "src": S((e,), i32), "dst": S((e,), i32),
        "y": S((y_len,), i32),
        "node_mask": S((v,), f32),
    }
    if d_edge:
        b["edge_attr"] = S((e, d_edge), f32)
    if shape == "molecule" and graph_level:
        b["graph_ids"] = S((v,), i32)
    return {"batch": b}


def nequip_specs(shape: str) -> dict:
    """NequIP consumes geometry (positions/species); non-molecular graphs
    are treated as point clouds with synthetic coordinates (the compute
    pattern — gather, tensor product, segment-sum — is identical)."""
    d = SHAPE_DEFS[shape]
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    if shape == "minibatch_lg":
        v, e = d["n0"], d["e0"] + d["e1"]
    else:
        v, e = d["v"], d["e_sym"]
    g = d["graphs"]
    return {"batch": {
        "positions": S((v, 3), f32),
        "species": S((v,), i32),
        "src": S((e,), i32), "dst": S((e,), i32),
        "graph_ids": S((v,), i32),
        "energy": S((g,), f32),
    }}
