"""Table I graph specs (the paper's own evaluation set) for the CC
dry-run + scaled stand-ins for CPU benchmarking.

The four full-size graphs are lowered as ShapeDtypeStruct edge lists
through the distributed-CC program (launch/dryrun.py lowers them on the
production mesh alongside the assigned architectures)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graphs.generators import TABLE1_FULL, table1_scaled  # noqa: F401

ARCH_ID = "cc-adaptive"
FAMILY = "cc"
SHAPES = tuple(TABLE1_FULL)      # usa-osm, euro-osm-karls, soc-lj, kron


def step_kind(shape: str) -> str:
    return "cc"


def skip_reason(shape: str):
    return None


def input_specs(shape: str) -> dict:
    nodes, edges, _, _ = TABLE1_FULL[shape]
    return {
        "edges": jax.ShapeDtypeStruct((edges, 2), jnp.int32),
        "num_nodes": nodes,
    }
