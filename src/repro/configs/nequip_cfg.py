"""nequip [gnn]: 5 layers, d_hidden=32 channels, l_max=2, n_rbf=8,
cutoff=5, E(3)-tensor-product interactions. [arXiv:2101.03164; paper]

Non-molecular shapes are treated as point clouds (synthetic coordinates)
— the irrep tensor-product compute pattern is shape-identical; see
configs/gnn_common.nequip_specs."""
from __future__ import annotations

from repro.configs import gnn_common as GC
from repro.models.gnn.nequip import NequIPConfig

ARCH_ID = "nequip"
FAMILY = "gnn"
SHAPES = GC.SHAPES


def make_config(shape: str = "molecule") -> NequIPConfig:
    return NequIPConfig(name=ARCH_ID, n_layers=5, d_hidden=32, l_max=2,
                        n_rbf=8, cutoff=5.0, n_species=32)


def make_smoke_config() -> NequIPConfig:
    return NequIPConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=8,
                        l_max=2, n_rbf=4, cutoff=5.0, n_species=4)


def step_kind(shape: str) -> str:
    return GC.step_kind(shape)


def skip_reason(shape: str):
    return None


def input_specs(shape: str) -> dict:
    return GC.nequip_specs(shape)
