"""gin-tu [gnn]: 5 layers, d_hidden=64, sum aggregator, learnable eps.
[arXiv:1810.00826; paper]

Graph-level readout on ``molecule``; node-level on the other shapes.
``minibatch_lg`` uses the sampled-subgraph edge union (5 layers > 2
sampled block levels — GraphSAINT-style; DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from repro.configs import gnn_common as GC
from repro.models.gnn.gin import GINConfig

ARCH_ID = "gin-tu"
FAMILY = "gnn"
SHAPES = GC.SHAPES


def make_config(shape: str = "molecule") -> GINConfig:
    d = GC.SHAPE_DEFS[shape]
    return GINConfig(name=ARCH_ID, n_layers=5,
                     d_in=d["d_feat"], d_hidden=64,
                     n_classes=d["n_classes"],
                     graph_level=(shape == "molecule"),
                     num_graphs=d["graphs"])


def make_smoke_config() -> GINConfig:
    return GINConfig(name=ARCH_ID + "-smoke", n_layers=2, d_in=16,
                     d_hidden=32, n_classes=2, graph_level=True,
                     num_graphs=8)


def step_kind(shape: str) -> str:
    return GC.step_kind(shape)


def skip_reason(shape: str):
    return None


def input_specs(shape: str) -> dict:
    return GC.feature_gnn_specs(shape, layered=False,
                                graph_level=(shape == "molecule"))
