"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — alternating local(4096-window)+global layers, logit
softcaps, post-norms, embedding scaling. [arXiv:2408.00118; hf]

``long_500k`` RUNS for this arch: the local half of the stack holds a
bounded 4,096-slot ring cache (sub-quadratic state), global layers are
linear-per-token at decode.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import lm_common as LC
from repro.models.transformer import LMConfig

ARCH_ID = "gemma2-2b"
FAMILY = "lm"
SHAPES = LC.SHAPES


def make_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        head_dim=256, d_ff=9216, vocab=256000, window=4096,
        layer_pattern="local_global", attn_softcap=50.0,
        final_softcap=30.0, post_norm=True, embed_scale=True,
        tie_embed=True, act="gelu", dtype=jnp.bfloat16, remat=True)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab=128, window=8,
        layer_pattern="local_global", attn_softcap=50.0,
        final_softcap=30.0, post_norm=True, embed_scale=True,
        act="gelu", dtype=jnp.float32, remat=False)


def step_kind(shape: str) -> str:
    return LC.step_kind(shape)


def skip_reason(shape: str):
    return None     # local/global: all four shapes run


def input_specs(shape: str) -> dict:
    return LC.input_specs(shape, make_config())
