"""Architecture registry: one module per assigned architecture.

Every module exposes the uniform interface the launcher consumes:

  ARCH_ID        str
  FAMILY         "lm" | "gnn" | "recsys"
  SHAPES         tuple of shape names (the assigned input-shape set)
  make_config()             full-size model config (dry-run only)
  make_smoke_config()       reduced same-family config (CPU tests)
  input_specs(shape)        dict of jax.ShapeDtypeStruct for the step fn
  step_kind(shape)          "train" | "prefill" | "decode" | "serve"
                            | "retrieval"
  skip_reason(shape)        None, or why the cell is skipped (e.g.
                            long_500k on pure full-attention archs)
"""
from __future__ import annotations

import importlib

_MODULES = {
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "nequip": "repro.configs.nequip_cfg",
    "gatedgcn": "repro.configs.gatedgcn_cfg",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "gin-tu": "repro.configs.gin_tu",
    "dcn-v2": "repro.configs.dcn_v2",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list(_MODULES)}")
    return importlib.import_module(_MODULES[name])


def all_cells():
    """Every (arch, shape) pair, with skip reasons resolved."""
    cells = []
    for arch_id in ARCH_IDS:
        mod = get_arch(arch_id)
        for shape in mod.SHAPES:
            cells.append((arch_id, shape, mod.skip_reason(shape)))
    return cells
