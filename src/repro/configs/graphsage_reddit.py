"""graphsage-reddit [gnn]: 2 layers, d_hidden=128, mean aggregator,
sample_sizes=25-10. [arXiv:1706.02216; paper]

``minibatch_lg`` consumes layered sampled blocks (the real neighbor
sampler in graphs/sampler.py); full-graph shapes use dense edge lists.
CC applicability: the sampler's CSR build + component filtering use
``repro.core.cc`` (DESIGN.md §4)."""
from __future__ import annotations

from repro.configs import gnn_common as GC
from repro.models.gnn.graphsage import SAGEConfig

ARCH_ID = "graphsage-reddit"
FAMILY = "gnn"
SHAPES = GC.SHAPES


def make_config(shape: str = "minibatch_lg") -> SAGEConfig:
    d = GC.SHAPE_DEFS[shape]
    return SAGEConfig(name=ARCH_ID, n_layers=2, d_in=d["d_feat"],
                      d_hidden=128, n_classes=d["n_classes"])


def make_smoke_config() -> SAGEConfig:
    return SAGEConfig(name=ARCH_ID + "-smoke", n_layers=2, d_in=16,
                      d_hidden=32, n_classes=5)


def step_kind(shape: str) -> str:
    return GC.step_kind(shape)


def skip_reason(shape: str):
    return None


def input_specs(shape: str) -> dict:
    return GC.feature_gnn_specs(shape, layered=(shape == "minibatch_lg"),
                                n_layers=2)
