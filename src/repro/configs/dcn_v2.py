"""dcn-v2 [recsys]: 13 dense, 26 sparse, embed_dim=16, 3 cross layers,
MLP 1024-1024-512, cross interaction. [arXiv:2008.13535; paper]

Shapes: train_batch B=65,536 (train) · serve_p99 B=512 (online) ·
serve_bulk B=262,144 (offline scoring) · retrieval_cand 1×1,000,000
(single query against 1M candidates — one batched matmul)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.recsys import RecsysConfig, CRITEO_TABLE_SIZES

ARCH_ID = "dcn-v2"
FAMILY = "recsys"
SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")

SHAPE_DEFS = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           candidates=1_000_000),
}


def make_config() -> RecsysConfig:
    import jax.numpy as jnp
    return RecsysConfig(name=ARCH_ID, n_dense=13, n_sparse=26,
                        embed_dim=16, n_cross=3, mlp=(1024, 1024, 512),
                        table_sizes=CRITEO_TABLE_SIZES,
                        dtype=jnp.bfloat16)


def make_smoke_config() -> RecsysConfig:
    return RecsysConfig(name=ARCH_ID + "-smoke", n_dense=5, n_sparse=4,
                        embed_dim=8, n_cross=2, mlp=(64, 32, 16),
                        table_sizes=(100, 50, 80, 30))


def step_kind(shape: str) -> str:
    return SHAPE_DEFS[shape]["kind"]


def skip_reason(shape: str):
    return None


def input_specs(shape: str) -> dict:
    cfg = make_config()
    d = SHAPE_DEFS[shape]
    b = d["batch"]
    S = jax.ShapeDtypeStruct
    batch = {
        "dense": S((b, cfg.n_dense), jnp.float32),
        "sparse_idx": S((b, cfg.n_sparse), jnp.int32),
        "label": S((b,), jnp.int32),
    }
    if d["kind"] == "retrieval":
        return {"batch": batch,
                "candidate_ids": S((d["candidates"],), jnp.int32)}
    return {"batch": batch}
