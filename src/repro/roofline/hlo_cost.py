"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts every while-loop body ONCE (measured
in roofline/analysis tests) — a 64-layer scanned transformer or a
gradient-accumulation loop is under-counted by its trip count. This
module re-derives the three roofline inputs by walking the post-SPMD HLO
text with loop multipliers:

  * flops            — 2·prod(output)·prod(contracting) per dot, scaled
                       by the product of enclosing loop trip counts;
  * hbm bytes        — operand+output bytes at fusion boundaries
                       (fusion internals excluded: a fusion is one
                       HBM-round-trip on TPU), scaled likewise;
  * collective bytes — ring-model wire bytes per collective × trips.

Trip counts are read from each while-loop's condition computation (the
constant bound of the counter compare — exact for ``lax.scan``/
``fori_loop``). Data-dependent ``while_loop``s report their static fuel
bound; CC benchmark tables pair this upper bound with measured sweep
counts from the work counters.

This is a *structural* model: dots dominate FLOPs in every assigned
arch (GNN/CC cells are gather/scatter-bound, where FLOPs ≈ 0 is the
right answer), and fusion boundaries approximate HBM materialization
points. Validated against analytic 6·N·D for the LM cells (§Roofline).
"""
from __future__ import annotations

import dataclasses
import math
import re

from repro.roofline.analysis import (_DTYPE_BYTES, _SHAPE_RE,
                                     _group_size, _COLLECTIVES)

# instruction: "%name = type opcode(...)" or "ROOT %name = ..."
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota"}


def _shape_list(text: str) -> list[tuple[str, str]]:
    return _SHAPE_RE.findall(text)


def _bytes_of(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    rest: str          # everything after the opening paren
    line: str


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_computations(hlo: str
                       ) -> tuple[dict[str, list[Instr]], dict[str, str]]:
    """Returns (computations, symbol table name -> output type). Post-opt
    HLO omits inline operand types, so operand shapes are resolved
    through the definitions."""
    comps: dict[str, list[Instr]] = {}
    defs: dict[str, str] = {}
    current: list[Instr] | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                current = []
                comps[m.group(1)] = current
            continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3),
                        m.group(4), line.strip())
            current.append(ins)
            defs[ins.name] = ins.out_type
    return comps, defs


def _operand_types(ins: Instr, defs: dict[str, str]) -> list[str]:
    """Output types of the instruction's direct operands (resolved
    through the symbol table; call-argument list only)."""
    arglist = ins.rest.split(")", 1)[0]
    return [defs[n] for n in _OPERAND_RE.findall(arglist) if n in defs]


def _operand_bytes(ins: Instr, defs: dict[str, str]) -> int:
    total = 0
    for t in _operand_types(ins, defs):
        total += sum(_bytes_of(d, s) for d, s in _shape_list(t))
    # fall back to inline shapes (older dumps annotate operands)
    if total == 0:
        arglist = ins.rest.split(")", 1)[0]
        total = sum(_bytes_of(d, s) for d, s in _shape_list(arglist))
    return total


def _entry_name(hlo: str, comps: dict) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps))


def _dot_flops(instr: Instr, defs: dict[str, str]) -> float:
    out_elems = 1
    shapes = _shape_list(instr.out_type)
    if shapes:
        dt, dims = shapes[0]
        if dims:
            out_elems = math.prod(int(d) for d in dims.split(","))
    m = _CONTRACT_RE.search(instr.line)
    contract = 1
    op_types = _operand_types(instr, defs)
    operand_shapes = (_shape_list(op_types[0]) if op_types
                      else _shape_list(instr.rest))
    if m and operand_shapes:
        lhs_dims = operand_shapes[0][1]
        if lhs_dims:
            ld = [int(d) for d in lhs_dims.split(",")]
            for ci in m.group(1).split(","):
                if ci != "":
                    contract *= ld[int(ci)]
    return 2.0 * out_elems * contract


def _trip_count(cond_comp: list[Instr],
                comps: dict[str, list[Instr]] | None = None) -> int:
    """Largest integer constant in the loop condition — exact for
    counted loops (scan/fori), the static fuel bound otherwise. The
    compare is often inside a fusion called FROM the condition, so
    fusion callees are scanned too."""
    best = 1
    for ins in cond_comp:
        for m in _CONST_INT_RE.finditer(ins.line):
            best = max(best, int(m.group(1)))
        if comps is not None and ins.opcode == "fusion":
            cm = _CALLS_RE.search(ins.line)
            if cm:
                for sub in comps.get(cm.group(1), []):
                    for m in _CONST_INT_RE.finditer(sub.line):
                        best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    loops: list = dataclasses.field(default_factory=list)


def analyze_hlo(hlo: str) -> HloCost:
    comps, defs = parse_computations(hlo)
    entry = _entry_name(hlo, comps)
    cost = HloCost()

    def out_bytes(ins: Instr) -> int:
        return sum(_bytes_of(d, s) for d, s in _shape_list(ins.out_type))

    def visit(name: str, mult: float, depth: int = 0):
        if depth > 32 or name not in comps:
            return
        for ins in comps[name]:
            op = ins.opcode
            if op == "while":
                bm = _BODY_RE.search(ins.line)
                cm = _COND_RE.search(ins.line)
                trips = _trip_count(comps.get(cm.group(1), []), comps) \
                    if cm else 1
                if bm:
                    cost.loops.append((bm.group(1), trips))
                    visit(bm.group(1), mult * trips, depth + 1)
                continue
            if op == "conditional":
                m = _BRANCHES_RE.search(ins.line)
                if m:
                    for br in m.group(1).split(","):
                        visit(br.strip().lstrip("%"), mult, depth + 1)
                continue
            if op == "call":
                m = _CALLS_RE.search(ins.line) or re.search(
                    r"to_apply=%?([\w.\-]+)", ins.line)
                if m:
                    visit(m.group(1), mult, depth + 1)
                continue
            if op == "fusion":
                m = _CALLS_RE.search(ins.line)
                if m:
                    # dots inside the fusion still count as flops
                    for sub in comps.get(m.group(1), []):
                        if sub.opcode == "dot":
                            cost.flops += mult * _dot_flops(sub, defs)
                # fusion boundary = HBM traffic: operands + output
                cost.hbm_bytes += mult * (_operand_bytes(ins, defs)
                                          + out_bytes(ins))
                continue
            if op == "dot":
                cost.flops += mult * _dot_flops(ins, defs)
                cost.hbm_bytes += mult * (_operand_bytes(ins, defs)
                                          + out_bytes(ins))
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                operand_bytes = _operand_bytes(ins, defs)
                g = _group_size(ins.line)
                ring = (g - 1) / max(g, 1)
                if base == "all-reduce":
                    wire = 2.0 * operand_bytes * ring
                elif base == "collective-permute":
                    wire = float(operand_bytes)
                else:
                    wire = operand_bytes * ring
                cost.wire_bytes += mult * wire
                cost.hbm_bytes += mult * 2 * operand_bytes
                continue
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            # other materializing ops (copy, scatter, gather, reduce,
            # dynamic-update-slice, convert, ...): operands + output
            cost.hbm_bytes += mult * (_operand_bytes(ins, defs)
                                      + out_bytes(ins))

    visit(entry, 1.0)
    return cost
