"""Three-term roofline model from a compiled (dry-run) artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = wire_bytes / (chips × link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis`` conventions (global vs per-partition flops) drift
across jax versions; ``calibrate_cost_convention`` measures the installed
one with a 4-way-sharded matmul probe and the report normalizes to
PER-CHIP terms.

Collective bytes are NOT in cost_analysis: ``collective_stats`` parses
the post-SPMD HLO (``compiled.as_text()``, per-partition shapes) and
converts operand bytes to wire bytes per chip with ring-algorithm
factors: all-reduce 2×N(g-1)/g, all-gather/reduce-scatter N(g-1)/g,
all-to-all N(g-1)/g, collective-permute N.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import re

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per chip (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

# f32[128,256]{1,0} — dtype + dims (possibly empty for scalars)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:                      # iota format [n_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len(first.split(","))
    return 2                   # conservative default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0          # per-chip bytes on the wire
    operand_bytes: float = 0.0       # raw operand sum (reference)
    by_op: dict = dataclasses.field(default_factory=dict)

    def add(self, op, wire, operand):
        self.wire_bytes += wire
        self.operand_bytes += operand
        d = self.by_op.setdefault(op, {"count": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["wire_bytes"] += wire


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Parse post-SPMD HLO; sum per-chip wire bytes of every collective.
    Operand shapes are resolved through the module's symbol table (the
    post-opt dump omits inline operand types). NOTE: counts each
    instruction ONCE — ``analysis.analyze`` overrides the total with the
    trip-count-aware walk; this function feeds the per-op breakdown."""
    from repro.roofline.hlo_cost import (_operand_bytes,
                                         parse_computations)
    comps, defs = parse_computations(hlo_text)
    stats = CollectiveStats()
    for body in comps.values():
        for ins in body:
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") \
                else ins.opcode
            if base not in _COLLECTIVES:
                continue
            operand_bytes = _operand_bytes(ins, defs)
            g = _group_size(ins.line)
            ring = (g - 1) / max(g, 1)
            if base == "all-reduce":
                wire = 2.0 * operand_bytes * ring
            elif base == "collective-permute":
                wire = float(operand_bytes)
            else:               # all-gather / reduce-scatter / a2a
                wire = operand_bytes * ring
            stats.add(base, wire, operand_bytes)
    return stats


@functools.lru_cache(maxsize=1)
def calibrate_cost_convention() -> str:
    """Is cost_analysis()['flops'] global or per-partition? Probe a
    4-way-sharded matmul and compare against the analytic count."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    if len(jax.devices()) < 4:
        return "global"         # single device: conventions coincide
    mesh = jax.sharding.Mesh(jax.devices()[:4], ("x",))
    n = 256
    sh = NamedSharding(mesh, P("x", None))

    @jax.jit
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((n, n), jnp.float32, sharding=sh)
    b = jax.ShapeDtypeStruct((n, n), jnp.float32, sharding=sh)
    cost = f.lower(a, b).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    analytic_global = 2.0 * n * n * n
    # per-partition would be ~1/4 of global
    return ("global" if abs(flops - analytic_global)
            < abs(flops - analytic_global / 4) else "per_partition")


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    chips: int
    hlo_gflops_per_chip: float
    hlo_gbytes_per_chip: float
    wire_gbytes_per_chip: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    collectives: dict
    model_gflops: float = 0.0     # 6·N·D (analytic, global)

    @property
    def step_time(self) -> float:
        """Optimistic (max-of-terms) step-time bound."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def useful_flop_ratio(self) -> float:
        total = self.hlo_gflops_per_chip * self.chips
        return self.model_gflops / total if total else 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self) | {
            "bottleneck": self.bottleneck,
            "step_time_bound_s": self.step_time,
            "useful_flop_ratio": round(self.useful_flop_ratio(), 4),
        }


def analyze(compiled, *, arch: str, shape: str, chips: int,
            model_flops: float = 0.0, hlo_text: str | None = None
            ) -> Roofline:
    """Roofline terms from the compiled artifact.

    FLOPs / HBM bytes / collective wire bytes come from the trip-count-
    aware HLO walk (roofline/hlo_cost.py) — ``cost_analysis()`` counts
    while-loop bodies once, under-counting every scanned model by its
    trip count (measured; see hlo_cost docstring). The raw
    ``cost_analysis`` numbers are per-partition on this backend
    (calibrated) and are kept only as a cross-check.
    """
    from repro.roofline.hlo_cost import analyze_hlo
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = analyze_hlo(text)       # per-partition HLO => per-chip costs
    flops_chip = hc.flops
    bytes_chip = hc.hbm_bytes
    coll = collective_stats(text)
    coll.wire_bytes = hc.wire_bytes   # trip-count-aware total
    t_comp = flops_chip / PEAK_FLOPS
    t_mem = bytes_chip / HBM_BW
    t_coll = hc.wire_bytes / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        arch=arch, shape=shape, chips=chips,
        hlo_gflops_per_chip=flops_chip / 1e9,
        hlo_gbytes_per_chip=bytes_chip / 1e9,
        wire_gbytes_per_chip=coll.wire_bytes / 1e9,
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck, collectives=coll.by_op,
        model_gflops=model_flops / 1e9,
    )


def memory_summary(compiled) -> dict:
    """Per-chip bytes from compiled.memory_analysis()."""
    try:
        m = compiled.memory_analysis()
    except Exception:            # noqa: BLE001
        return {}
    if m is None:
        return {}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_gib"] = round(
        (out.get("argument_size_in_bytes", 0)
         + out.get("output_size_in_bytes", 0)
         + out.get("temp_size_in_bytes", 0)
         - out.get("alias_size_in_bytes", 0)) / 2**30, 3)
    return out
