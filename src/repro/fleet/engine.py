"""Pipelined tick engine: dispatch everything, sync once, one tick
late (DESIGN.md §15).

The single-device ``ConnectivityService`` tick is synchronous per
query group: every (tenant, kind) microbatch pays a registry cache
check (a host version sync), a kernel dispatch, and a device->host
materialization before the NEXT group even dispatches — with 32
tenants that is ~32+ round-trip stalls per tick, and every stall
idles every device in a mesh. This engine restructures the tick into
three phases that never interleave a sync between dispatches:

  1. **mutation phase** — each shard's coalesced insert/delete calls
     (``ConnectivityService._run_mutations``, reused verbatim: the
     per-device shell IS the service) dispatch asynchronously; results
     ride as device version scalars, nothing syncs;
  2. **query phase** — queries batch ACROSS tenants per shard: every
     same-|V| tenant group on a device answers ALL pairs in one
     vmapped kernel (``_batched_query_jit``) over a cached stacked
     label plane (``_label_plane`` — rebuilt only when a member
     re-resolved), so a 16-tenant shard pays ~1 dispatch per
     (kind, |V|) instead of 16, with O(1) not O(T) host work per
     dispatch. Results stay on device;
  3. **collect phase** — LAST tick's pending results materialize
     through the audited ``queries.to_host`` sink while THIS tick's
     work is still executing on the devices (double buffering: the
     host's sync time overlaps device compute, and requests retire
     exactly one tick after dispatch).

The steady-state mutation phase stays transfer-free per shard — same
``jax.transfer_guard`` contract as the single-device tick, pinned by
tests and the ``fleet.*`` entries in ``repro.analysis``. Query
payloads cross host->device once, as ONE explicit ``device_put`` per
batched group (admission keeps them host-side: they are tiny and the
batcher wants to stack them anyway); answers cross back in collect,
after the kernels returned.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.connectivity import queries
from repro.connectivity.service import (MUTATION_KINDS, ConnectivityService,
                                        Request)
from repro.core.batch import next_pow2
from repro.obs import trace as obs

# kinds the cross-tenant batcher stacks (per-row payloads); the scalar
# kinds dispatch one tiny kernel per tenant instead
BATCHED_KINDS = ("same_component", "component_size")

_MIN_QROWS = 8


@functools.partial(jax.jit, static_argnames=("kind",))
def _batched_query_jit(labels, batch, *, kind: str):
    """ONE program answering a query kind for a whole same-|V| tenant
    group: ``labels`` is the stacked label plane [T, V] (one array —
    see ``_label_plane``), ``batch`` is the padded per-tenant query
    rows ([T, Q, 2] pairs or [T, Q] vertices). vmap over the
    single-tenant kernels keeps the fleet bit-identical to the
    per-tenant path."""
    if kind == "same_component":
        return jax.vmap(queries.same_component)(labels, batch)
    return jax.vmap(queries.component_size)(labels, batch)


@jax.jit
def _plane_row_update_jit(plane, row, idx):
    """Patch ONE tenant's row into a cached label plane. ``idx`` is a
    traced scalar (not static) so every row position shares one
    compiled program."""
    return plane.at[idx].set(row)


def _mark_labels_dirty(shard, tenants) -> None:
    """Invalidate ``_label_plane`` entries containing these tenants
    (called by the mutation phase — a mutated session REPLACES its
    label array, so any cached stack holding the old one is stale)."""
    dirty = getattr(shard, "_fleet_dirty_labels", None)
    if dirty is None:
        dirty = shard._fleet_dirty_labels = set()
    dirty.update(tenants)


def _label_plane(shard, v: int, group):
    """The stacked [T, V] label plane for one same-|V| tenant group,
    CACHED on the shard across ticks. Passing T separate label arrays
    into a jit costs O(T) host-side argument processing per dispatch —
    at 64 tenants/device that is the same order as the per-tenant
    dispatch loop the batcher exists to remove; even reading T label
    properties to check freshness costs more than the dispatch itself.
    So the stack is one device array rebuilt (one ``jnp.stack``
    launch, no transfer: every operand already lives on this shard)
    ONLY when the mutation phase marked a member dirty
    (``_mark_labels_dirty``) — the engine sees every mutation, and
    membership changes show up in the cache key itself (the sorted
    tenant tuple; the fleet's migration paths also drop the source
    shard's cache outright). Steady state reuses the plane with O(1)
    host work per dispatch."""
    key = (v, tuple(g[0] for g in group))
    cache = getattr(shard, "_fleet_label_planes", None)
    if cache is None:
        cache = shard._fleet_label_planes = {}
    dirty = getattr(shard, "_fleet_dirty_labels", ())
    plane = cache.get(key)
    if plane is None:
        plane = jnp.stack([t.labels for _, t, _ in group])
    elif dirty:
        # k mutated members -> k O(1) row patches (one small
        # dynamic_update_slice dispatch each), NOT a T-array restack:
        # restacking a 128-tenant plane costs ~T host dispatches and
        # would hand the mutation tick an O(T) bill for k~1 changes
        for i, name in enumerate(key[1]):
            if name in dirty:
                idx = jax.device_put(np.int32(i), shard.device)
                plane = _plane_row_update_jit(plane, group[i][1].labels,
                                              idx)
    else:
        return plane
    cache[key] = plane
    if dirty:
        shard._fleet_dirty_labels -= set(key[1])
    return plane


@dataclasses.dataclass
class PendingGroup:
    """One dispatched query group awaiting collect: either a batched
    (kind, |V|) tenant stack or a single tenant's scalar-kind call."""

    kind: str
    tenants: list                    # tenant names, stack order
    reqs: list                       # list[list[Request]] per tenant
    rows: list                       # list[list[int]] rows per request
    result: Any                      # device array(s), not yet synced
    batched: bool = True


def dispatch_queries(shard: ConnectivityService, admitted
                     ) -> list[PendingGroup]:
    """Phase-2 dispatch for one shard: group, stack, launch. Returns
    pending groups whose results are still device-resident."""
    by_kind: dict[str, dict[str, list]] = {}
    for r in admitted:
        by_kind.setdefault(r.kind, {}).setdefault(r.tenant, []).append(r)
    pending: list[PendingGroup] = []
    for kind, tenants in by_kind.items():
        if kind in BATCHED_KINDS:
            pending.extend(_dispatch_batched(shard, kind, tenants))
        else:
            pending.extend(_dispatch_scalar(shard, kind, tenants))
    return pending


def _fail_group(shard, reqs, err) -> None:
    for r in reqs:
        shard._fail(r, err)


def _dispatch_batched(shard, kind, tenants) -> list[PendingGroup]:
    # sub-group by |V|: the stacked kernel needs one label shape
    by_v: dict[int, list] = {}
    for tenant, reqs in sorted(tenants.items()):
        try:
            t = shard.registry.get(tenant)
        except Exception as err:
            _fail_group(shard, reqs, err)
            continue
        by_v.setdefault(t.num_nodes, []).append((tenant, t, reqs))
    out = []
    for v, group in by_v.items():
        names = [g[0] for g in group]
        with obs.span(f"fleet.query.{kind}", tenants=len(group),
                      num_nodes=v) as sp:
            try:
                flats, rows = [], []
                for _, _, reqs in group:
                    if len(reqs) == 1:      # no concat copy on the
                        f = np.asarray(reqs[0].payload)   # common path
                        flats.append(f)
                        rows.append([f.shape[0]])
                        continue
                    parts = [np.asarray(r.payload) for r in reqs]
                    flats.append(np.concatenate(parts, axis=0))
                    rows.append([p.shape[0] for p in parts])
                qb = next_pow2(max(_MIN_QROWS,
                                   max(f.shape[0] for f in flats)))
                if all(f.shape[0] == qb for f in flats):
                    stacked = np.stack(flats)   # uniform: no pad fill
                else:
                    shape = (len(group), qb) + flats[0].shape[1:]
                    stacked = np.zeros(shape, np.int32)
                    for i, f in enumerate(flats):
                        stacked[i, : f.shape[0]] = f
                # the ONE host->device crossing of the query phase:
                # explicit, batched, legal under transfer_guard
                batch = jax.device_put(stacked, shard.device)
                labels = _label_plane(shard, v, group)
                result = _batched_query_jit(labels, batch, kind=kind)
                sp.tag(rows=int(sum(f.shape[0] for f in flats)))
            except Exception as err:      # fail the group, not the tick
                for _, _, reqs in group:
                    _fail_group(shard, reqs, err)
                sp.tag(failed=sum(len(g[2]) for g in group))
                continue
        shard.stats["query_calls"] += 1
        out.append(PendingGroup(kind=kind, tenants=names,
                                reqs=[g[2] for g in group], rows=rows,
                                result=result))
    return out


def _dispatch_scalar(shard, kind, tenants) -> list[PendingGroup]:
    out = []
    for tenant, reqs in sorted(tenants.items()):
        with obs.span(f"fleet.query.{kind}", tenant=tenant) as sp:
            try:
                labels = shard.registry.get(tenant).labels
                result = getattr(queries, "count_components"
                                 if kind == "count_components"
                                 else "component_histogram")(labels)
            except Exception as err:
                _fail_group(shard, reqs, err)
                sp.tag(failed=len(reqs))
                continue
        shard.stats["query_calls"] += 1
        out.append(PendingGroup(kind=kind, tenants=[tenant],
                                reqs=[reqs], rows=[[0] * len(reqs)],
                                result=result, batched=False))
    return out


def collect_group(shard: ConnectivityService, group: PendingGroup
                  ) -> None:
    """Phase-3 materialization of one pending group: the audited
    device->host sink, answer slicing, retire + end-to-end SLO."""
    record = obs.enabled()
    try:
        host = queries.to_host(group.result)
    except Exception as err:
        for reqs in group.reqs:
            _fail_group(shard, reqs, err)
        return
    now = time.perf_counter()
    for i, (tenant, reqs, rows) in enumerate(
            zip(group.tenants, group.reqs, group.rows)):
        off = 0
        for r, nrows in zip(reqs, rows):
            if group.batched:
                r.result = host[i, off: off + nrows]
                off += nrows
                shard.stats["pairs_answered"] += nrows
            elif group.kind == "count_components":
                r.result = int(host)
            else:
                r.result = host
            r.done = True
            shard.stats["queries_served"] += 1
            shard.stats["recomputes_avoided"] += 1
            if record:
                # END-TO-END: collect minus submit — queue wait,
                # dispatch, device time, and the one-tick pipeline
                # delay all included (this is what a user of the
                # fleet front door actually waits)
                shard.slo.record(tenant, group.kind, now - r.t_submit)


class PipelinedTickEngine:
    """Double-buffered tick loop over per-device shards.

    ``tick()`` dispatches mutation + query phases for EVERY shard
    before syncing anything, then collects the PREVIOUS tick's pending
    results — so the host's only blocking read overlaps the devices
    executing the current tick. ``flush()`` drains the last in-flight
    tick when the queues run dry."""

    def __init__(self, shards: list):
        self.shards = list(shards)
        self._inflight: list = []     # (shard, admitted, groups)
        self.stats = {"ticks": 0, "batched_dispatches": 0,
                      "collects": 0}

    @property
    def inflight(self) -> bool:
        return bool(self._inflight)

    def tick(self) -> list:
        """One pipelined tick; returns the requests RETIRED this tick
        (admitted one tick earlier — the pipeline's latency price)."""
        staged = []
        for shard in self.shards:
            admitted = shard._pop_admitted()
            if admitted:
                shard.stats["ticks"] += 1
            staged.append((shard, admitted))
        if any(adm for _, adm in staged):
            self.stats["ticks"] += 1
        with obs.span("fleet.tick", step=self.stats["ticks"],
                      admitted=sum(len(a) for _, a in staged)):
            # phase 1: EVERY shard's mutations dispatch back-to-back
            for shard, admitted in staged:
                for kind in MUTATION_KINDS:
                    batch = [r for r in admitted if r.kind == kind]
                    if batch:
                        _mark_labels_dirty(
                            shard, (r.tenant for r in batch))
                        shard._run_mutations(kind, batch)
            # phase 2: query kernels, still no syncs
            current = []
            for shard, admitted in staged:
                qreqs = [r for r in admitted
                         if r.kind not in MUTATION_KINDS and not r.done]
                groups = dispatch_queries(shard, qreqs)
                self.stats["batched_dispatches"] += sum(
                    1 for g in groups if g.batched)
                if admitted:
                    current.append((shard, admitted, groups))
            # phase 3: collect LAST tick while this one executes
            retired = self._collect()
            self._inflight = current
        return retired

    def _collect(self) -> list:
        retired = []
        for shard, admitted, groups in self._inflight:
            for g in groups:
                collect_group(shard, g)
            self.stats["collects"] += len(groups)
            retired.extend(admitted)
        self._inflight = []
        return retired

    def flush(self) -> list:
        """Drain the in-flight tick (the pipeline's tail)."""
        return self._collect()
