"""repro.fleet — sharded multi-tenant serving across a device mesh
(DESIGN.md §15).

Three layers, importable separately:

  * ``placement`` — work-model bin packing (packed tenants) + shard
    routing (mesh tenants), host-side only;
  * ``engine`` — the pipelined tick: batched cross-tenant query
    kernels, double-buffered dispatch/collect over per-device shards;
  * ``service`` — the ``FleetService`` front door: admit / submit /
    step / retire, rebalancing, merged fleet SLOs.
"""
from repro.fleet.engine import (BATCHED_KINDS, PendingGroup,
                                PipelinedTickEngine, collect_group,
                                dispatch_queries)
from repro.fleet.placement import (DEFAULT_SHARD_THRESHOLD, PlacementPlan,
                                   TenantSpec, imbalance, plan_placement,
                                   predicted_work, size_plan)
from repro.fleet.service import FleetService, ShardedTenant

__all__ = [
    "BATCHED_KINDS", "DEFAULT_SHARD_THRESHOLD", "FleetService",
    "PendingGroup", "PipelinedTickEngine", "PlacementPlan",
    "ShardedTenant", "TenantSpec", "collect_group", "dispatch_queries",
    "imbalance", "plan_placement", "predicted_work", "size_plan",
]
