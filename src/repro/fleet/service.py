"""Fleet front door: one admit/submit/step/retire surface over a
device mesh (DESIGN.md §15).

``FleetService`` composes the pieces the rest of the repo already
ships into a multi-device serving plane:

  * **packed tenants** ride thin per-device shells — one pinned
    ``ConnectivityService`` per mesh device (``device=`` commits every
    payload and every session's dynamic state to that device), ticked
    together by the ``PipelinedTickEngine`` so the host dispatches all
    shards' work before syncing any of it;
  * **sharded tenants** (predicted work >= ``shard_threshold``) are too
    big for one device: each owns a device-resident ``EdgeLog`` whose
    alive view re-solves through the ``distributed`` backend across the
    WHOLE mesh (``DistributedRunnerCache`` amortizes the shard_map
    build per capacity bucket), and their queries run on the replicated
    label array — dispatched this tick, collected next tick, same
    double-buffer discipline as the packed path;
  * **rebalancing** — every ``rebalance_every`` ticks the service reads
    per-device LIVE load (host-known edge counts through the same
    ``predicted_work`` model placement packs on) and, when
    ``imbalance`` crosses ``rebalance_factor``, replans and migrates
    drifted tenants (a deliberate maintenance sync: edges come back to
    host, the tenant re-opens pinned to its new device). Tenants whose
    live work crosses the shard threshold promote to the sharded class
    the same way.

SLO accounting: each shard's ``SLORecorder`` IS the per-device
recorder; sharded-tenant latencies land in ``mesh_slo``. ``slo()``
merges them with ``obs.merge_recorders`` — exact bucket-count sums,
so global percentiles are the percentiles of the union stream, not an
average of per-device percentiles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.connectivity import policy, queries
from repro.connectivity.service import (KINDS, MUTATION_KINDS, QUERY_KINDS,
                                        ConnectivityService, Request)
from repro.core.batch import pad_rows_pow2
from repro.core.distributed import DistributedRunnerCache
from repro.fleet.engine import PipelinedTickEngine
from repro.fleet.placement import (DEFAULT_SHARD_THRESHOLD, TenantSpec,
                                   imbalance, plan_placement,
                                   predicted_work)
from repro.graphs.device import DeviceGraph, EdgeLog, validate_edge_bounds
from repro.obs import trace as obs
from repro.obs.slo import SLORecorder, merge_recorders


class ShardedTenant:
    """One mesh-wide tenant: a device-resident tombstone log re-solved
    through the ``distributed`` backend. Labels are lazy — mutations
    only mark the partition dirty; the next query (or an explicit
    ``resolve()``) dispatches ONE mesh solve for however many mutations
    accumulated. Mutation dispatch itself is device-side (``EdgeLog``
    append/tombstone jits); this class sits OUTSIDE the per-shard
    transfer-free contract because the solve crosses the whole mesh."""

    def __init__(self, name: str, num_nodes: int,
                 runners: DistributedRunnerCache):
        self.name = name
        self.num_nodes = int(num_nodes)
        self.runners = runners
        self.log = EdgeLog(num_nodes)
        self.num_edges = 0              # host-known inserted total
        self.version = 0                # resolves performed
        self.resolves = 0
        self._labels = None
        self._dirty = True              # empty graph still needs labels

    def _coerce(self, edges) -> DeviceGraph:
        if isinstance(edges, DeviceGraph):
            if edges.num_nodes not in (0, self.num_nodes):
                raise ValueError(f"delta num_nodes {edges.num_nodes} != "
                                 f"{self.num_nodes}")
            if edges.num_nodes == 0:
                return DeviceGraph.from_edges(edges.edges, self.num_nodes)
            return edges
        arr = np.asarray(edges, np.int32).reshape(-1, 2)
        validate_edge_bounds(arr, self.num_nodes)
        return DeviceGraph.from_edges(arr, self.num_nodes, name=self.name)

    def insert(self, edges) -> int:
        delta = self._coerce(edges)
        t = delta.true_edges_static
        if t is None:
            raise ValueError("sharded-tenant inserts need a static "
                             "true count (EdgeLog.append contract)")
        self.log.append(delta)
        self.num_edges += t
        self._dirty = True
        self.version += 1
        return self.version

    def delete(self, edges) -> int:
        if isinstance(edges, DeviceGraph):
            dels, d_true = edges.edges, edges.true_edges
        else:
            arr = np.asarray(edges, np.int32).reshape(-1, 2)
            validate_edge_bounds(arr, self.num_nodes)
            dels, d_true = pad_rows_pow2(arr), arr.shape[0]
        self.log.delete(jnp.asarray(dels, jnp.int32), d_true)
        self._dirty = True
        self.version += 1
        return self.version

    def resolve(self):
        """Labels [V] (replicated device array), re-solving the alive
        view across the mesh iff a mutation landed since the last
        solve. The log's pow2 capacity IS the runner-cache key, so
        steady-state re-solves reuse one compiled shard_map program."""
        if self._dirty or self._labels is None:
            self._labels = self.runners.solve(self.log.view())
            self.resolves += 1
            self._dirty = False
        return self._labels

    @property
    def labels(self):
        return self.resolve()


class FleetService:
    """Sharded multi-tenant connectivity serving over a device mesh.

    ``admit()`` places a tenant (packed onto the least-loaded device,
    or sharded across the mesh when its predicted work crosses the
    threshold); ``submit*()`` routes requests to the owning shard's
    queue; ``step()`` runs one pipelined fleet tick; ``run()`` drains
    everything including the pipeline tail. One object, any mesh size —
    on a single device it degrades to exactly one shard (the engine's
    batching still applies)."""

    def __init__(self, devices=None, *, slots_per_device: int = 32,
                 lift_steps: int = 2,
                 shard_threshold: int = DEFAULT_SHARD_THRESHOLD,
                 rebalance_every: int = 16,
                 rebalance_factor: float = 1.5,
                 policy_cache: policy.AutotuneCache | None = None,
                 runners: DistributedRunnerCache | None = None):
        self.devices = list(devices) if devices is not None \
            else list(jax.devices())
        if not self.devices:
            raise ValueError("FleetService needs at least one device")
        self.mesh = Mesh(np.asarray(self.devices), ("data",))
        self.shards = [
            ConnectivityService(slots=slots_per_device, device=d)
            for d in self.devices]
        self.engine = PipelinedTickEngine(self.shards)
        if runners is not None:
            # share compiled shard_map programs across service
            # instances (the cache is keyed by (rows, |V|), so it only
            # makes sense for an identical mesh)
            if list(runners.mesh.devices.flat) != self.devices:
                raise ValueError("shared runner cache was built for a "
                                 "different mesh")
            self.runners = runners
        else:
            self.runners = DistributedRunnerCache(self.mesh, ("data",),
                                                  lift_steps=lift_steps)
        self.shard_threshold = int(shard_threshold)
        self.rebalance_every = int(rebalance_every)
        self.rebalance_factor = float(rebalance_factor)
        self.policy_cache = policy_cache
        # sharded-tenant request plumbing (own queue + double buffer,
        # mirroring the engine's discipline)
        self._sharded: dict[str, ShardedTenant] = {}
        self._placement: dict[str, int] = {}   # packed tenant -> dev idx
        self._squeue: list[Request] = []
        self._s_inflight: list = []            # (req, device result, rows)
        self._uid = 0
        self.mesh_slo = SLORecorder()
        self.stats = {"ticks": 0, "admitted_packed": 0,
                      "admitted_sharded": 0, "sharded_resolves": 0,
                      "rebalances": 0, "migrations": 0, "promotions": 0}

    # -- admission ---------------------------------------------------------

    def tenants(self) -> list[str]:
        return sorted(list(self._placement) + list(self._sharded))

    def placement_of(self, name: str):
        """'mesh' for a sharded tenant, else the owning device index."""
        if name in self._sharded:
            return "mesh"
        if name in self._placement:
            return self._placement[name]
        raise KeyError(f"unknown tenant {name!r}; have {self.tenants()}")

    def admit(self, name: str, num_nodes: int, *,
              expected_edges: int = 0,
              degree_skew: float | None = None):
        """Place + create one tenant. Placement is incremental LPT over
        LIVE device loads — admitting tenants one by one lands each on
        the currently lightest device, consistent with what a full
        ``plan_placement`` replan would choose for the same arrival
        order (same work model, same tie-break)."""
        if name in self._sharded or name in self._placement:
            raise ValueError(f"tenant {name!r} already admitted")
        work = predicted_work(num_nodes, expected_edges,
                              degree_skew=degree_skew,
                              cache=self.policy_cache)
        if work >= self.shard_threshold:
            t = ShardedTenant(name, num_nodes, self.runners)
            self._sharded[name] = t
            self.stats["admitted_sharded"] += 1
            obs.count("fleet.admit.sharded")
            return t
        loads = self.device_loads()
        idx = min(range(len(self.shards)), key=lambda i: (loads[i], i))
        self.shards[idx].registry.create(name, num_nodes)
        self._placement[name] = idx
        self.stats["admitted_packed"] += 1
        obs.count("fleet.admit.packed")
        return self.shards[idx].registry.get(name)

    def drop(self, name: str) -> None:
        if name in self._sharded:
            del self._sharded[name]
            return
        idx = self._placement.pop(name)   # KeyError for unknown tenants
        self.shards[idx].registry.drop(name)

    # -- submission --------------------------------------------------------

    def submit(self, tenant: str, kind: str, payload=None) -> int:
        if tenant in self._sharded:
            return self._submit_sharded(tenant, kind, payload)
        idx = self._placement.get(tenant)
        if idx is None:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"have {self.tenants()}")
        return self.shards[idx].submit(tenant, kind, payload)

    def _submit_sharded(self, tenant: str, kind: str, payload) -> int:
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r}; choose from {KINDS}")
        if kind in ("same_component", "component_size"):
            if payload is None:
                raise ValueError(f"kind {kind!r} requires a payload")
            payload = np.asarray(payload, np.int32)
            payload = payload.reshape(-1) if kind == "component_size" \
                else payload.reshape(-1, 2)
        elif kind in MUTATION_KINDS and payload is None:
            raise ValueError(f"kind {kind!r} requires a payload")
        self._uid += 1
        self._squeue.append(Request(self._uid, tenant, kind, payload,
                                    t_submit=time.perf_counter()))
        return self._uid

    def submit_insert(self, tenant: str, edges) -> int:
        return self.submit(tenant, "insert", edges)

    def submit_delete(self, tenant: str, edges) -> int:
        return self.submit(tenant, "delete", edges)

    def submit_query(self, tenant: str, kind: str, payload=None) -> int:
        if kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; "
                             f"choose from {QUERY_KINDS}")
        return self.submit(tenant, kind, payload)

    # -- the fleet tick ----------------------------------------------------

    @property
    def pending(self) -> int:
        return (sum(len(s.queue) for s in self.shards)
                + len(self._squeue))

    @property
    def inflight(self) -> bool:
        return self.engine.inflight or bool(self._s_inflight)

    def step(self) -> list[Request]:
        """One fleet tick: the engine's pipelined pass over every
        per-device shard, plus the sharded-tenant dispatch/collect.
        Returns requests retired THIS tick (dispatched one tick ago)."""
        self.stats["ticks"] += 1
        retired = self.engine.tick()
        retired.extend(self._step_sharded())
        if self.rebalance_every > 0 \
                and self.stats["ticks"] % self.rebalance_every == 0:
            self._maybe_rebalance()
        return retired

    def run(self) -> list[Request]:
        """Drain every queue AND the pipeline tail."""
        finished: list[Request] = []
        while self.pending:
            finished.extend(self.step())
        while self.inflight:
            finished.extend(self.engine.flush())
            finished.extend(self._collect_sharded())
        return finished

    def _step_sharded(self) -> list[Request]:
        """Sharded-tenant phase of a tick: apply mutations (device-side
        log jits; retire immediately — the version is host-known), then
        dispatch queries on the lazily re-solved replicated labels;
        collect LAST tick's query results."""
        admitted, self._squeue = self._squeue, []
        retired: list[Request] = []
        current: list = []
        for r in admitted:
            t = self._sharded.get(r.tenant)
            try:
                if t is None:
                    raise KeyError(f"unknown sharded tenant {r.tenant!r}")
                if r.kind in MUTATION_KINDS:
                    with obs.span(f"fleet.sharded.{r.kind}",
                                  tenant=r.tenant):
                        r.result = getattr(t, r.kind)(r.payload)
                    r.done = True
                    if obs.enabled():
                        self.mesh_slo.record(
                            r.tenant, r.kind,
                            time.perf_counter() - r.t_submit)
                    retired.append(r)
                    continue
                before = t.resolves
                with obs.span(f"fleet.sharded.query.{r.kind}",
                              tenant=r.tenant):
                    labels = t.resolve()
                    if t.resolves != before:
                        self.stats["sharded_resolves"] += 1
                    if r.kind == "same_component":
                        res = queries.same_component(
                            labels, pad_rows_pow2(r.payload))
                        rows = int(r.payload.shape[0])
                    elif r.kind == "component_size":
                        res = queries.component_size(
                            labels, pad_rows_pow2(r.payload))
                        rows = int(r.payload.shape[0])
                    elif r.kind == "count_components":
                        res, rows = queries.count_components(labels), -1
                    else:
                        res, rows = queries.component_histogram(labels), -2
                current.append((r, res, rows))
            except Exception as err:
                r.error = f"{type(err).__name__}: {err}"
                r.done = True
                retired.append(r)
        retired.extend(self._collect_sharded())
        self._s_inflight = current
        return retired

    def _collect_sharded(self) -> list[Request]:
        pending, self._s_inflight = self._s_inflight, []
        retired = []
        now = time.perf_counter()
        for r, res, rows in pending:
            try:
                host = queries.to_host(res)
                if rows == -1:
                    r.result = int(host)
                elif rows == -2:
                    r.result = host
                else:
                    r.result = host[:rows]
            except Exception as err:
                r.error = f"{type(err).__name__}: {err}"
            r.done = True
            if obs.enabled() and r.error is None:
                self.mesh_slo.record(r.tenant, r.kind, now - r.t_submit)
            retired.append(r)
        return retired

    # -- rebalancing -------------------------------------------------------

    def _live_spec(self, name: str, idx: int) -> TenantSpec:
        t = self.shards[idx].registry.get(name)
        return TenantSpec(name, t.num_nodes, t.num_edges,
                          degree_skew=None)

    def device_loads(self) -> list[int]:
        """Predicted work per device over LIVE (host-known) edge
        counts — no sync; this is what the rebalance trigger polls."""
        loads = [0] * len(self.shards)
        for name, idx in self._placement.items():
            s = self._live_spec(name, idx)
            loads[idx] += predicted_work(s.num_nodes, s.num_edges,
                                         cache=self.policy_cache)
        return loads

    def _maybe_rebalance(self) -> None:
        loads = self.device_loads()
        drift = imbalance(loads)
        if drift <= self.rebalance_factor:
            return
        with obs.span("fleet.rebalance", imbalance=round(drift, 3)) as sp:
            specs = [self._live_spec(n, i)
                     for n, i in self._placement.items()]
            plan = plan_placement(specs, len(self.shards),
                                  shard_threshold=self.shard_threshold,
                                  cache=self.policy_cache)
            moved = 0
            for name in plan.sharded:          # grew past the threshold
                if self._can_move(name):
                    self._promote(name)
                    moved += 1
            for name, dst in plan.device_of.items():
                if name not in self._placement:
                    continue                   # just promoted
                src = self._placement[name]
                if dst != src and self._can_move(name):
                    self._migrate(name, src, dst)
                    moved += 1
            sp.tag(moved=moved)
        self.stats["rebalances"] += 1

    def _can_move(self, name: str) -> bool:
        """A tenant with queued or in-flight requests on its shard
        stays put this round — migration drops and re-creates the
        session, which would orphan them."""
        src = self.shards[self._placement[name]]
        if any(r.tenant == name for r in src.queue):
            return False
        for shard, admitted, _ in self.engine._inflight:
            if shard is src and any(r.tenant == name for r in admitted):
                return False
        return True

    def _take_out(self, name: str):
        """Maintenance extraction: host view of the surviving edges
        (the ONE deliberate sync of the migration path), then drop the
        source session."""
        src_idx = self._placement.pop(name)
        t = self.shards[src_idx].registry.get(name)
        num_nodes, edges = t.num_nodes, t.edges()
        self.shards[src_idx].registry.drop(name)
        # the engine's cached label planes key on group MEMBERSHIP; a
        # departing tenant could later return under the same key with
        # labels the mutation phase never saw — drop the lot
        self.shards[src_idx]._fleet_label_planes = {}
        return num_nodes, edges

    def _migrate(self, name: str, src: int, dst: int) -> None:
        with obs.span("fleet.migrate", tenant=name, src=src, dst=dst):
            num_nodes, edges = self._take_out(name)
            self.shards[dst].registry.create(name, num_nodes)
            if edges.size:
                # re-ingests through the destination's pinned session:
                # the bulk insert policy-routes (rebuild for big sets)
                # and every array commits to the new device
                self.shards[dst].registry.insert(name, edges)
            self._placement[name] = dst
        self.stats["migrations"] += 1
        obs.count("fleet.migrations")

    def _promote(self, name: str) -> None:
        """Packed -> sharded class change when live work crosses the
        threshold: same extract-and-reingest as migration, landing in a
        mesh-wide tombstone log instead of a single-device session."""
        with obs.span("fleet.promote", tenant=name):
            num_nodes, edges = self._take_out(name)
            t = ShardedTenant(name, num_nodes, self.runners)
            if edges.size:
                t.insert(edges)
            self._sharded[name] = t
        self.stats["promotions"] += 1
        obs.count("fleet.promotions")

    # -- telemetry ---------------------------------------------------------

    def slo(self) -> SLORecorder:
        """EXACT global percentiles: per-device recorders + the mesh
        recorder merged by bucket-count summation (spec-checked), so
        the fleet's p99 is the p99 of the union request stream."""
        return merge_recorders([s.slo for s in self.shards]
                               + [self.mesh_slo])

    def slo_summary(self) -> dict:
        return self.slo().summary()

    def stats_summary(self) -> dict:
        out = dict(self.stats)
        out["engine"] = dict(self.engine.stats)
        out["runner_cache"] = dict(self.runners.stats)
        out["shards"] = [dict(s.stats) for s in self.shards]
        out["placement"] = {**{n: "mesh" for n in self._sharded},
                            **dict(self._placement)}
        return out

    def obs_summary(self) -> dict:
        return {"ticks": self.stats["ticks"],
                "latency": self.slo_summary(),
                "counters": dict(obs.tracer().counters),
                "fleet": {k: v for k, v in self.stats.items()
                          if k != "ticks"}}
