"""Placement planner: which device serves which tenant (DESIGN.md §15).

Two tenant classes fall out of the work model:

  * **packed tenants** — small enough that one device serves many; the
    planner bin-packs them onto the mesh's devices by PREDICTED work,
    reusing the same per-round cost model ``ExecutionPlan.predicted``
    attaches (hook ops scale with |E| per round, jump ops with |V| per
    compress sweep) — placement and the execution planner can't drift
    apart because they read one model;
  * **sharded tenants** — predicted work at or above
    ``shard_threshold``; no single device should own one, so they
    route onto sharded ``DeviceGraph``s served by the existing
    ``distributed`` backend across the WHOLE mesh
    (``core.distributed``), not onto any one bin.

Packing is greedy LPT (longest-processing-time first): tenants sorted
by descending work, each assigned to the currently lightest device —
the classic 4/3-approximation, deterministic (ties break on device
index) so a replan over unchanged specs is a fixed point and the
rebalancer never oscillates.

``imbalance(loads)`` (max/mean) is the rebalance trigger the fleet
service polls: merge/split-driven growth drifts per-device load, and
when the ratio crosses the service's factor it replans against LIVE
edge counts and migrates the moved tenants.

Everything here is host-side metadata — planning touches no device.
"""
from __future__ import annotations

import dataclasses

from repro.api.plan import ExecutionPlan
from repro.connectivity import policy
from repro.core.batch import bucket_shape
from repro.core.segmentation import plan_segmentation

# Predicted-work floor for routing a tenant onto the sharded/
# distributed path instead of packing it onto one device. In work
# units (hook ops per round + jump ops per sweep = |E| + |V|); the
# CI-scale benchmark overrides it to exercise both classes.
DEFAULT_SHARD_THRESHOLD = 1 << 22


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Host-side sizing facts the planner packs on: |V| is exact,
    ``num_edges`` is the expected (admission) or live (rebalance)
    count — the same host-known upper bound the policy's size feature
    uses; reading the exact alive count would sync."""

    name: str
    num_nodes: int
    num_edges: int = 0
    degree_skew: float | None = None


def size_plan(num_nodes: int, num_edges: int, *,
              degree_skew: float | None = None,
              cache: policy.AutotuneCache | None = None) -> ExecutionPlan:
    """An ``ExecutionPlan`` for a bare (|V|, |E|) size — the same
    backend choice and ``predicted`` work model ``Solver._build_plan``
    attaches, without opening a session or touching a device. This is
    the planner's one costing primitive."""
    num_nodes, num_edges = int(num_nodes), int(num_edges)
    chosen, reason = policy.select_static_explained(
        num_nodes, num_edges, degree_skew=degree_skew, cache=cache)
    seg = plan_segmentation(num_edges, num_nodes)
    predicted = {"hook_ops_per_round": num_edges,
                 "jump_ops_per_sweep": num_nodes,
                 "segments": seg.num_segments}
    if degree_skew is not None:
        predicted["degree_skew"] = round(float(degree_skew), 3)
    return ExecutionPlan(backend=chosen, reason=reason,
                         num_nodes=num_nodes, num_edges=num_edges,
                         bucket=bucket_shape(num_nodes, num_edges),
                         segmentation=seg, predicted=predicted)


def predicted_work(num_nodes: int, num_edges: int, *,
                   degree_skew: float | None = None,
                   cache: policy.AutotuneCache | None = None) -> int:
    """Scalar packing weight from ``ExecutionPlan.predicted``: hook
    ops per round + jump ops per sweep (= |E| + |V|) — proportional to
    one adaptive round over the tenant, which is what a steady-state
    tick costs."""
    p = size_plan(num_nodes, num_edges, degree_skew=degree_skew,
                  cache=cache).predicted
    return int(p["hook_ops_per_round"]) + int(p["jump_ops_per_sweep"])


def imbalance(loads) -> float:
    """max/mean over per-device loads — the rebalance trigger. 1.0
    (perfectly balanced) when nothing is loaded."""
    loads = list(loads)
    total = sum(loads)
    if not loads or total <= 0:
        return 1.0
    return max(loads) / (total / len(loads))


@dataclasses.dataclass
class PlacementPlan:
    """One planning decision: packed assignments + sharded routing."""

    device_of: dict                  # packed tenant -> device index
    sharded: tuple                   # tenants routed to the mesh
    loads: tuple                     # predicted work per device
    work: dict                       # tenant -> predicted work units
    shard_threshold: int

    def imbalance(self) -> float:
        return imbalance(self.loads)

    def explain(self) -> str:
        lines = [f"placement over {len(self.loads)} device(s), "
                 f"shard_threshold={self.shard_threshold}:"]
        for name in sorted(self.sharded):
            lines.append(f"  {name}: SHARDED across the mesh "
                         f"(work={self.work[name]})")
        by_dev: dict[int, list] = {}
        for name, idx in self.device_of.items():
            by_dev.setdefault(idx, []).append(name)
        for idx in range(len(self.loads)):
            names = ", ".join(sorted(by_dev.get(idx, []))) or "-"
            lines.append(f"  device[{idx}] load={self.loads[idx]}: "
                         f"{names}")
        lines.append(f"  imbalance(max/mean)={self.imbalance():.3f}")
        return "\n".join(lines)


def plan_placement(specs, n_devices: int, *,
                   shard_threshold: int = DEFAULT_SHARD_THRESHOLD,
                   cache: policy.AutotuneCache | None = None
                   ) -> PlacementPlan:
    """Route + pack a tenant fleet over ``n_devices`` devices.

    Tenants whose predicted work reaches ``shard_threshold`` go to the
    sharded class; the rest LPT-pack onto devices. Deterministic for a
    given spec list (sort by (-work, name); lightest device wins, ties
    on index)."""
    if n_devices < 1:
        raise ValueError("plan_placement needs at least one device")
    specs = list(specs)
    if len({s.name for s in specs}) != len(specs):
        raise ValueError("duplicate tenant names in placement specs")
    work = {s.name: predicted_work(s.num_nodes, s.num_edges,
                                   degree_skew=s.degree_skew,
                                   cache=cache)
            for s in specs}
    sharded = tuple(sorted(s.name for s in specs
                           if work[s.name] >= shard_threshold))
    packed = sorted((s for s in specs if s.name not in sharded),
                    key=lambda s: (-work[s.name], s.name))
    loads = [0] * n_devices
    device_of: dict[str, int] = {}
    for s in packed:
        idx = min(range(n_devices), key=lambda i: (loads[i], i))
        device_of[s.name] = idx
        loads[idx] += work[s.name]
    return PlacementPlan(device_of=device_of, sharded=sharded,
                         loads=tuple(loads), work=work,
                         shard_threshold=shard_threshold)
