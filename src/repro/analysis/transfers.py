"""Pass ``transfer`` — compile-time transfer-freedom.

The serving contract (DESIGN.md §7–§10) says steady-state ticks —
absorb, delete, coalesce, plan — perform zero host round trips. The
runtime ``jax.transfer_guard("disallow")`` tests pin that on the
inputs they happen to run; this pass makes it a *static* guarantee
over the traced program:

  1. **the entry must stage at all** — ``jax.make_jaxpr`` fails
     exactly when the Python path materializes a tracer on the host
     (``.item()``, ``int(...)``, ``np.asarray`` on a traced value, a
     Python ``if`` on a traced bool). A trace failure on a
     ``transfer_free``-contracted entry is an error finding carrying
     the tracer leak's own message;
  2. **no host-callback primitives reachable** — ``pure_callback`` /
     ``io_callback`` / ``debug_callback`` / infeed / outfeed anywhere
     in the closed jaxpr (including loop bodies and called jaxprs) is
     a host round trip per invocation. Error on contracted entries,
     warning elsewhere (a callback in a benchmark-only path is legal
     but worth seeing);
  3. **no device_put of large host constants** inside contracted
     programs — a host->device transfer per call defeats the contract
     even though the guard classifies explicit ``device_put`` as
     legal. Scalar puts (the true-count idiom) are exempt.

The ONE audited host sink of the stack is
``repro.connectivity.queries.to_host`` — result materialization after
a query kernel, outside any jaxpr — so nothing here needs a runtime
whitelist: anything that shows up inside a traced program is a
violation by construction.
"""
from __future__ import annotations

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_utils import TracedEntry, eqn_site, walk_eqns

PASS_ID = "transfer"

# host round trip per invocation wherever they appear
_CALLBACK_PRIMS = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "host_callback_call", "outside_call",
}

_SCALAR_PUT_MAX_ELEMS = 8         # true-count / version scalars are fine


def run(traced: list[TracedEntry]) -> list[Finding]:
    findings: list[Finding] = []
    for t in traced:
        contracted = "transfer_free" in t.entry.contracts
        if t.failure is not None:
            if contracted:
                findings.append(Finding(
                    PASS_ID, t.name, "error", "trace-host-sync",
                    f"entry failed to stage ({t.failure.exc_type}): a "
                    "transfer-free path must close to a jaxpr — "
                    f"{t.failure.message.splitlines()[0][:200]}"))
            else:
                # not contracted transfer-free, but an entry that can't
                # stage at all is invisible to every jaxpr pass — say so
                findings.append(Finding(
                    PASS_ID, t.name, "warning", "trace-failed",
                    f"entry failed to trace ({t.failure.exc_type}); "
                    "jaxpr passes did not see it — "
                    f"{t.failure.message.splitlines()[0][:200]}"))
            continue
        for eqn in walk_eqns(t.jaxpr):
            prim = eqn.primitive.name
            if prim in _CALLBACK_PRIMS:
                file, line = eqn_site(eqn)
                findings.append(Finding(
                    PASS_ID, t.name,
                    "error" if contracted else "warning",
                    f"callback-{prim}",
                    f"host-callback primitive `{prim}` reachable "
                    + ("on a transfer-free contracted path (one host "
                       "round trip per tick)" if contracted else
                       "(host round trip per invocation)"),
                    file, line))
            elif prim == "device_put" and contracted:
                sizes = [getattr(v.aval, "size", 0) for v in eqn.invars]
                if any(s > _SCALAR_PUT_MAX_ELEMS for s in sizes):
                    file, line = eqn_site(eqn)
                    findings.append(Finding(
                        PASS_ID, t.name, "warning", "large-device-put",
                        "non-scalar device_put inside a transfer-free "
                        f"contracted program (sizes={sizes}) — a "
                        "host->device copy per call",
                        file, line))
    return findings
