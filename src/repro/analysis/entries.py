"""Non-backend traceable entries + the entry collector.

``repro.api.backends`` registers one trace spec per execution backend;
this module adds the two surfaces the serving layer runs that are NOT
a backend's own program:

* **the service tick** — what ``ConnectivityService._run_mutations``
  stages per tenant per tick: coalesce payload graphs with
  ``DeviceGraph.concat``, bucket with ``pad_pow2``, absorb through
  ``_absorb_jit`` (inserts) or tombstone through ``_delete_jit``
  (deletes). The tick is the hottest transfer-free contract in the
  repo — a host sync here blocks every tenant in the slot;
* **the query kernels** — ``repro.connectivity.queries``; all four are
  contracted transfer-free (results are materialized only through the
  audited ``to_host`` sink, *after* the kernel).

``all_entries()`` is the one discovery point the runner and the tests
use: it imports the spec-bearing modules for their registration side
effects and returns every ``TraceEntry`` in name order.
"""
from __future__ import annotations

from repro.api.registry import (TraceEntry, VarInfo, register_trace_spec,
                                trace_entries)

_TF = frozenset({"transfer_free", "bucketed"})


@register_trace_spec("service")
def _service_specs():
    import jax
    import jax.numpy as jnp

    from repro.core import incremental as inc_mod
    from repro.core.segmentation import adaptive_num_segments
    from repro.graphs.device import DeviceGraph

    def build_insert_tick(v, e):
        half = max(e // 2, 8)

        def fn(pi, edges_a, edges_b, version):
            # two coalesced payloads, as _run_mutations stages them
            batch = DeviceGraph.concat([
                DeviceGraph.from_edges(edges_a, v),
                DeviceGraph.from_edges(edges_b, v),
            ]).pad_pow2()
            return inc_mod._absorb_jit(
                pi, batch.edges, batch.true_edges_device(), version,
                lift_steps=2)
        return (fn,
                (jax.ShapeDtypeStruct((v,), jnp.int32),
                 jax.ShapeDtypeStruct((half, 2), jnp.int32),
                 jax.ShapeDtypeStruct((half, 2), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32)),
                [VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo()])

    def build_delete_tick(v, e):
        d = max(e // 8, 8)

        def fn(edges, alive, pi, dels_a, dels_b, version, deleted):
            batch = DeviceGraph.concat([
                DeviceGraph.from_edges(dels_a, v),
                DeviceGraph.from_edges(dels_b, v),
            ]).pad_pow2()
            return inc_mod._delete_jit(
                edges, alive, pi, batch.edges,
                batch.true_edges_device(), version, deleted,
                lift_steps=2, num_segments=adaptive_num_segments(e, v),
                scan_method="jnp", interpret=True)
        return (fn,
                (jax.ShapeDtypeStruct((e, 2), jnp.int32),
                 jax.ShapeDtypeStruct((e,), jnp.bool_),
                 jax.ShapeDtypeStruct((v,), jnp.int32),
                 jax.ShapeDtypeStruct((d, 2), jnp.int32),
                 jax.ShapeDtypeStruct((d, 2), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32)),
                [VarInfo(range=(0, v - 1), padded=True),
                 VarInfo(mask=True),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(),
                 VarInfo()])

    def build_delete_forest_tick(v, e):
        d = max(e // 8, 8)

        def fn(edges, alive, pi, parents, parent_eidx, dels_a, dels_b,
               version, deleted, routes):
            batch = DeviceGraph.concat([
                DeviceGraph.from_edges(dels_a, v),
                DeviceGraph.from_edges(dels_b, v),
            ]).pad_pow2()
            return inc_mod._delete_forest_jit(
                edges, alive, pi, parents, parent_eidx, batch.edges,
                batch.true_edges_device(), version, deleted, routes,
                lift_steps=2)
        return (fn,
                (jax.ShapeDtypeStruct((e, 2), jnp.int32),
                 jax.ShapeDtypeStruct((e,), jnp.bool_),
                 jax.ShapeDtypeStruct((v,), jnp.int32),
                 jax.ShapeDtypeStruct((v, 2), jnp.int32),
                 jax.ShapeDtypeStruct((v,), jnp.int32),
                 jax.ShapeDtypeStruct((d, 2), jnp.int32),
                 jax.ShapeDtypeStruct((d, 2), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32),
                 jax.ShapeDtypeStruct((2,), jnp.int32)),
                [VarInfo(range=(0, v - 1), padded=True),
                 VarInfo(mask=True),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(range=(-1, v - 1)),
                 VarInfo(range=(-1, e - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(),
                 VarInfo(),
                 VarInfo()])

    return [TraceEntry("service.tick.insert", build_insert_tick, _TF),
            TraceEntry("service.tick.delete", build_delete_tick, _TF),
            TraceEntry("service.tick.delete_forest",
                       build_delete_forest_tick, _TF)]


@register_trace_spec("obs")
def _obs_specs():
    """The INSTRUMENTED service tick: the PR-7 telemetry contract that
    carrying the ``repro.obs`` Metrics pytree through the mutation jits
    keeps the steady-state tick transfer-free. Same staging as the
    ``service.tick.*`` entries plus the ``record_mutation`` fold — if
    the metrics update ever grows a host sync or a callback, the
    ``transfer`` pass flags it here."""
    import jax
    import jax.numpy as jnp

    from repro.core import incremental as inc_mod
    from repro.core.segmentation import adaptive_num_segments
    from repro.graphs.device import DeviceGraph
    from repro.obs import metrics as obs_metrics

    n_slots = 16                          # Metrics.counts leading dim
    n_kinds = len(obs_metrics.HIST_KINDS)
    n_bins = obs_metrics.WORK_SPEC.num_bins

    def metrics_args():
        return ((jax.ShapeDtypeStruct((n_slots,), jnp.int32),
                 jax.ShapeDtypeStruct((n_kinds, n_bins), jnp.int32)),
                [VarInfo(), VarInfo()])

    def build_insert_tick(v, e):
        half = max(e // 2, 8)
        (m_avals, m_infos) = metrics_args()

        def fn(pi, edges_a, edges_b, version, counts, hist):
            metrics = obs_metrics.Metrics(counts, hist)
            batch = DeviceGraph.concat([
                DeviceGraph.from_edges(edges_a, v),
                DeviceGraph.from_edges(edges_b, v),
            ]).pad_pow2()
            true_count = batch.true_edges_device()
            pi1, version1, work = inc_mod._absorb_jit(
                pi, batch.edges, true_count, version, lift_steps=2)
            metrics = obs_metrics.record_mutation(
                metrics, work, true_count, version, version1,
                kind="insert")
            return pi1, version1, metrics
        return (fn,
                (jax.ShapeDtypeStruct((v,), jnp.int32),
                 jax.ShapeDtypeStruct((half, 2), jnp.int32),
                 jax.ShapeDtypeStruct((half, 2), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32)) + m_avals,
                [VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo()] + m_infos)

    def build_delete_tick(v, e):
        d = max(e // 8, 8)
        (m_avals, m_infos) = metrics_args()

        def fn(edges, alive, pi, dels, version, deleted, counts, hist):
            metrics = obs_metrics.Metrics(counts, hist)
            batch = DeviceGraph.from_edges(dels, v).pad_pow2()
            true_count = batch.true_edges_device()
            pi1, alive1, version1, deleted1, work = inc_mod._delete_jit(
                edges, alive, pi, batch.edges, true_count, version,
                deleted, lift_steps=2,
                num_segments=adaptive_num_segments(e, v),
                scan_method="jnp", interpret=True)
            metrics = obs_metrics.record_mutation(
                metrics, work, true_count, version, version1,
                kind="delete")
            return pi1, alive1, version1, deleted1, metrics
        return (fn,
                (jax.ShapeDtypeStruct((e, 2), jnp.int32),
                 jax.ShapeDtypeStruct((e,), jnp.bool_),
                 jax.ShapeDtypeStruct((v,), jnp.int32),
                 jax.ShapeDtypeStruct((d, 2), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32)) + m_avals,
                [VarInfo(range=(0, v - 1), padded=True),
                 VarInfo(mask=True),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1)),
                 VarInfo(),
                 VarInfo()] + m_infos)

    return [TraceEntry("obs.tick.insert", build_insert_tick, _TF),
            TraceEntry("obs.tick.delete", build_delete_tick, _TF)]


@register_trace_spec("queries")
def _query_specs():
    import jax
    import jax.numpy as jnp

    from repro.connectivity import queries as q

    def labels_arg(v):
        return (jax.ShapeDtypeStruct((v,), jnp.int32),
                VarInfo(range=(0, v - 1)))

    def build_same_component(v, e):
        la, li = labels_arg(v)
        nq = max(e // 16, 8)

        def fn(labels, pairs):
            return q.same_component(labels, pairs)
        return (fn, (la, jax.ShapeDtypeStruct((nq, 2), jnp.int32)),
                [li, VarInfo(range=(0, v - 1), padded=True)])

    def build_component_size(v, e):
        la, li = labels_arg(v)
        nq = max(e // 16, 8)

        def fn(labels, vertices):
            return q.component_size(labels, vertices)
        return (fn, (la, jax.ShapeDtypeStruct((nq,), jnp.int32)),
                [li, VarInfo(range=(0, v - 1), padded=True)])

    def build_count_components(v, e):
        la, li = labels_arg(v)

        def fn(labels):
            return q.count_components(labels)
        return fn, (la,), [li]

    def build_component_histogram(v, e):
        la, li = labels_arg(v)

        def fn(labels):
            return q.component_histogram(labels)
        return fn, (la,), [li]

    def build_forest_stats(v, e):
        la, li = labels_arg(v)

        def fn(labels, parents):
            return q.spanning_forest_stats(labels, parents)
        # parents rows carry -1 sentinels for roots, hence the -1 floor
        return (fn, (la, jax.ShapeDtypeStruct((v, 2), jnp.int32)),
                [li, VarInfo(range=(-1, v - 1))])

    return [
        TraceEntry("queries.same_component", build_same_component, _TF),
        TraceEntry("queries.component_size", build_component_size, _TF),
        TraceEntry("queries.count_components", build_count_components, _TF),
        TraceEntry("queries.component_histogram",
                   build_component_histogram, _TF),
        TraceEntry("queries.spanning_forest_stats", build_forest_stats,
                   _TF),
    ]


@register_trace_spec("fleet")
def _fleet_specs():
    """The fleet's pipelined query phase (DESIGN.md §15): the
    cross-tenant BATCHED kernels ``repro.fleet.engine`` dispatches —
    one stacked program answering a query kind for a whole same-|V|
    tenant group. The mutation phase is deliberately absent here: the
    fleet reuses ``ConnectivityService._run_mutations`` verbatim, so
    the ``service.tick.*`` entries above already pin it. The 4-tenant
    stack mirrors the engine's (kind, |V|) grouping; the batch rows
    are pow2-padded (``padded=True``) exactly as ``_dispatch_batched``
    stages them, and a host sync creeping into the stacked vmap would
    surface in the ``transfer`` pass against these entries."""
    import jax
    import jax.numpy as jnp

    from repro.fleet.engine import _batched_query_jit

    n_tenants = 4

    def build_batched_same_component(v, e):
        qb = max(e // 16, 8)

        def fn(labels, batch):
            return _batched_query_jit(labels, batch,
                                      kind="same_component")
        return (fn,
                (jax.ShapeDtypeStruct((n_tenants, v), jnp.int32),
                 jax.ShapeDtypeStruct((n_tenants, qb, 2), jnp.int32)),
                [VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1), padded=True)])

    def build_batched_component_size(v, e):
        qb = max(e // 16, 8)

        def fn(labels, batch):
            return _batched_query_jit(labels, batch,
                                      kind="component_size")
        return (fn,
                (jax.ShapeDtypeStruct((n_tenants, v), jnp.int32),
                 jax.ShapeDtypeStruct((n_tenants, qb), jnp.int32)),
                [VarInfo(range=(0, v - 1)),
                 VarInfo(range=(0, v - 1), padded=True)])

    return [TraceEntry("fleet.query.same_component",
                       build_batched_same_component, _TF),
            TraceEntry("fleet.query.component_size",
                       build_batched_component_size, _TF)]


def all_entries() -> list:
    """Every registered ``TraceEntry`` (backends + service + queries +
    fleet), importing the spec-bearing modules for their side
    effects."""
    import repro.api.backends  # noqa: F401  — registers backend specs
    return trace_entries()
