"""Seeded-bug fixtures — the analyzer's own regression suite.

Each fixture is a ``TraceEntry`` reproducing a *real* bug class from
this repo's history (or its known-good twin), kept OUT of the
production registry so the tree stays clean. ``--selftest`` (and
``tests/test_analysis.py``) trace them through the full pass stack and
assert the analyzer still catches every one — the checker is only a
gate while it demonstrably flags the bugs it was built from:

* ``fixture.int32_edge_key`` — the PR-4 incremental-engine bug: edges
  keyed as ``min*V + max`` in int32. Exact until ``|V| ~ 46341``,
  silent wraparound after; must flag at the scale bucket and stay
  quiet at the small bucket (the "CI-sized shapes miss it" story);
* ``fixture.int32_edge_key_fixed`` — the shipped fix (lexicographic
  two-key sort, no packed product); must be clean at every bucket;
* ``fixture.host_sync`` — a Python branch on a traced value inside a
  contracted-transfer-free program (the classic ``if count > 0:``);
  staging fails, which IS the finding;
* ``fixture.host_callback`` — a ``jax.pure_callback`` smuggled onto a
  tick path: one host round trip per invocation;
* ``fixture.unmasked_padded_sum`` — billing over a padded edge array
  with no dominating mask (the §8 violation WorkCounters tests chase
  at runtime); its twin ``fixture.masked_padded_sum`` applies the
  prefix mask and must be clean;
* ``fixture.retrace_nonpow2`` — a non-pow2 input shape plus a leaked
  weak-typed Python scalar on a bucketed entry (one compiled program
  per distinct size in serving);
* ``fixture.stale_forest_idx`` — the PR-9 compaction hazard: summing
  edge spans gathered through ``parent_eidx`` log-row pointers that
  were NOT remapped through ``EdgeLog.compact()``'s permutation. Stale
  pointers land past the packed true count, billing retired/padding
  rows; its twin ``fixture.stale_forest_idx_fixed`` remaps through the
  permutation and masks by the post-compaction true count (the
  ``_remap_eidx_jit`` discipline) and must be clean.
"""
from __future__ import annotations

from repro.api.registry import TraceEntry, VarInfo

_TF = frozenset({"transfer_free", "bucketed"})


def _sds(shape, dtype="int32"):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, getattr(jnp, dtype))


def _build_edge_key(v, e):
    import jax.numpy as jnp

    def fn(edges):
        u, w = edges[:, 0], edges[:, 1]
        lo = jnp.minimum(u, w)
        hi = jnp.maximum(u, w)
        key = lo * v + hi              # pre-PR-4 packed key: wraps at scale
        return jnp.sort(key)
    return fn, (_sds((e, 2)),), [VarInfo(range=(0, v - 1))]


def _build_edge_key_fixed(v, e):
    import jax.numpy as jnp
    from jax import lax

    def fn(edges):
        u, w = edges[:, 0], edges[:, 1]
        lo = jnp.minimum(u, w)
        hi = jnp.maximum(u, w)
        # the fix: two-key lexicographic sort, nothing packed
        lo_s, hi_s = lax.sort((lo, hi), num_keys=2)
        return lo_s, hi_s
    return fn, (_sds((e, 2)),), [VarInfo(range=(0, v - 1))]


def _build_host_sync(v, e):
    import jax.numpy as jnp

    def fn(edges):
        total = jnp.sum(edges >= 0)
        if total > 0:                  # Python branch on a traced value
            return total
        return jnp.zeros((), jnp.int32)
    return fn, (_sds((e, 2)),), [VarInfo(range=(0, v - 1))]


def _build_host_callback(v, e):
    import jax
    import jax.numpy as jnp

    def fn(pi):
        # a host hop dressed up as a pure function
        return jax.pure_callback(
            lambda x: x, jax.ShapeDtypeStruct(pi.shape, jnp.int32), pi)
    return fn, (_sds((v,)),), [VarInfo(range=(0, v - 1))]


def _build_unmasked_sum(v, e):
    import jax.numpy as jnp

    def fn(edges, true_edges):
        hops = edges[:, 0] - edges[:, 1]
        return jnp.sum(jnp.abs(hops))  # bills the padding rows
    return (fn, (_sds((e, 2)), _sds(())),
            [VarInfo(range=(0, v - 1), padded=True),
             VarInfo(range=(0, e), mask=True)])


def _build_masked_sum(v, e):
    import jax.numpy as jnp

    def fn(edges, true_edges):
        hops = jnp.abs(edges[:, 0] - edges[:, 1])
        alive = jnp.arange(e, dtype=jnp.int32) < true_edges
        return jnp.sum(jnp.where(alive, hops, 0))   # the §8 discipline
    return (fn, (_sds((e, 2)), _sds(())),
            [VarInfo(range=(0, v - 1), padded=True),
             VarInfo(range=(0, e), mask=True)])


def _build_retrace_nonpow2(v, e):
    import jax.numpy as jnp

    def fn(pi, shift):
        return pi + shift
    # non-pow2 leading dim + a raw Python int (leaks a weak-typed aval)
    return (fn, (_sds((e - 3,)), 7),
            [VarInfo(range=(0, v - 1)), VarInfo()])


def _build_stale_forest_idx(v, e):
    import jax.numpy as jnp

    def fn(edges, parent_eidx, true_edges):
        # pre-compaction pointers into a freshly packed log: rows past
        # the true count are retired padding, but nothing masks them
        safe = jnp.maximum(parent_eidx, 0)
        rows = edges[safe]
        span = jnp.abs(rows[:, 0] - rows[:, 1])
        return jnp.sum(span)           # bills retired rows
    return (fn, (_sds((e, 2)), _sds((v,)), _sds(())),
            [VarInfo(range=(0, v - 1), padded=True),
             VarInfo(range=(-1, e - 1)),
             VarInfo(range=(0, e), mask=True)])


def _build_stale_forest_idx_fixed(v, e):
    import jax.numpy as jnp

    def fn(edges, parent_eidx, perm, true_edges):
        # the fix: remap through the compaction permutation, then mask
        # by the post-compaction true count (the _remap_eidx_jit rule)
        safe = jnp.maximum(parent_eidx, 0)
        idx = jnp.where(parent_eidx >= 0, perm[safe], -1)
        rows = edges[jnp.maximum(idx, 0)]
        span = jnp.abs(rows[:, 0] - rows[:, 1])
        live = (idx >= 0) & (idx < true_edges)
        return jnp.sum(jnp.where(live, span, 0))
    return (fn, (_sds((e, 2)), _sds((v,)), _sds((e,)), _sds(())),
            [VarInfo(range=(0, v - 1), padded=True),
             VarInfo(range=(-1, e - 1)),
             VarInfo(range=(-1, e - 1)),
             VarInfo(range=(0, e), mask=True)])


def fixture_entries() -> list:
    return [
        TraceEntry("fixture.int32_edge_key", _build_edge_key, _TF),
        TraceEntry("fixture.int32_edge_key_fixed", _build_edge_key_fixed,
                   _TF),
        TraceEntry("fixture.host_sync", _build_host_sync, _TF),
        TraceEntry("fixture.host_callback", _build_host_callback, _TF),
        TraceEntry("fixture.unmasked_padded_sum", _build_unmasked_sum,
                   _TF),
        TraceEntry("fixture.masked_padded_sum", _build_masked_sum, _TF),
        TraceEntry("fixture.retrace_nonpow2", _build_retrace_nonpow2,
                   _TF),
        TraceEntry("fixture.stale_forest_idx", _build_stale_forest_idx,
                   _TF),
        TraceEntry("fixture.stale_forest_idx_fixed",
                   _build_stale_forest_idx_fixed, _TF),
    ]


# entry -> (pass_id, finding code, bucket it must fire at) — "scale"
# means the small bucket must stay QUIET (that asymmetry is the point)
EXPECTED = {
    "fixture.int32_edge_key": ("int32", "mul-overflow", "scale"),
    "fixture.host_sync": ("transfer", "trace-host-sync", "any"),
    "fixture.host_callback": ("transfer", "callback-pure_callback", "any"),
    "fixture.unmasked_padded_sum": ("padmask", "unmasked-padded-sum",
                                    "any"),
    "fixture.retrace_nonpow2": ("retrace", "non-pow2-shape-arg0", "any"),
    "fixture.stale_forest_idx": ("padmask", "unmasked-padded-sum", "any"),
}

# entries that must produce ZERO findings (the fixed twins)
CLEAN = {"fixture.int32_edge_key_fixed", "fixture.masked_padded_sum",
         "fixture.stale_forest_idx_fixed"}
