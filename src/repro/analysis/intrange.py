"""Pass ``int32`` — interval analysis for index arithmetic.

The whole stack runs with x64 disabled, so every id, index, and edge
key is int32. That makes index *arithmetic* the one place where a
perfectly clean-looking program silently corrupts at scale: the PR-4
incremental engine keyed undirected edges as ``min*V + max``, which is
exact math until ``|V|`` crosses ~46341 (2**31 / |V| < |V|) and then
wraps negative — CI-sized graphs never see it, the paper's scale
graphs always do. This pass re-derives that class of bug statically:

* every traced input gets an inclusive value interval from its
  ``VarInfo`` (vertex ids in [0, |V|-1], counts in [0, |E|], unknown =
  TOP) and intervals are propagated through the jaxpr with exact
  Python-int arithmetic (no wrapping);
* an ``add`` / ``sub`` / ``mul`` / ``convert_element_type`` whose
  *exact* result interval escapes [-2**31, 2**31-1] while its output
  dtype is a 32-bit-or-narrower int is an error — the runtime value
  has wrapped;
* TOP never flags, and loop-carried values that fail to reach a join
  fixed point are widened to TOP — unbounded work counters
  accumulating across rounds can not produce phantom findings. The
  cost is known: a genuine overflow *proved only by loop iteration
  count* is out of scope (documented in DESIGN.md §11).

Entries are traced at two buckets; the overflow only fires at the
scale bucket (V=2**20), which is exactly the point: the checker sees
what small-shape CI cannot.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_utils import AbstractInterpreter, eqn_site

PASS_ID = "int32"

INT32_MIN, INT32_MAX = -(2 ** 31), 2 ** 31 - 1
TOP = None                                   # unknown interval
_FLAG_PRIMS = {"add", "sub", "mul", "convert_element_type"}
_CONST_SCAN_MAX = 1 << 22                    # min/max scan cap for consts

Interval = Optional[tuple]                   # (lo, hi) exact Python ints


def _is_small_int(dtype) -> bool:
    try:
        return (np.issubdtype(dtype, np.integer)
                and np.dtype(dtype).itemsize <= 4)
    except TypeError:
        return False


def _corners(a: Interval, b: Interval, op) -> Interval:
    if a is TOP or b is TOP:
        return TOP
    vals = [op(x, y) for x in a for y in b]
    return (min(vals), max(vals))


class _IntRange(AbstractInterpreter):
    def __init__(self, traced):
        self.traced = traced
        self.findings: list[Finding] = []

    # -- lattice -----------------------------------------------------------

    def top(self):
        return TOP

    def join(self, a: Interval, b: Interval) -> Interval:
        if a is TOP or b is TOP:
            return TOP
        return (min(a[0], b[0]), max(a[1], b[1]))

    def from_literal(self, val, aval) -> Interval:
        try:
            arr = np.asarray(val)
            if arr.dtype == np.bool_:
                return (0, 1)
            if np.issubdtype(arr.dtype, np.integer) and arr.size >= 1:
                return (int(arr.min()), int(arr.max()))
        except Exception:  # noqa: BLE001
            pass
        return TOP

    def const_value(self, const) -> Interval:
        try:
            arr = np.asarray(const)
            if (np.issubdtype(arr.dtype, np.integer)
                    and 1 <= arr.size <= _CONST_SCAN_MAX):
                return (int(arr.min()), int(arr.max()))
            if arr.dtype == np.bool_:
                return (0, 1)
        except Exception:  # noqa: BLE001
            pass
        return TOP

    # -- transfer ----------------------------------------------------------

    def rule(self, eqn, vals) -> list:
        p = eqn.primitive.name
        out: Interval = TOP

        if p == "add":
            out = _corners(vals[0], vals[1], lambda x, y: x + y)
        elif p == "sub":
            out = _corners(vals[0], vals[1], lambda x, y: x - y)
        elif p == "mul":
            out = _corners(vals[0], vals[1], lambda x, y: x * y)
        elif p == "convert_element_type":
            out = vals[0]
        elif p in ("max", "min"):
            if vals[0] is not TOP and vals[1] is not TOP:
                pick = max if p == "max" else min
                out = (pick(vals[0][0], vals[1][0]),
                       pick(vals[0][1], vals[1][1]))
        elif p == "clamp" and vals[0] is not TOP and vals[2] is not TOP:
            out = (vals[0][0], vals[2][1])     # bounded by [lo.lo, hi.hi]
        elif p == "neg" and vals[0] is not TOP:
            out = (-vals[0][1], -vals[0][0])
        elif p == "abs" and vals[0] is not TOP:
            lo, hi = vals[0]
            out = (0 if lo <= 0 <= hi else min(abs(lo), abs(hi)),
                   max(abs(lo), abs(hi)))
        elif p == "iota":
            shape = eqn.params.get("shape") or (0,)
            dim = eqn.params.get("dimension", 0)
            out = (0, max(int(shape[dim]) - 1, 0))
        elif p in ("argmax", "argmin"):
            size = getattr(eqn.invars[0].aval, "size", 0)
            out = (0, max(int(size) - 1, 0))
        elif p in ("reshape", "broadcast_in_dim", "squeeze", "transpose",
                   "slice", "dynamic_slice", "rev", "copy", "stop_gradient",
                   "expand_dims", "reduce_max", "reduce_min",
                   "reduce_or", "reduce_and", "cumsum", "gather"):
            # shape ops and order-preserving reductions keep the operand
            # interval; gather's indices can't widen the gathered values.
            # (cumsum of a bounded array CAN exceed the element bound —
            # but only via the length factor, which we fold in exactly.)
            if p == "cumsum" and vals[0] is not TOP:
                n = max(int(getattr(eqn.invars[0].aval, "size", 1)), 1)
                lo, hi = vals[0]
                out = (min(lo, lo * n), max(hi, hi * n))
            else:
                out = vals[0]
        elif p in ("concatenate", "pad", "select_n", "dynamic_update_slice"):
            ops = vals[1:] if p == "select_n" else vals   # drop predicate
            ops = [v for v in ops] or [TOP]
            out = ops[0]
            for v in ops[1:]:
                out = self.join(out, v)
        elif p in ("scatter", "scatter_min", "scatter_max"):
            out = self.join(vals[0], vals[-1])   # operand ∪ updates
        elif p == "sort":
            n_ops = len(eqn.outvars)
            return [vals[i] if i < len(vals) else TOP
                    for i in range(n_ops)]
        elif p in ("eq", "ne", "lt", "le", "gt", "ge", "and", "or", "not",
                   "xor", "is_finite", "reduce_sum") and \
                eqn.outvars and getattr(eqn.outvars[0].aval, "dtype",
                                        None) == np.bool_:
            out = (0, 1)
        elif p == "rem" and vals[1] is not TOP:
            m = max(abs(vals[1][0]), abs(vals[1][1]))
            if m > 0:
                out = (-(m - 1), m - 1)
        elif p == "shift_left":
            out = _corners(vals[0], vals[1],
                           lambda x, y: x * (2 ** max(min(y, 64), 0)))

        # the flag: exact interval escaped int32 while dtype stayed int32
        if p in _FLAG_PRIMS and out is not TOP and eqn.outvars:
            aval = eqn.outvars[0].aval
            if (_is_small_int(getattr(aval, "dtype", None))
                    and (out[0] < INT32_MIN or out[1] > INT32_MAX)):
                file, line = eqn_site(eqn)
                v, e = self.traced.bucket
                self.findings.append(Finding(
                    PASS_ID, self.traced.name, "error",
                    f"{'convert' if p == 'convert_element_type' else p}"
                    "-overflow",
                    f"int32 `{p}` with exact value interval "
                    f"[{out[0]}, {out[1]}] at bucket (V={v}, E={e}) — "
                    "wraps past 2**31-1 on device (the min*V+max edge-key "
                    "class of bug; use segment ids or a (min,max) pair "
                    "instead of a packed product key)",
                    file, line))
                out = TOP          # wrapped value is unknowable downstream

        return [out for _ in eqn.outvars]


def run(traced: list) -> list[Finding]:
    findings: list[Finding] = []
    for t in traced:
        if t.jaxpr is None:
            continue
        interp = _IntRange(t)
        seeds = []
        for i, var in enumerate(t.jaxpr.jaxpr.invars):
            info = t.arg_info[i] if i < len(t.arg_info) else None
            rng = getattr(info, "range", None) if info else None
            seeds.append(tuple(int(x) for x in rng) if rng else TOP)
        interp.run(t.jaxpr, seeds)
        findings.extend(interp.findings)
    return findings
