"""Pass ``pallas-ast`` — source-level companion lint.

The jaxpr passes treat ``pallas_call`` bodies as opaque (Mosaic
lowering, not XLA, owns their semantics), so kernel hygiene is checked
where it lives — in the source:

* **static grid bounds** — every ``pl.pallas_call(...)`` must pass an
  explicit ``grid=`` / ``grid_spec=``; an implicit whole-array launch
  compiles, then silently serializes (error);
* **ref/ops parity** — every kernel package under ``repro.kernels``
  ships the triple ``<name>.py`` (the pallas kernel) + ``ops.py``
  (jit'd public wrapper) + ``ref.py`` (pure oracle, ``ref_*``
  functions). The conformance tests diff kernel vs oracle; a package
  missing either half has nothing holding it to its semantics (error);
* **no 64-bit dtypes in kernels** — the stack runs x64-disabled;
  a ``jnp.int64``/``float64`` in a kernel file either silently
  downcasts or diverges from the int32 range analysis (error);
* **no facade bypass** — engine entry points (``solve_*``,
  ``IncrementalCC``, ``DynamicCC``) are imported only by
  ``repro.core``/``repro.api``/``repro.analysis``; anything else in
  ``src/`` importing them dodges the plan/registry layer the Solver
  contracts are enforced through (error).

Pure ``ast`` — no imports of the linted modules, so a broken module
still gets linted. Findings anchor to real lines, so the standard
``# analysis: ok[pallas-ast]`` pragma applies.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.findings import Finding

PASS_ID = "pallas-ast"

_X64_NAMES = {"int64", "uint64", "float64"}
_ENGINE_ENTRIES = {
    "solve_static", "solve_pallas", "solve_hostloop", "solve_batched",
    "solve_distributed", "build_distributed_cc",
    "IncrementalCC", "DynamicCC",
}
_ENGINE_MODULES = ("repro.core.cc", "repro.core.batch",
                   "repro.core.incremental", "repro.core.distributed",
                   "repro.core")
# packages allowed to touch engine entries directly
_ENGINE_CLIENTS = ("src/repro/core/", "src/repro/api/",
                   "src/repro/analysis/")


def _rel(path: Path, root: Path) -> str:
    return path.relative_to(root).as_posix()


def _parse(path: Path):
    try:
        return ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return None


def _lint_pallas_file(path: Path, rel: str) -> list[Finding]:
    tree = _parse(path)
    if tree is None:
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fname = (node.func.attr if isinstance(node.func, ast.Attribute)
                     else getattr(node.func, "id", ""))
            if fname == "pallas_call":
                kws = {kw.arg for kw in node.keywords}
                if not ({"grid", "grid_spec"} & kws):
                    out.append(Finding(
                        PASS_ID, rel, "error", "pallas-no-static-grid",
                        "pl.pallas_call without an explicit grid= / "
                        "grid_spec= — the implicit whole-array launch "
                        "serializes; derive the grid from static tile "
                        "counts",
                        rel, node.lineno))
        if isinstance(node, ast.Attribute) and node.attr in _X64_NAMES:
            out.append(Finding(
                PASS_ID, rel, "error", f"kernel-{node.attr}",
                f"64-bit dtype `{node.attr}` in a kernel file — the "
                "stack is x64-disabled; this silently downcasts and "
                "escapes the int32 range analysis",
                rel, node.lineno))
    return out


def _lint_kernel_package(pkg: Path, root: Path) -> list[Finding]:
    out = []
    rel = _rel(pkg, root)
    ops, ref = pkg / "ops.py", pkg / "ref.py"
    for part, req in (("ops.py", ops), ("ref.py", ref)):
        tree = _parse(req) if req.exists() else None
        has_pub = tree is not None and any(
            isinstance(n, ast.FunctionDef) and not n.name.startswith("_")
            for n in tree.body)
        if not has_pub:
            out.append(Finding(
                PASS_ID, rel, "error",
                f"kernel-missing-{part.split('.')[0]}",
                f"kernel package has no public function in {part} — "
                "the kernel/oracle conformance contract (DESIGN.md §8) "
                "requires the ops+ref pair",
                f"{rel}/{part}", 1))
    return out


def _lint_facade_bypass(path: Path, rel: str) -> list[Finding]:
    if rel.startswith(_ENGINE_CLIENTS) or not rel.endswith(".py"):
        return []
    tree = _parse(path)
    if tree is None:
        return []
    out = []
    for node in ast.walk(tree):
        names: set = set()
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.startswith(_ENGINE_MODULES):
            names = {a.name for a in node.names} & _ENGINE_ENTRIES
        if names:
            out.append(Finding(
                PASS_ID, rel, "error", "facade-bypass",
                f"imports engine entry {sorted(names)} from "
                f"`{node.module}` outside repro.core/api — go through "
                "`repro.api` (Solver / BACKENDS) so plans, counters, "
                "and contracts apply",
                rel, node.lineno))
    return out


def run(src_root: Path) -> list[Finding]:
    """Lint ``src/repro`` under ``src_root`` (the repo root)."""
    repro = src_root / "src" / "repro"
    findings: list[Finding] = []
    kernels = repro / "kernels"
    if kernels.is_dir():
        for pkg in sorted(p for p in kernels.iterdir() if p.is_dir()):
            if not any(pkg.glob("*.py")):
                continue
            findings.extend(_lint_kernel_package(pkg, src_root))
    for path in sorted(repro.rglob("*.py")):
        rel = _rel(path, src_root)
        text = path.read_text()
        if "pallas_call" in text and "/analysis/" not in rel:
            findings.extend(_lint_pallas_file(path, rel))
        findings.extend(_lint_facade_bypass(path, rel))
    return findings
