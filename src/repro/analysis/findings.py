"""Findings — the structured output of every checker pass.

A ``Finding`` names the pass that produced it, the traced entry (or
file, for the AST lint) it anchors to, a severity, a human message,
and — when jaxpr source provenance resolved — a ``file:line`` anchor
into the repo. Findings carry a stable ``key`` (pass, entry, site,
code) used for two things:

  * **suppression pragmas** — a ``# analysis: ok[<pass-id>]`` comment
    on (or immediately above) the anchored source line acknowledges a
    finding in place, the same way ``# noqa`` works;
  * **baseline gating** — ``python -m repro.analysis`` compares the
    current finding keys against a committed baseline
    (``analysis_baseline.json``) and exits nonzero only on NEW keys,
    so a pre-existing acknowledged violation cannot block CI while any
    regression does. Keys deliberately exclude line numbers (an
    unrelated edit must not invalidate the baseline) and
    bucket-dependent numbers in messages.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Iterable, Optional

PASS_IDS = ("transfer", "int32", "retrace", "padmask", "pallas-ast")
SEVERITIES = ("error", "warning")

_PRAGMA = re.compile(r"#\s*analysis:\s*ok\[([a-z0-9, -]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_id: str                  # one of PASS_IDS
    entry: str                    # traced entry name / linted file
    severity: str                 # "error" | "warning"
    code: str                     # short machine code, e.g. "mul-overflow"
    message: str                  # human account (may include numbers)
    file: Optional[str] = None    # repo-relative source anchor
    line: Optional[int] = None

    @property
    def key(self) -> str:
        """Stable identity for baselines: excludes line numbers and
        message text (both drift under unrelated edits)."""
        return f"{self.pass_id}:{self.entry}:{self.file or '-'}:{self.code}"

    def render(self) -> str:
        site = f"{self.file}:{self.line}" if self.file else "-"
        return (f"{self.severity}[{self.pass_id}] {self.entry} @ {site}: "
                f"{self.message}")


def _line_has_pragma(path: Path, line: int, pass_id: str) -> bool:
    try:
        lines = path.read_text().splitlines()
    except OSError:
        return False
    for n in (line, line - 1):                 # the line or the one above
        if not 1 <= n <= len(lines):
            continue
        text = lines[n - 1]
        if n == line - 1 and not text.lstrip().startswith("#"):
            continue           # line-above form must be a pure comment
        m = _PRAGMA.search(text)
        if m and pass_id in [p.strip() for p in m.group(1).split(",")]:
            return True
    return False


def apply_suppressions(findings: Iterable[Finding], root: Path
                       ) -> tuple[list[Finding], list[Finding]]:
    """Split findings into (kept, suppressed) by source pragmas."""
    kept, suppressed = [], []
    for f in findings:
        if f.file and f.line and _line_has_pragma(
                root / f.file, f.line, f.pass_id):
            suppressed.append(f)
        else:
            kept.append(f)
    return kept, suppressed


def dedupe(findings: Iterable[Finding]) -> list[Finding]:
    """One finding per key (the multi-bucket sweep re-derives the same
    site at every shape bucket; report it once)."""
    seen, out = set(), []
    for f in findings:
        if f.key not in seen:
            seen.add(f.key)
            out.append(f)
    return out


@dataclasses.dataclass
class Report:
    findings: list = dataclasses.field(default_factory=list)
    suppressed: list = dataclasses.field(default_factory=list)
    entries_checked: list = dataclasses.field(default_factory=list)
    passes_run: list = dataclasses.field(default_factory=list)

    def new_vs(self, baseline_keys: set[str]) -> list[Finding]:
        return [f for f in self.findings if f.key not in baseline_keys]

    def to_json(self) -> dict:
        return {
            "passes": list(self.passes_run),
            "entries": list(self.entries_checked),
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "suppressed": [dataclasses.asdict(f) for f in self.suppressed],
        }


def load_baseline(path: Path) -> set[str]:
    """Committed baseline = the set of acknowledged finding keys."""
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("keys", []))


def write_baseline(path: Path, report: Report) -> None:
    path.write_text(json.dumps(
        {"keys": sorted({f.key for f in report.findings})}, indent=2)
        + "\n")
