"""Pass ``padmask`` — every billed sum over a padded array is masked.

The §8 discipline pads every edge buffer to its pow2 bucket with (0,0)
self-loops and carries the true count alongside. Self-loop padding is
*algebraically invisible* to the connectivity math (a self-loop never
merges anything) but NOT to additive statistics: an unmasked
``jnp.sum`` over a padded hops/edges/per-round array bills the padding
into WorkCounters — precisely the corruption the true-work billing
tests exist to catch, found here at trace time instead.

Taint analysis over the jaxpr:

* inputs marked ``padded=True`` in their ``VarInfo`` seed the
  ``padded`` taint; inputs marked ``mask=True`` (true counts, alive
  masks) seed the ``mask`` tag;
* taints propagate through shape ops and arithmetic (union of operand
  tags); comparisons against a mask-tagged value (``iota < true_count``)
  produce new masks;
* **sanitizers**: ``select_n`` whose predicate is mask-tagged, and
  ``and``/``mul`` with a mask-tagged operand, strip the ``padded``
  taint — that IS the masking discipline, in any of its three idioms
  (``jnp.where(alive, x, 0)``, ``x * mask``, ``flags & alive``);
* ``gather`` keeps only the *operand's* taint (indexing a clean table
  with padded indices reads in-range garbage rows — a semantic
  question for the min/consistency reductions, which are safe over
  (0,0) self-loops — it does not bill);
* the finding: ``reduce_sum`` over a still-padded operand. Order- and
  idempotent reductions (min/max/and/or) over self-loop padding are
  correct by construction and never flagged.
"""
from __future__ import annotations

from typing import FrozenSet

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_utils import AbstractInterpreter, eqn_site

PASS_ID = "padmask"

CLEAN: FrozenSet[str] = frozenset()
PADDED = frozenset({"padded"})
MASK = frozenset({"mask"})

_CMP = {"eq", "ne", "lt", "le", "gt", "ge"}
_SANITIZING_MUL = {"and", "mul"}


class _PadTaint(AbstractInterpreter):
    def __init__(self, traced):
        self.traced = traced
        self.findings: list[Finding] = []

    # -- lattice (frozensets of tags; join = union) ------------------------

    def top(self):
        return CLEAN          # unknown provenance carries no taint

    def join(self, a, b):
        return a | b

    def from_literal(self, val, aval):
        return CLEAN

    def const_value(self, const):
        return CLEAN

    # -- transfer ----------------------------------------------------------

    def rule(self, eqn, vals) -> list:
        p = eqn.primitive.name
        union = CLEAN
        for v in vals:
            union = union | v

        if p == "select_n" and vals and "mask" in vals[0]:
            # where(alive, x, fill): the canonical sanitizer
            out = (union - vals[0]) - PADDED | (vals[0] & MASK)
        elif p in _SANITIZING_MUL and any("mask" in v for v in vals):
            out = union - PADDED
            if p == "and":
                out = out | MASK          # alive & flags is itself a mask
        elif p in _CMP and any("mask" in v for v in vals):
            out = MASK                    # iota < true_count → a new mask
        elif p == "gather":
            out = vals[0] if vals else CLEAN   # operand taint only
        elif p in ("scatter", "scatter_add", "scatter_min", "scatter_max"):
            out = (vals[0] | vals[-1]) if vals else CLEAN
        else:
            out = union

        if p == "reduce_sum" and vals and "padded" in vals[0]:
            file, line = eqn_site(eqn)
            self.findings.append(Finding(
                PASS_ID, self.traced.name, "error", "unmasked-padded-sum",
                "`reduce_sum` over a padded array with no dominating "
                "alive/prefix mask — self-loop padding rows are billed "
                "into the sum (WorkCounters corruption); mask with "
                "`jnp.where(alive, x, 0)` or multiply by the prefix mask "
                "before summing",
                file, line))
            out = CLEAN        # one report per sink, not per consumer

        return [out for _ in eqn.outvars]


def run(traced: list) -> list[Finding]:
    findings: list[Finding] = []
    for t in traced:
        if t.jaxpr is None:
            continue
        interp = _PadTaint(t)
        seeds = []
        for i, _var in enumerate(t.jaxpr.jaxpr.invars):
            info = t.arg_info[i] if i < len(t.arg_info) else None
            tags = CLEAN
            if info is not None and getattr(info, "padded", False):
                tags = tags | PADDED
            if info is not None and getattr(info, "mask", False):
                tags = tags | MASK
            seeds.append(tags)
        interp.run(t.jaxpr, seeds)
        findings.extend(interp.findings)
    return findings
