"""Pass ``retrace`` — shape-bucket hygiene (the retrace-storm guard).

``repro.core.batch`` owns the rule: every device program is compiled
at pow2 shape buckets, so a stream of arbitrary-sized inputs hits a
bounded set of compiled programs. This pass lints the traced entries
against that rule:

* an entry contracted ``bucketed`` whose input avals carry a non-pow2
  leading dimension compiles one program per distinct size — the
  retrace storm the bucket rule exists to prevent (error);
* a weak-typed input aval (a Python scalar that leaked into the traced
  signature without ``jnp.asarray``/explicit dtype) splits the
  compilation cache: weak and strong avals hash differently, so the
  same shapes compile twice (warning);
* a large array constant captured by closure is baked into the
  executable — re-traced and re-shipped per compilation. The one
  sanctioned pattern is the iota table (``jnp.arange(num_nodes)``
  closes over every variant and XLA folds it); anything else that is
  big and non-iota gets flagged (warning).
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_utils import TracedEntry

PASS_ID = "retrace"

_CONST_FLAG_BYTES = 1 << 20          # 1 MiB of captured constant


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def _is_iota_like(arr: np.ndarray) -> bool:
    if arr.ndim != 1 or not np.issubdtype(arr.dtype, np.integer):
        return False
    n = arr.shape[0]
    if n == 0:
        return True
    # cheap exact check: endpoints + strict monotone step of 1
    return (int(arr[0]) == 0 and int(arr[-1]) == n - 1
            and bool(np.all(np.diff(arr[:: max(n // 64, 1)]) > 0)))


def run(traced: list[TracedEntry]) -> list[Finding]:
    findings: list[Finding] = []
    for t in traced:
        bucketed = "bucketed" in t.entry.contracts
        if t.jaxpr is None:
            continue
        for i, var in enumerate(t.jaxpr.jaxpr.invars):
            aval = var.aval
            shape = tuple(getattr(aval, "shape", ()))
            if bucketed and shape and not _is_pow2(int(shape[0])):
                findings.append(Finding(
                    PASS_ID, t.name, "error", f"non-pow2-shape-arg{i}",
                    f"input {i} has leading dim {shape[0]} (shape "
                    f"{shape}) on a bucketed entry — one compiled "
                    "program per distinct size; round up with "
                    "`next_pow2` / `pad_pow2` before dispatch"))
            if getattr(aval, "weak_type", False):
                findings.append(Finding(
                    PASS_ID, t.name, "warning", f"weak-typed-arg{i}",
                    f"input {i} is weak-typed ({aval}) — a Python "
                    "scalar leaked into the traced signature; weak and "
                    "strong avals split the compilation cache. Pass "
                    "`jnp.asarray(x, jnp.int32)` instead"))
        for j, const in enumerate(t.jaxpr.consts):
            try:
                arr = np.asarray(const)
            except Exception:  # noqa: BLE001
                continue
            if arr.nbytes <= _CONST_FLAG_BYTES or _is_iota_like(arr):
                continue
            findings.append(Finding(
                PASS_ID, t.name, "warning", "large-captured-const",
                f"captured constant #{j} ({arr.dtype}{list(arr.shape)}, "
                f"{arr.nbytes >> 10} KiB) is baked into every compiled "
                "variant; thread it through as an argument (the iota "
                "table is the one exempt pattern)"))
    return findings
