"""repro.analysis — static invariant checking over traced backends.

Every registered backend (plus the service tick and the query kernels)
is closed to a jaxpr at symbolic shape buckets and held to the repo's
contracts *at trace time*: transfer-freedom on tick paths, int32 range
safety at scale-tier shapes, pow2 bucket hygiene, and the §8
padding-mask discipline — plus an AST-level lint for the Pallas
kernels and facade boundaries. DESIGN.md §11 documents the pass
architecture; ``python -m repro.analysis`` is the CI gate.
"""
from repro.analysis.findings import (PASS_IDS, Finding, Report,
                                     load_baseline, write_baseline)
from repro.analysis.runner import BUCKETS, analyze, selftest

__all__ = ["PASS_IDS", "Finding", "Report", "load_baseline",
           "write_baseline", "BUCKETS", "analyze", "selftest"]
