"""Jaxpr graph plumbing shared by every checker pass.

* ``trace(entry, bucket)`` closes a ``TraceEntry`` to a jaxpr at a
  symbolic shape bucket (``jax.make_jaxpr`` over ShapeDtypeStructs —
  nothing is allocated or executed). A trace that raises because the
  program tried to materialize a tracer on the host (``.item()``,
  ``np.asarray`` on a traced value, a Python branch on a traced bool)
  is itself a *transfer-freedom violation*, so the failure is captured
  as data (``TraceFailure``) rather than propagated.

* ``AbstractInterpreter`` is a tiny fixed-point abstract interpreter
  over jaxpr graphs: passes subclass it with a value lattice (``top`` /
  ``join`` / ``from_literal``) and per-primitive transfer rules, and it
  handles the structural recursion — ``pjit`` call bodies, ``scan`` /
  ``while`` loop bodies (iterated to a join fixed point, widening to
  TOP on non-convergence so loop-carried values never produce phantom
  findings), ``cond`` branches, and custom-derivative call wrappers.
  ``pallas_call`` bodies are deliberately opaque (outputs = TOP): the
  kernels have their own AST-level lint (``repro.analysis.astlint``)
  and their internals follow ref-kernel parity tests, not jaxpr rules.

* ``eqn_site(eqn)`` resolves an equation's source provenance to a
  repo-relative ``file:line`` anchor (first traceback frame under
  ``src/repro``), which findings and suppression pragmas hang off.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Optional

import jax
from jax import core as jax_core

REPO_SRC_MARKER = "repro"
_LOOP_FIXPOINT_ITERS = 4


@dataclasses.dataclass
class TracedEntry:
    entry: Any                     # the api.registry.TraceEntry
    bucket: tuple                  # (num_nodes, num_edges)
    jaxpr: Optional[Any]           # ClosedJaxpr on success
    arg_info: list                 # VarInfo per flat invar
    failure: Optional["TraceFailure"] = None

    @property
    def name(self) -> str:
        return self.entry.name


@dataclasses.dataclass
class TraceFailure:
    exc_type: str
    message: str


def trace(entry, bucket: tuple) -> TracedEntry:
    """Close ``entry`` to a jaxpr at ``bucket`` = (V, E)."""
    v, e = bucket
    fn, args, info = entry.build(v, e)
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as err:  # noqa: BLE001 — the failure IS the datum
        return TracedEntry(entry, bucket, None, info,
                           TraceFailure(type(err).__name__, str(err)))
    return TracedEntry(entry, bucket, closed, info)


# ---------------------------------------------------------------------------
# Source provenance
# ---------------------------------------------------------------------------

def eqn_site(eqn) -> tuple[Optional[str], Optional[int]]:
    """(repo-relative file, line) for an equation, via its traceback's
    innermost frame under ``src/repro`` (library internals and jax
    frames are skipped). Best-effort: (None, None) when provenance is
    unavailable (e.g. synthesized equations)."""
    try:
        from jax._src import source_info_util
        frames = list(source_info_util.user_frames(eqn.source_info))
        candidates = frames or [
            source_info_util.raw_frame_to_frame(f)
            for f in (eqn.source_info.traceback.frames
                      if eqn.source_info.traceback else [])]
    except Exception:  # noqa: BLE001 — provenance is best-effort
        return None, None
    for frame in candidates:
        name = getattr(frame, "file_name", "").replace("\\", "/")
        idx = name.rfind("/repro/")
        if idx >= 0:
            return ("src" + name[idx:],
                    int(getattr(frame, "start_line", 0)) or None)
    return None, None


def subjaxpr_params(eqn) -> list:
    """Every ClosedJaxpr/Jaxpr hiding in an equation's params."""
    out = []
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else [val]
        for v in vals:
            if isinstance(v, (jax_core.ClosedJaxpr, jax_core.Jaxpr)):
                out.append(v)
    return out


def _as_closed(j):
    if isinstance(j, jax_core.ClosedJaxpr):
        return j.jaxpr, list(j.consts)
    return j, []


# ---------------------------------------------------------------------------
# The abstract interpreter
# ---------------------------------------------------------------------------

class AbstractInterpreter:
    """Fixed-point abstract interpretation over a closed jaxpr.

    Subclasses define the value lattice — ``top()``, ``join(a, b)``,
    ``from_literal(val, aval)``, ``const_value(const)`` — and
    ``rule(eqn, in_vals) -> list[out_vals]`` for primitive transfer.
    ``visit(eqn, in_vals, out_vals)`` is the finding hook, called for
    every equation INCLUDING inside loop bodies (idempotent findings
    expected — callers dedupe by key).
    """

    def top(self):
        raise NotImplementedError

    def join(self, a, b):
        raise NotImplementedError

    def from_literal(self, val, aval):
        return self.top()

    def const_value(self, const):
        return self.top()

    def rule(self, eqn, in_vals) -> list:
        return [self.top() for _ in eqn.outvars]

    def visit(self, eqn, in_vals, out_vals) -> None:
        pass

    # -- driver ------------------------------------------------------------

    def run(self, closed_jaxpr, in_vals: list) -> list:
        jaxpr, consts = _as_closed(closed_jaxpr)
        env: dict = {}
        for var, const in zip(jaxpr.constvars, consts):
            env[var] = self.const_value(const)
        for var, val in zip(jaxpr.invars, in_vals):
            env[var] = val
        self._eval_eqns(jaxpr, env)
        return [self._read(env, v) for v in jaxpr.outvars]

    def _read(self, env, atom):
        if isinstance(atom, jax_core.Literal):
            return self.from_literal(atom.val, atom.aval)
        return env.get(atom, self.top())

    def _eval_eqns(self, jaxpr, env) -> None:
        for eqn in jaxpr.eqns:
            in_vals = [self._read(env, a) for a in eqn.invars]
            out_vals = self._dispatch(eqn, in_vals)
            self.visit(eqn, in_vals, out_vals)
            for var, val in zip(eqn.outvars, out_vals):
                if not isinstance(var, jax_core.DropVar):
                    env[var] = val

    # -- structural primitives ---------------------------------------------

    def _dispatch(self, eqn, in_vals) -> list:
        prim = eqn.primitive.name
        if prim in ("pjit", "closed_call", "core_call", "xla_call",
                    "remat", "checkpoint", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr"):
            subs = subjaxpr_params(eqn)
            if subs:
                body = subs[0]
                n = len(_as_closed(body)[0].invars)
                return self.run(body, (in_vals + [self.top()] * n)[:n])
            return [self.top() for _ in eqn.outvars]
        if prim == "cond":
            branches = eqn.params.get("branches", ())
            operands = in_vals[1:]            # drop the predicate index
            outs = None
            for br in branches:
                n = len(_as_closed(br)[0].invars)
                res = self.run(br, (operands + [self.top()] * n)[:n])
                outs = res if outs is None else [
                    self.join(a, b) for a, b in zip(outs, res)]
            return outs if outs is not None \
                else [self.top() for _ in eqn.outvars]
        if prim == "while":
            return self._while(eqn, in_vals)
        if prim == "scan":
            return self._scan(eqn, in_vals)
        if prim == "pallas_call":
            # kernels are audited by the AST lint, not jaxpr rules
            return [self.top() for _ in eqn.outvars]
        return self.rule(eqn, in_vals)

    def _while(self, eqn, in_vals) -> list:
        body = eqn.params["body_jaxpr"]
        cond = eqn.params["cond_jaxpr"]
        bn = eqn.params.get("body_nconsts", 0)
        cn = eqn.params.get("cond_nconsts", 0)
        body_consts = in_vals[cn:cn + bn]
        carry = list(in_vals[cn + bn:])
        for _ in range(_LOOP_FIXPOINT_ITERS):
            nxt = self.run(body, body_consts + carry)
            joined = [self.join(a, b) for a, b in zip(carry, nxt)]
            if joined == carry:
                break
            carry = joined
        else:
            carry = [self.top() for _ in carry]
            self.run(body, body_consts + carry)   # visit at the widened env
        self.run(cond, in_vals[:cn] + carry)
        return carry

    def _scan(self, eqn, in_vals) -> list:
        body = eqn.params["jaxpr"]
        nc = eqn.params.get("num_consts", 0)
        ncar = eqn.params.get("num_carry", 0)
        consts = in_vals[:nc]
        carry = list(in_vals[nc:nc + ncar])
        xs = in_vals[nc + ncar:]             # per-step slice ~ whole array
        ys = None
        for _ in range(_LOOP_FIXPOINT_ITERS):
            outs = self.run(body, consts + carry + xs)
            new_carry = [self.join(a, b)
                         for a, b in zip(carry, outs[:ncar])]
            ys = outs[ncar:] if ys is None else [
                self.join(a, b) for a, b in zip(ys, outs[ncar:])]
            if new_carry == carry:
                break
            carry = new_carry
        else:
            carry = [self.top() for _ in carry]
            outs = self.run(body, consts + carry + xs)
            ys = outs[ncar:]
        return carry + list(ys or [])


def walk_eqns(closed_jaxpr):
    """Yield every equation, recursing into all sub-jaxprs (loop
    bodies, branches, called jaxprs — including pallas kernels)."""
    jaxpr, _ = _as_closed(closed_jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in subjaxpr_params(eqn):
            yield from walk_eqns(sub)


def repo_root() -> Path:
    """The repository root (…/src/repro/analysis → three up)."""
    return Path(__file__).resolve().parents[3]
