"""The sweep driver: trace every entry, run every pass, gate on the
baseline.

Buckets: every entry is traced at a CI-sized bucket AND a scale-tier
bucket (|V|=2^20). Both are symbolic — ``jax.make_jaxpr`` over
``ShapeDtypeStruct``s allocates nothing — so scale-tier analysis costs
trace time, not memory. The int32 pass exists for exactly this split:
the ``min*V+max`` overflow class is invisible at CI shapes and
guaranteed at paper shapes.

Findings are deduped by key across buckets, filtered through source
suppression pragmas, and compared against the committed baseline
(``analysis_baseline.json``); only NEW keys gate.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

from repro.analysis import astlint, intrange, padmask, retrace, transfers
from repro.analysis.findings import (PASS_IDS, Report, apply_suppressions,
                                     dedupe)
from repro.analysis.jaxpr_utils import repo_root, trace

# (num_nodes, num_edges): the CI tier and the paper's scale tier
BUCKETS = {"small": (1024, 4096), "scale": (1 << 20, 1 << 22)}

_JAXPR_PASSES = (transfers, intrange, retrace, padmask)


def analyze(entries: Optional[list] = None, *,
            buckets: Optional[dict] = None,
            root: Optional[Path] = None,
            run_astlint: bool = True) -> Report:
    """Trace ``entries`` (default: every registered entry) at every
    bucket, run the pass stack, and return the gated ``Report``."""
    if entries is None:
        from repro.analysis.entries import all_entries
        entries = all_entries()
    buckets = dict(buckets or BUCKETS)
    root = root or repo_root()

    traced = [trace(e, b) for e in entries for b in buckets.values()]

    findings = []
    for pass_mod in _JAXPR_PASSES:
        findings.extend(pass_mod.run(traced))
    passes = [p.PASS_ID for p in _JAXPR_PASSES]
    if run_astlint:
        findings.extend(astlint.run(root))
        passes.append(astlint.PASS_ID)
    assert set(passes) <= set(PASS_IDS)

    kept, suppressed = apply_suppressions(dedupe(findings), root)
    kept.sort(key=lambda f: (f.severity != "error", f.pass_id, f.entry))
    return Report(findings=kept, suppressed=suppressed,
                  entries_checked=sorted({e.name for e in entries}),
                  passes_run=passes)


def selftest() -> list[str]:
    """Run the pass stack over the seeded-bug fixtures; return the list
    of failures (empty = the analyzer still catches every bug class it
    was built from)."""
    from repro.analysis import fixtures

    failures: list[str] = []
    fixture_by_name = {e.name: e for e in fixtures.fixture_entries()}

    for name, (pass_id, code, where) in fixtures.EXPECTED.items():
        entry = fixture_by_name[name]
        for bucket_name, bucket in BUCKETS.items():
            rep = analyze([entry], buckets={bucket_name: bucket},
                          run_astlint=False)
            hit = any(f.pass_id == pass_id and f.code == code
                      for f in rep.findings)
            must_hit = where == "any" or bucket_name == where
            if must_hit and not hit:
                failures.append(
                    f"{name}: expected {pass_id}/{code} at bucket "
                    f"{bucket_name}{bucket}, not flagged")
            if where == "scale" and bucket_name == "small" and hit:
                failures.append(
                    f"{name}: {pass_id}/{code} fired at the SMALL "
                    "bucket — the scale-only asymmetry is broken")

    for name in sorted(fixtures.CLEAN):
        rep = analyze([fixture_by_name[name]], run_astlint=False)
        if rep.findings:
            failures.append(
                f"{name}: clean twin produced findings: "
                + "; ".join(f.render() for f in rep.findings))
    return failures
