"""``python -m repro.analysis`` — the static invariant gate.

Exit codes: 0 = no findings beyond the committed baseline,
1 = new violations (listed, marked NEW), 2 = ``--selftest`` failure
(the analyzer stopped catching its own seeded bug fixtures).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.analysis.findings import load_baseline, write_baseline
from repro.analysis.jaxpr_utils import repo_root
from repro.analysis.runner import analyze, selftest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checker over every registered "
                    "backend's traced program (DESIGN.md §11).")
    ap.add_argument("--baseline", type=Path,
                    default=repo_root() / "analysis_baseline.json",
                    help="committed baseline of acknowledged finding "
                         "keys (default: analysis_baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current "
                         "findings and exit 0")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="dump the full findings report as JSON")
    ap.add_argument("--selftest", action="store_true",
                    help="run the pass stack over the seeded-bug "
                         "fixtures instead of the repo sweep")
    ap.add_argument("--entry", action="append", default=None,
                    help="restrict the sweep to entries whose name "
                         "contains this substring (repeatable)")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()

    if args.selftest:
        failures = selftest()
        dt = time.perf_counter() - t0
        if failures:
            for f in failures:
                print(f"selftest FAIL: {f}")
            print(f"selftest: {len(failures)} failure(s) in {dt:.1f}s")
            return 2
        print(f"selftest: all seeded fixtures caught ({dt:.1f}s)")
        return 0

    entries = None
    if args.entry:
        from repro.analysis.entries import all_entries
        entries = [e for e in all_entries()
                   if any(s in e.name for s in args.entry)]
        if not entries:
            print(f"no entries match {args.entry}", file=sys.stderr)
            return 2

    report = analyze(entries)
    dt = time.perf_counter() - t0

    if args.json:
        args.json.write_text(json.dumps(report.to_json(), indent=2) + "\n")

    if args.write_baseline:
        write_baseline(args.baseline, report)
        print(f"baseline written: {args.baseline} "
              f"({len(report.findings)} key(s))")
        return 0

    baseline = load_baseline(args.baseline)
    new = {f.key for f in report.new_vs(baseline)}
    for f in report.findings:
        tag = "NEW " if f.key in new else "    "
        print(f"{tag}{f.render()}")
    stale = baseline - {f.key for f in report.findings}
    for key in sorted(stale):
        print(f"    (baseline key no longer fires: {key})")

    print(f"checked {len(report.entries_checked)} entries x "
          f"{len(report.passes_run)} passes in {dt:.1f}s: "
          f"{len(report.findings)} finding(s) "
          f"({len(new)} new, {len(report.suppressed)} suppressed, "
          f"baseline {len(baseline)})")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
