"""Connectivity query service (DESIGN.md §7): on-device query kernels
vs NumPy oracles across every generator family, policy selection,
autotune-cache persistence, registry version/invalidation safety, and
the slot-based service engine."""
import collections
import json

import numpy as np
import pytest

from _graphgen import (dynamic_scripts, edges_array,
                       graph_with_query_pairs, insert_batch_cases,
                       two_cliques_one_bridge)
from _propcheck import given, settings, st
from repro.connectivity import policy, queries
from repro.connectivity.registry import GraphRegistry
from repro.connectivity.service import ConnectivityService
from repro.core.batch import next_pow2, pad_rows_pow2
from repro.core.cc import connected_components, num_components
from repro.core.incremental import IncrementalCC
from repro.core.unionfind import connected_components_oracle
from repro.graphs import generators as G


def generator_family_graphs():
    """One graph per generators family (the kernel-oracle matrix)."""
    return [
        G.chain(23),
        G.star(11),
        G.disjoint_cliques(4, 5),
        G.grid_road(7, seed=1),
        G.rmat(6, 4, seed=3),
        G.random_uniform(40, 70, seed=2),
        G.molecule_batch(3, 7, 9, seed=4),
        G.table1_scaled("usa-osm", scale=1 / 4096, seed=5),
        # degenerate: no edges / single vertex
        G.Graph(edges=np.zeros((0, 2), np.int64), num_nodes=6),
        G.Graph(edges=np.zeros((0, 2), np.int64), num_nodes=1),
    ]


def oracle_labels(g):
    return connected_components_oracle(g.edges, g.num_nodes)


# --------------------------------------------------------------------------
# Query kernels vs NumPy oracles
# --------------------------------------------------------------------------

def test_query_kernels_match_numpy_oracle_across_families():
    rng = np.random.default_rng(0)
    for g in generator_family_graphs():
        labels = oracle_labels(g)
        n = g.num_nodes
        # count_components == np.unique
        want_count = int(np.unique(labels).size) if n else 0
        assert int(queries.count_components(labels)) == want_count, g.name
        if n == 0:
            continue
        # same_component on a random pair batch
        pairs = rng.integers(0, n, (17, 2))
        got = np.asarray(queries.same_component(labels, pairs))
        want = labels[pairs[:, 0]] == labels[pairs[:, 1]]
        np.testing.assert_array_equal(got, want, err_msg=g.name)
        # component_size against a Counter census
        census = collections.Counter(labels.tolist())
        verts = rng.integers(0, n, (13,))
        got_sz = np.asarray(queries.component_size(labels, verts))
        want_sz = np.array([census[labels[v]] for v in verts])
        np.testing.assert_array_equal(got_sz, want_sz, err_msg=g.name)
        # component_sizes for every vertex
        got_all = np.asarray(queries.component_sizes(labels))
        want_all = np.array([census[l] for l in labels.tolist()])
        np.testing.assert_array_equal(got_all, want_all, err_msg=g.name)
        # histogram: one count per component in bin floor(log2 size)
        hist = np.asarray(queries.component_histogram(labels))
        want_h = np.zeros_like(hist)
        for size in census.values():
            want_h[int(np.floor(np.log2(size)))] += 1
        np.testing.assert_array_equal(hist, want_h, err_msg=g.name)
        assert hist.sum() == want_count, g.name


@settings(max_examples=10, deadline=None)
@given(graph_with_query_pairs())
def test_query_kernels_property(case):
    """Any random (graph, query batch): kernels == NumPy on the oracle
    labels, and padding to the shared pow2 buckets never changes the
    sliced answers."""
    n, edges, qpairs = case
    edges = edges_array(edges)
    qpairs = edges_array(qpairs)
    labels = connected_components_oracle(edges, n)
    got = np.asarray(queries.same_component(labels, qpairs))
    want = labels[qpairs[:, 0]] == labels[qpairs[:, 1]]
    np.testing.assert_array_equal(got, want)
    padded = pad_rows_pow2(qpairs)
    assert padded.shape[0] == next_pow2(max(qpairs.shape[0], 8))
    np.testing.assert_array_equal(
        np.asarray(queries.same_component(labels, padded))[: len(qpairs)],
        want)
    assert int(queries.count_components(labels)) == np.unique(labels).size
    sizes = np.asarray(queries.component_size(labels, qpairs[:, 0]))
    census = collections.Counter(labels.tolist())
    np.testing.assert_array_equal(
        sizes, [census[labels[v]] for v in qpairs[:, 0]])


def test_floor_log2_exact_at_int32_boundaries():
    """The histogram binning must be exact where a float32 cast is not:
    2^k - 1 above 2^24 rounds UP to 2^k under float32."""
    ks = [1, 2, 15, 16, 17, 23, 24, 25, 26, 30]
    n = np.array([x for k in ks for x in ((1 << k) - 1, 1 << k,
                                          (1 << k) + 1)], np.int32)
    got = np.asarray(queries._floor_log2(n))
    want = np.floor(np.log2(n.astype(np.float64))).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_num_components_wrappers_on_device():
    g = G.disjoint_cliques(3, 4)
    labels = connected_components(g.edges, g.num_nodes).labels
    assert num_components(labels) == 3
    inc = IncrementalCC(g.num_nodes)
    inc.insert(g.edges)
    assert inc.num_components() == 3
    assert num_components(np.array([], np.int32)) == 0


# --------------------------------------------------------------------------
# Policy: heuristic, auto method, autotune cache
# --------------------------------------------------------------------------

def test_policy_heuristic_regimes():
    # sparse (s <= 1 segment): atomic_hook
    assert policy.select_method(100, 20) == "atomic_hook"
    # mid-density: the paper's adaptive segmentation
    assert policy.select_method(100, 400) == "adaptive"
    # near-clique: labelprop
    assert policy.select_method(12, 66) == "labelprop"
    # small delta over existing state: incremental absorb
    assert policy.select_method(100, 400, delta_edges=20) == \
        policy.INCREMENTAL_ABSORB
    # bulk load (delta dominates): a static method
    assert policy.select_method(100, 10, delta_edges=500) in \
        policy.STATIC_METHODS


def test_policy_delete_routes_on_tree_edge_ratio():
    """ISSUE 9: the delete-side heuristic splits on the tree-edge-ratio
    feature — dense graphs (most deletes provably non-tree) take the
    maintained-forest route, road-like |E| ~ |V| graphs stay on the
    plain scoped recompute, and bulk drops still fall through to a
    static rebuild over the survivors."""
    fresh = policy.AutotuneCache()          # no measured overrides
    # dense regime: ratio = 99/1000 << FOREST_TREE_RATIO
    assert policy.select_method(100, 1000, delta_deletes=10,
                                cache=fresh) == \
        policy.DYNAMIC_DELETE_FOREST
    # road-like regime: ratio ~ 1 -> nearly every delete IS a tree edge
    assert policy.select_method(100, 99, delta_deletes=5,
                                cache=fresh) == policy.DYNAMIC_DELETE
    # bulk drop falls through to a static rebuild either way
    assert policy.select_method(100, 1000, delta_deletes=900,
                                cache=fresh) in policy.STATIC_METHODS
    f = policy.extract_features(100, 1000, delta_deletes=10)
    assert f.tree_edge_ratio == pytest.approx(99 / 1000)
    assert policy.extract_features(100, 99).tree_edge_ratio == \
        pytest.approx(1.0)


def test_method_auto_matches_oracle_across_families():
    for g in generator_family_graphs():
        res = connected_components(g.edges, g.num_nodes, method="auto")
        np.testing.assert_array_equal(
            np.asarray(res.labels), oracle_labels(g), err_msg=g.name)


def test_autotune_cache_roundtrip_and_override(tmp_path):
    path = str(tmp_path / "autotune.json")
    cache = policy.AutotuneCache(path)
    g = G.rmat(5, 4, seed=0)
    won = cache.measure(g.edges, g.num_nodes)
    assert won in policy.AUTOTUNE_METHODS
    # measured winner overrides the heuristic for the whole bucket
    assert policy.select_method(g.num_nodes, g.num_edges,
                                cache=cache) == won
    # persisted JSON reloads into a fresh cache
    reloaded = policy.AutotuneCache(path)
    assert reloaded.lookup(g.num_nodes, g.num_edges) == won
    payload = json.loads(open(path).read())
    assert payload["version"] == policy.CACHE_FORMAT_VERSION
    (entry,) = payload["entries"].values()
    assert entry["method"] == won and entry["ms"] > 0
    # a different bucket misses
    assert reloaded.lookup(4 * g.num_nodes, 64 * g.num_edges) is None


def test_autotune_save_is_atomic_and_collision_free(tmp_path):
    """Two caches (standing in for two concurrent ConnectivityService
    processes) interleave saves to one path: every save goes through a
    process-unique temp file + atomic rename, so the JSON on disk is
    complete and parseable after every interleaving, and no stray temp
    files survive."""
    path = str(tmp_path / "shared" / "autotune.json")
    a = policy.AutotuneCache(path)
    b = policy.AutotuneCache(path)
    for i in range(4):
        a.record(64 << i, 256 << i, "adaptive", 1.0 + i)
        payload = json.loads(open(path).read())
        assert payload["version"] == policy.CACHE_FORMAT_VERSION
        b.record(96 << i, 512 << i, "atomic_hook", 2.0 + i)
        payload = json.loads(open(path).read())
        assert payload["version"] == policy.CACHE_FORMAT_VERSION
    leftovers = [p for p in (tmp_path / "shared").iterdir()
                 if p.name != "autotune.json"]
    assert leftovers == []
    # last writer wins wholesale, and its table is intact
    assert policy.AutotuneCache(path).entries == b.entries


# --------------------------------------------------------------------------
# Registry: versioning + invalidation safety
# --------------------------------------------------------------------------

def test_registry_lifecycle_and_validation():
    reg = GraphRegistry()
    reg.create("a", 10)
    with pytest.raises(ValueError, match="already registered"):
        reg.create("a", 10)
    with pytest.raises(KeyError, match="unknown tenant"):
        reg.get("b")
    with pytest.raises(ValueError, match="out of range"):
        reg.insert("a", [[0, 10]])
    with pytest.raises(ValueError, match="out of range"):
        reg.same_component("a", [[0, 10]])
    reg.drop("a")
    assert reg.names() == []


def test_registry_version_ticks_only_on_merge():
    reg = GraphRegistry()
    reg.create("g", 8)
    v0 = reg.version("g")
    reg.insert("g", [[0, 1], [2, 3]])
    v1 = reg.version("g")
    assert v1 > v0
    # already-connected batch: no merge, version unchanged, cache warm
    assert bool(reg.same_component("g", [[0, 1]])[0])
    reg.insert("g", [[1, 0], [3, 2]])
    assert reg.version("g") == v1
    t = reg.get("g")
    hits_before = t.stats.cache_hits
    assert bool(reg.same_component("g", [[0, 1]])[0])
    assert t.stats.cache_hits == hits_before + 1
    # a merging batch ticks the version and invalidates
    reg.insert("g", [[1, 2]])
    assert reg.version("g") > v1
    assert bool(reg.same_component("g", [[0, 3]])[0])


@settings(max_examples=6, deadline=None)
@given(insert_batch_cases())
def test_registry_never_serves_stale_answers_property(case):
    """The invalidation property from the ISSUE: across any insert-batch
    sequence, a cached ``same_component`` answer is never stale — every
    response equals the union-find oracle on the edges inserted so far,
    with the SAME query batch re-asked every round to maximize cache
    pressure."""
    n, batches = case
    reg = GraphRegistry()
    reg.create("t", n)
    rng = np.random.default_rng(n)
    fixed_pairs = rng.integers(0, n, (9, 2))      # re-asked every round
    acc = np.zeros((0, 2), np.int32)
    for batch in batches:
        edges = np.asarray(batch, np.int32).reshape(-1, 2)
        reg.insert("t", edges)
        acc = np.concatenate([acc, edges], axis=0)
        labels = connected_components_oracle(acc, n)
        got = np.asarray(reg.same_component("t", fixed_pairs))
        want = labels[fixed_pairs[:, 0]] == labels[fixed_pairs[:, 1]]
        np.testing.assert_array_equal(got, want)
        assert reg.count_components("t") == np.unique(labels).size
        # and the full label state stays at the oracle fixed point
        np.testing.assert_array_equal(np.asarray(reg.get("t").labels),
                                      labels)


@settings(max_examples=8, deadline=None)
@given(dynamic_scripts())
def test_registry_stale_free_across_splits_property(case):
    """Acceptance (ISSUE 4): across any interleaved insert/delete
    script, cached answers are never stale — every ``same_component`` /
    ``component_size`` / ``count_components`` response equals the
    union-find oracle over the surviving edges, the SAME query batch is
    re-asked after every mutation to maximize cache pressure, and the
    version (= invalidation) ticks EXACTLY when the canonical
    partition changed (merge or split) — never for a batch that left
    connectivity alone."""
    from repro.core.unionfind import DynamicConnectivityOracle
    n, script = case
    reg = GraphRegistry()
    reg.create("t", n)
    oracle = DynamicConnectivityOracle(n)
    rng = np.random.default_rng(n)
    fixed_pairs = rng.integers(0, n, (7, 2))
    prev_labels = connected_components_oracle(
        np.zeros((0, 2), np.int32), n)
    for op, batch in script:
        edges = edges_array(batch)
        v_before = reg.version("t")
        if op == 0:
            reg.insert("t", edges)
            oracle.insert(edges)
        else:
            reg.delete("t", edges)
            oracle.delete(edges)
        labels = oracle.labels()
        changed = not np.array_equal(labels, prev_labels)
        # invalidation precision: the version moved iff the partition
        # did (insert merges and delete splits both count; anything
        # else keeps every cached answer warm)
        assert reg.version("t") - v_before == int(changed), str(script)
        got = np.asarray(reg.same_component("t", fixed_pairs))
        want = labels[fixed_pairs[:, 0]] == labels[fixed_pairs[:, 1]]
        np.testing.assert_array_equal(got, want, err_msg=str(script))
        assert reg.count_components("t") == np.unique(labels).size
        sizes = np.asarray(
            reg.component_size("t", fixed_pairs[:, 0]))
        want_sizes = np.asarray(
            [np.sum(labels == labels[v]) for v in fixed_pairs[:, 0]])
        np.testing.assert_array_equal(sizes, want_sizes)
        np.testing.assert_array_equal(np.asarray(reg.get("t").labels),
                                      labels, err_msg=str(script))
        prev_labels = labels


def test_registry_version_ticks_only_on_actual_split():
    """The delete-side mirror of the merge-tick test: a non-bridge
    delete keeps every cached answer warm; a bridge delete invalidates
    exactly once."""
    n, edges, bridge = two_cliques_one_bridge(4, 4)
    reg = GraphRegistry()
    reg.create("g", n)
    reg.insert("g", edges)
    v0 = reg.version("g")
    assert bool(reg.same_component("g", [[0, n - 1]])[0])
    reg.delete("g", [edges[0]])          # cycle edge: partition intact
    assert reg.version("g") == v0
    t = reg.get("g")
    hits = t.stats.cache_hits
    assert bool(reg.same_component("g", [[0, n - 1]])[0])
    assert t.stats.cache_hits == hits + 1      # cache stayed warm
    reg.delete("g", [bridge])            # split: one tick, cache cold
    assert reg.version("g") == v0 + 1
    assert not bool(reg.same_component("g", [[0, n - 1]])[0])
    assert t.stats.scoped_deletes == 2


def test_registry_policy_routes_bulk_then_absorb():
    g = G.rmat(6, 6, seed=2)
    reg = GraphRegistry()
    t = reg.create("g", g.num_nodes)
    edges = np.asarray(g.edges)
    reg.insert("g", edges[: edges.shape[0] - 16])     # bulk load
    assert t.last_method in policy.STATIC_METHODS
    assert t.stats.rebuilds == 1
    reg.insert("g", edges[edges.shape[0] - 16:])      # small delta
    assert t.last_method == policy.INCREMENTAL_ABSORB
    assert t.stats.absorbs == 1
    np.testing.assert_array_equal(np.asarray(t.labels), oracle_labels(g))


# --------------------------------------------------------------------------
# Service engine
# --------------------------------------------------------------------------

def test_service_mixed_stream_matches_oracle_and_microbatches():
    tenants = {"social": G.rmat(5, 5, seed=1),
               "road": G.grid_road(6, seed=2)}
    reg = GraphRegistry()
    svc = ConnectivityService(reg, slots=64)
    for name, g in tenants.items():
        reg.create(name, g.num_nodes)
    rng = np.random.default_rng(0)
    n_rounds = 3
    splits = {name: np.array_split(rng.permutation(g.num_edges), n_rounds)
              for name, g in tenants.items()}
    acc = {name: np.zeros((0, 2), np.int64) for name in tenants}
    for rnd in range(n_rounds):
        expected = {}
        for name, g in tenants.items():
            edges = np.asarray(g.edges)[splits[name][rnd]]
            svc.submit_insert(name, edges)
            acc[name] = np.concatenate([acc[name], edges], axis=0)
            for _ in range(3):      # 3 requests -> ONE kernel call
                pairs = rng.integers(0, g.num_nodes, (11, 2))
                uid = svc.submit_query(name, "same_component", pairs)
                expected[uid] = (name, pairs)
            svc.submit_query(name, "count_components")
        calls_before = svc.stats["query_calls"]
        finished = {r.uid: r for r in svc.run()}
        # per tick: 2 tenants x (1 same_component microbatch + 1 count)
        assert svc.stats["query_calls"] == calls_before + 4
        for uid, (name, pairs) in expected.items():
            labels = connected_components_oracle(acc[name],
                                                 tenants[name].num_nodes)
            want = labels[pairs[:, 0]] == labels[pairs[:, 1]]
            np.testing.assert_array_equal(
                np.asarray(finished[uid].result), want)
    assert svc.stats["inserts_absorbed"] == 2 * n_rounds
    assert svc.stats["insert_calls"] == 2 * n_rounds
    assert svc.stats["recomputes_avoided"] == svc.stats["queries_served"]
    assert svc.stats["errors"] == 0


def test_service_coalesces_inserts_per_tenant():
    reg = GraphRegistry()
    reg.create("g", 12)
    svc = ConnectivityService(reg, slots=8)
    for e in ([[0, 1]], [[1, 2]], [[3, 4]]):
        svc.submit_insert("g", e)
    svc.run()
    # three insert requests -> one coalesced registry insert
    assert svc.stats["inserts_absorbed"] == 3
    assert svc.stats["insert_calls"] == 1
    assert reg.get("g").stats.inserts == 1
    assert bool(reg.same_component("g", [[0, 2]])[0])


def test_service_errors_do_not_poison_the_tick():
    reg = GraphRegistry()
    reg.create("g", 8)
    svc = ConnectivityService(reg, slots=8)
    bad = svc.submit_query("nope", "count_components")
    ok = svc.submit_query("g", "count_components")
    finished = {r.uid: r for r in svc.run()}
    assert finished[bad].error and finished[bad].done
    assert finished[ok].result == 8 and finished[ok].error is None
    with pytest.raises(ValueError, match="unknown kind"):
        svc.submit("g", "frobnicate")
    with pytest.raises(ValueError, match="unknown query kind"):
        svc.submit_query("g", "insert")
    with pytest.raises(ValueError, match="requires a payload"):
        svc.submit_query("g", "same_component")
    with pytest.raises(ValueError, match="requires a payload"):
        svc.submit("g", "insert")


def test_service_steady_state_has_no_host_transfers():
    """Acceptance (ISSUE 3 + 4): the steady-state service mutation
    paths — device-side coalescing, policy feature extraction from
    DeviceGraph metadata, the on-device merge tick (insert), AND the
    tombstone + scoped-recompute + split tick (delete) — perform ZERO
    implicit host transfers, including a mixed insert+delete tick.
    ``jax.transfer_guard("disallow")`` turns any
    ``bool(device_scalar)``, ``np.concatenate`` fallback, or
    host-scalar jit argument into a hard error."""
    import jax
    from repro.connectivity.service import ConnectivityService
    from repro.core.unionfind import DynamicConnectivityOracle
    from repro.graphs.device import DeviceGraph

    g = G.grid_road(8, extra_prob=0.0, seed=0)
    n, edges = g.num_nodes, np.asarray(g.edges, np.int32)
    reg = GraphRegistry()
    svc = ConnectivityService(reg, slots=16)
    reg.create("t", n)
    # bulk load, then warm every jit entry the steady state will hit
    # (same coalesced shapes as the guarded ticks below)
    svc.submit_insert("t", edges[:-40])
    svc.run()
    svc.submit_insert("t", edges[-40:-30])
    svc.submit_insert("t", edges[-30:-20])
    svc.run()
    svc.submit_delete("t", edges[:5])
    svc.submit_delete("t", edges[5:10])
    svc.run()
    assert reg.get("t").last_method in policy.DELETE_METHODS

    # steady state: same shapes again. Admission (submit) is ingress
    # and may sync for validation; the TICK — coalescing, policy
    # features, absorb, tombstone, scoped recompute, version ticks —
    # must not transfer at all.
    svc.submit_insert("t", DeviceGraph.from_edges(edges[-20:-10], n))
    svc.submit_insert("t", DeviceGraph.from_edges(edges[-10:], n))
    with jax.transfer_guard("disallow"):
        finished = svc.run()
    assert [r.error for r in finished] == [None, None]

    # steady-state DELETE tick (same coalesced shape as the warm one)
    svc.submit_delete("t", DeviceGraph.from_edges(edges[10:15], n))
    svc.submit_delete("t", DeviceGraph.from_edges(edges[15:20], n))
    with jax.transfer_guard("disallow"):
        finished = svc.run()
    assert [r.error for r in finished] == [None, None]

    # MIXED insert+delete tick: re-insert edges deleted above and
    # delete others, one tick, still transfer-free
    svc.submit_insert("t", DeviceGraph.from_edges(edges[:5], n))
    svc.submit_insert("t", DeviceGraph.from_edges(edges[5:10], n))
    svc.submit_delete("t", DeviceGraph.from_edges(edges[20:25], n))
    svc.submit_delete("t", DeviceGraph.from_edges(edges[25:30], n))
    with jax.transfer_guard("disallow"):
        finished = svc.run()
    assert [r.error for r in finished] == [None] * 4
    assert all(r.done for r in finished)
    # results ride as device scalars (the tick never synced them)
    assert all(isinstance(r.result, jax.Array) for r in finished)

    # the guarded mutations really landed: answers match the dynamic
    # oracle replaying the exact mutation sequence
    oracle = DynamicConnectivityOracle(n)
    oracle.insert(edges[:-20])
    oracle.delete(edges[:10])
    oracle.insert(edges[-20:])
    oracle.delete(edges[10:20])
    oracle.insert(edges[:10])
    oracle.delete(edges[20:30])
    labels = oracle.labels()
    pairs = np.stack([np.arange(n, dtype=np.int32),
                      np.zeros(n, np.int32)], axis=1)
    got = np.asarray(reg.same_component("t", pairs))
    np.testing.assert_array_equal(got, labels == labels[0])
    np.testing.assert_array_equal(np.asarray(reg.get("t").labels), labels)


def test_service_interleaved_insert_delete_matches_oracle():
    """Mixed insert/delete/query traffic through the slot engine: every
    answer equals the dynamic oracle over the surviving edges; deletes
    coalesce per tenant per tick (one device call for k requests)."""
    from repro.core.unionfind import DynamicConnectivityOracle
    g = G.rmat(5, 5, seed=9)
    n = g.num_nodes
    edges = np.asarray(g.edges, np.int32)
    reg = GraphRegistry()
    svc = ConnectivityService(reg, slots=64)
    reg.create("t", n)
    oracle = DynamicConnectivityOracle(n)
    rng = np.random.default_rng(1)
    third = edges.shape[0] // 3
    chunks = (edges[:third], edges[third:2 * third], edges[2 * third:])
    for rnd, chunk in enumerate(chunks):
        svc.submit_insert("t", chunk)
        oracle.insert(chunk)
        if rnd:
            # delete a few live edges (sampled) + one absent edge,
            # split across requests to exercise coalescing
            live = oracle.alive()
            kills = live[rng.integers(0, live.shape[0], 4)]
            svc.submit_delete("t", kills[:2])
            svc.submit_delete("t", kills[2:])
            svc.submit_delete("t", [[0, n - 1]])
            oracle.delete(np.concatenate([kills, [[0, n - 1]]]))
        pairs = rng.integers(0, n, (9, 2))
        uid = svc.submit_query("t", "same_component", pairs)
        delete_calls = svc.stats["delete_calls"]
        finished = {r.uid: r for r in svc.run()}
        if rnd:       # 3 delete requests -> ONE coalesced device call
            assert svc.stats["delete_calls"] == delete_calls + 1
        labels = oracle.labels()
        np.testing.assert_array_equal(
            np.asarray(finished[uid].result),
            labels[pairs[:, 0]] == labels[pairs[:, 1]])
        np.testing.assert_array_equal(np.asarray(reg.get("t").labels),
                                      labels)
    assert svc.stats["errors"] == 0
    assert svc.stats["deletes_absorbed"] == 6


def test_registry_insert_accepts_device_graph_and_stays_fresh():
    """DeviceGraph inserts through the registry keep the version /
    invalidation protocol intact (device-side version ticks)."""
    from repro.graphs.device import DeviceGraph
    reg = GraphRegistry()
    reg.create("g", 8)
    reg.insert("g", DeviceGraph.from_edges([[0, 1], [2, 3]], 8))
    v1 = reg.version("g")
    assert v1 == 1
    assert bool(reg.same_component("g", [[0, 1]])[0])
    # non-merging DeviceGraph insert: version unchanged, cache warm
    reg.insert("g", DeviceGraph.from_edges([[1, 0]], 8))
    assert reg.version("g") == v1
    t = reg.get("g")
    hits = t.stats.cache_hits
    assert bool(reg.same_component("g", [[0, 1]])[0])
    assert t.stats.cache_hits == hits + 1
    # merging insert ticks and invalidates
    reg.insert("g", DeviceGraph.from_edges([[1, 2]], 8))
    assert reg.version("g") > v1
    assert bool(reg.same_component("g", [[0, 3]])[0])


def test_service_respects_slot_budget():
    reg = GraphRegistry()
    reg.create("g", 8)
    svc = ConnectivityService(reg, slots=2)
    for _ in range(5):
        svc.submit_query("g", "count_components")
    assert len(svc.step()) == 2
    assert len(svc.queue) == 3
    assert len(svc.run()) == 3

def test_service_tick_keeps_midtick_submissions():
    """Regression: ``step()`` must snapshot the admitted slice ONCE
    and delete exactly that many entries. The old ``self.queue =
    self.queue[self.slots:]`` reslice re-read the list, so a submit
    landing DURING the tick (a collect callback enqueueing follow-up
    work) below the slot budget was silently dropped — admitted by
    nobody, never retired."""
    reg = GraphRegistry()
    reg.create("g", 8)
    svc = ConnectivityService(reg, slots=4)

    class MidTickQueue(list):
        """Appends one follow-up request the first time the tick
        reads the admitted slice (before the deletion happens)."""
        def __init__(self, svc):
            super().__init__()
            self.svc = svc
            self.armed = False

        def __getitem__(self, item):
            out = super().__getitem__(item)
            if self.armed and isinstance(item, slice):
                self.armed = False
                # lands mid-tick, below the slot budget
                super().append(_mk(self.svc, "late"))
            return out

    def _mk(svc, tag):
        from repro.connectivity.service import Request
        svc._uid += 1
        return Request(svc._uid, "g", "count_components")

    q = MidTickQueue(svc)
    svc.queue = q
    svc.submit_query("g", "count_components")   # 1 queued < slots=4
    q.armed = True
    first = svc.step()
    # only the pre-tick request retired; the mid-tick one SURVIVES
    assert len(first) == 1
    assert len(svc.queue) == 1, "mid-tick submission was dropped"
    second = svc.step()
    assert len(second) == 1 and second[0].done
    assert svc.queue == []
