"""repro.fleet: placement planning, the pipelined tick engine, the
fleet front door, and the multi-device paths (subprocess, 8 forced
host devices — same harness as test_distributed)."""
import numpy as np
import pytest

import jax

from repro.connectivity.service import ConnectivityService
from repro.core.unionfind import DynamicConnectivityOracle
from repro.fleet import (FleetService, PipelinedTickEngine, TenantSpec,
                         imbalance, plan_placement, predicted_work,
                         size_plan)
from repro.graphs import generators as G
from repro.graphs.device import DeviceGraph

from test_distributed import run_sub


# ---------------------------------------------------------------------------
# placement planner (host-side, no device work)
# ---------------------------------------------------------------------------

def test_size_plan_matches_solver_plan():
    """The planner's costing primitive and ``Solver.plan()`` read ONE
    work model: same backend choice, same predicted ops for the same
    (|V|, |E|)."""
    from repro.api import Solver
    g = G.grid_road(8, seed=0)
    sp = size_plan(g.num_nodes, g.num_edges)
    real = Solver.open(g.edges, num_nodes=g.num_nodes).plan()
    assert sp.backend == real.backend
    for k in ("hook_ops_per_round", "jump_ops_per_sweep"):
        assert sp.predicted[k] == real.predicted[k]
    assert predicted_work(g.num_nodes, g.num_edges) \
        == g.num_edges + g.num_nodes


def test_plan_placement_lpt_and_shard_routing():
    specs = [TenantSpec(f"t{i}", 64, 64 * (i + 1)) for i in range(8)]
    specs.append(TenantSpec("whale", 1 << 16, 1 << 20))
    plan = plan_placement(specs, 4, shard_threshold=1 << 18)
    assert plan.sharded == ("whale",)
    assert "whale" not in plan.device_of
    assert set(plan.device_of) == {f"t{i}" for i in range(8)}
    assert all(0 <= i < 4 for i in plan.device_of.values())
    # loads reconcile with assignments
    loads = [0] * 4
    for name, idx in plan.device_of.items():
        loads[idx] += plan.work[name]
    assert tuple(loads) == plan.loads
    # LPT keeps the spread tight: max load < mean + heaviest item
    heaviest = max(plan.work[n] for n in plan.device_of)
    assert max(plan.loads) <= sum(plan.loads) / 4 + heaviest
    assert "SHARDED" in plan.explain()


def test_plan_placement_deterministic_fixed_point():
    specs = [TenantSpec(f"t{i}", 32 + i, 16 * (i % 5)) for i in range(20)]
    a = plan_placement(specs, 8)
    b = plan_placement(list(reversed(specs)), 8)
    assert a.device_of == b.device_of and a.loads == b.loads


def test_plan_placement_rejects_duplicates_and_zero_devices():
    with pytest.raises(ValueError, match="duplicate"):
        plan_placement([TenantSpec("a", 8), TenantSpec("a", 8)], 2)
    with pytest.raises(ValueError, match="at least one device"):
        plan_placement([TenantSpec("a", 8)], 0)


def test_imbalance_trigger():
    assert imbalance([]) == 1.0
    assert imbalance([0, 0]) == 1.0
    assert imbalance([10, 10, 10]) == 1.0
    assert imbalance([30, 0, 0]) == 3.0


# ---------------------------------------------------------------------------
# fleet service, single device (the mesh degenerates to one shard;
# batching + pipelining still run)
# ---------------------------------------------------------------------------

def _mixed_workload(fs, oracles, rng, tenants, n):
    """Interleave inserts/deletes/queries; mirror into dyn oracles.
    Returns the uid -> expected-answer map for pair queries."""
    expect = {}
    for t in tenants:
        e = rng.integers(0, n, (24, 2)).astype(np.int32)
        fs.submit_insert(t, e)
        oracles[t].insert(e)
    fs.run()
    for t in tenants:
        e = rng.integers(0, n, (8, 2)).astype(np.int32)
        fs.submit_insert(t, e)
        oracles[t].insert(e)
        pairs = rng.integers(0, n, (6, 2)).astype(np.int32)
        uid = fs.submit_query(t, "same_component", pairs)
        expect[uid] = (t, pairs)
    return expect


def test_fleet_matches_dynamic_oracle_single_device():
    n = 48
    rng = np.random.default_rng(7)
    fs = FleetService(slots_per_device=64, rebalance_every=0)
    tenants = [f"g{i}" for i in range(6)]
    oracles = {}
    for t in tenants:
        fs.admit(t, n, expected_edges=64)
        oracles[t] = DynamicConnectivityOracle(n)
    expect = _mixed_workload(fs, oracles, rng, tenants, n)
    done = {r.uid: r for r in fs.run()}
    assert all(r.error is None for r in done.values())
    for uid, (t, pairs) in expect.items():
        labels = oracles[t].labels()
        want = labels[pairs[:, 0]] == labels[pairs[:, 1]]
        np.testing.assert_array_equal(np.asarray(done[uid].result), want,
                                      err_msg=t)
    # deletes flow through the same pipelined tick
    for t in tenants[:2]:
        e = rng.integers(0, n, (4, 2)).astype(np.int32)
        fs.submit_insert(t, e)
        oracles[t].insert(e)
        fs.submit_delete(t, e[:2])
        oracles[t].delete(e[:2])
        pairs = np.stack([np.arange(n, dtype=np.int32),
                          np.zeros(n, np.int32)], 1)
        fs.submit_query(t, "same_component", pairs)
    done = fs.run()
    assert all(r.error is None for r in done)
    for r in done:
        if r.kind == "same_component":
            labels = oracles[r.tenant].labels()
            want = labels[np.arange(n)] == labels[0]
            np.testing.assert_array_equal(np.asarray(r.result), want)


def test_fleet_all_query_kinds_and_batching():
    """All four kinds through the batched/scalar dispatch split; the
    cross-tenant batcher must collapse same-|V| same-kind traffic into
    ONE dispatch per (kind, |V|) group."""
    n = 32
    rng = np.random.default_rng(3)
    fs = FleetService(slots_per_device=64, rebalance_every=0)
    oracle = {}
    for i in range(4):
        t = f"q{i}"
        fs.admit(t, n)
        e = rng.integers(0, n, (20, 2)).astype(np.int32)
        fs.submit_insert(t, e)
        oracle[t] = DynamicConnectivityOracle(n)
        oracle[t].insert(e)
    fs.run()
    calls_before = fs.shards[0].stats["query_calls"]
    uids = {}
    for t in oracle:
        uids[t, "same_component"] = fs.submit_query(
            t, "same_component", rng.integers(0, n, (5, 2)))
        uids[t, "component_size"] = fs.submit_query(
            t, "component_size", rng.integers(0, n, (3,)))
        uids[t, "count_components"] = fs.submit_query(
            t, "count_components")
        uids[t, "component_histogram"] = fs.submit_query(
            t, "component_histogram")
    done = {r.uid: r for r in fs.run()}
    assert all(r.error is None for r in done.values())
    # 4 tenants x 2 batched kinds -> 2 dispatches; scalar kinds stay
    # per-tenant (4 + 4)
    assert fs.shards[0].stats["query_calls"] - calls_before == 2 + 8
    for t, oc in oracle.items():
        labels = oc.labels()
        uid = uids[t, "count_components"]
        assert done[uid].result == len(np.unique(labels))
        sizes = np.asarray(done[uids[t, "component_size"]].result)
        assert sizes.shape == (3,)
        counts = np.bincount(labels, minlength=n)
        # component size of v == count of v's label
        # (payload regenerated with the same rng draw order is gone;
        # check against the histogram instead)
        hist = np.asarray(done[uids[t, "component_histogram"]].result)
        assert int(hist.sum()) == len(np.unique(labels))


def test_fleet_pipeline_retires_one_tick_late():
    """Double buffering: a query dispatched in tick N materializes in
    tick N+1; ``run()`` hides this (drains the tail), ``step()`` shows
    it."""
    n = 16
    fs = FleetService(slots_per_device=8, rebalance_every=0)
    fs.admit("t", n)
    fs.submit_insert("t", [[0, 1], [1, 2]])
    fs.run()
    fs.submit_query("t", "same_component", [[0, 2], [0, 3]])
    first = fs.step()          # dispatched, not yet collected
    assert first == []
    assert fs.inflight
    second = fs.step()         # collected here
    assert [r.done for r in second] == [True]
    np.testing.assert_array_equal(np.asarray(second[0].result),
                                  [True, False])
    assert not fs.inflight


def test_fleet_unknown_tenant_and_bad_kind():
    fs = FleetService(rebalance_every=0)
    with pytest.raises(KeyError):
        fs.submit_query("nope", "count_components")
    fs.admit("t", 8)
    with pytest.raises(ValueError, match="unknown query kind"):
        fs.submit_query("t", "insert")
    with pytest.raises(ValueError, match="already admitted"):
        fs.admit("t", 8)
    assert fs.placement_of("t") == 0
    fs.drop("t")
    with pytest.raises(KeyError):
        fs.placement_of("t")


def test_fleet_steady_state_mutation_tick_transfer_free():
    """Acceptance: the pipelined per-shard mutation tick — admission
    pop, coalescing, policy features, absorb, tombstone, version tick —
    performs ZERO implicit host transfers once shapes are warm. Query
    DISPATCH is also guarded (its one host->device hop is an explicit
    device_put); only collect (the audited to_host sink) syncs, outside
    the guard."""
    g = G.grid_road(8, extra_prob=0.0, seed=0)
    n, edges = g.num_nodes, np.asarray(g.edges, np.int32)
    fs = FleetService(slots_per_device=16, rebalance_every=0)
    fs.admit("t", n)
    # warm: bulk load, then the exact coalesced shapes the guarded
    # ticks below will replay
    fs.submit_insert("t", edges[:-40])
    fs.run()
    fs.submit_insert("t", edges[-40:-30])
    fs.submit_insert("t", edges[-30:-20])
    fs.run()
    fs.submit_delete("t", edges[:5])
    fs.submit_delete("t", edges[5:10])
    fs.run()
    fs.submit_query("t", "same_component", edges[:8])
    fs.run()

    # steady state: same shapes, DeviceGraph payloads, guarded ticks
    fs.submit_insert("t", DeviceGraph.from_edges(edges[-20:-10], n))
    fs.submit_insert("t", DeviceGraph.from_edges(edges[-10:], n))
    fs.submit_query("t", "same_component", edges[8:16])
    with jax.transfer_guard("disallow"):
        assert fs.step() == []          # dispatch-only tick
    finished = fs.run()                 # collect outside the guard
    assert [r.error for r in finished] == [None] * 3
    fs.submit_delete("t", DeviceGraph.from_edges(edges[10:15], n))
    fs.submit_delete("t", DeviceGraph.from_edges(edges[15:20], n))
    with jax.transfer_guard("disallow"):
        fs.step()
    finished = fs.run()
    assert [r.error for r in finished] == [None, None]
    # mutation results ride as device scalars (the tick never synced)
    assert all(isinstance(r.result, jax.Array) for r in finished)

    # the guarded mutations really landed
    oracle = DynamicConnectivityOracle(n)
    oracle.insert(edges[:-20])
    oracle.delete(edges[:10])
    oracle.insert(edges[-20:])
    oracle.delete(edges[10:20])
    labels = oracle.labels()
    pairs = np.stack([np.arange(n, dtype=np.int32),
                      np.zeros(n, np.int32)], 1)
    fs.submit_query("t", "same_component", pairs)
    got = np.asarray(fs.run()[0].result)
    np.testing.assert_array_equal(got, labels[pairs[:, 0]] == labels[0])


def test_fleet_promotion_to_sharded_class():
    """A packed tenant whose LIVE work crosses the threshold promotes
    to the sharded class at the next rebalance poll, answers intact."""
    n = 256
    fs = FleetService(slots_per_device=32, shard_threshold=n + 60,
                      rebalance_every=1, rebalance_factor=0.9)
    fs.admit("small", n, expected_edges=8)
    assert fs.placement_of("small") == 0
    chain = np.stack([np.arange(40), np.arange(40) + 1], 1)
    fs.submit_insert("small", chain)
    fs.run()
    assert fs.placement_of("small") == 0        # 256+40 < threshold
    fs.submit_insert("small", chain + 100)      # ragged second block
    fs.run()                                     # live work crosses
    # ticks keep running until the poll fires
    for _ in range(3):
        fs.step()
    assert fs.placement_of("small") == "mesh"
    assert fs.stats["promotions"] == 1
    fs.submit_query("small", "same_component", [[0, 40], [0, 141], [0, 99]])
    done = fs.run()
    assert [r.error for r in done] == [None]
    np.testing.assert_array_equal(np.asarray(done[0].result),
                                  [True, False, False])


def test_fleet_sharded_tenant_lifecycle_single_device():
    """Sharded-class tenant on a 1-device mesh: admit routes by
    predicted work, mutations accumulate in the tombstone log, queries
    lazily re-solve (once per dirty window, not once per query)."""
    n = 1 << 10
    fs = FleetService(shard_threshold=1 << 10, rebalance_every=0)
    fs.admit("whale", n, expected_edges=1 << 12)
    assert fs.placement_of("whale") == "mesh"
    chain = np.stack([np.arange(200), np.arange(200) + 1], 1)
    fs.submit_insert("whale", chain)
    fs.submit_query("whale", "same_component", [[0, 200], [0, 201]])
    fs.submit_query("whale", "count_components")
    done = fs.run()
    assert [r.error for r in done] == [None] * 3
    by_kind = {r.kind: r for r in done}
    np.testing.assert_array_equal(
        np.asarray(by_kind["same_component"].result), [True, False])
    assert by_kind["count_components"].result == n - 200
    assert fs.stats["sharded_resolves"] == 1    # one solve, two queries
    # delete the chain's middle edge -> split
    fs.submit_delete("whale", [[100, 101]])
    fs.submit_query("whale", "same_component", [[0, 100], [0, 101]])
    done = fs.run()
    assert [r.error for r in done] == [None, None]
    q = [r for r in done if r.kind == "same_component"][0]
    np.testing.assert_array_equal(np.asarray(q.result), [True, False])
    assert fs.stats["sharded_resolves"] == 2


def test_fleet_slo_merged_percentiles_exact():
    """Fleet percentiles come from bucket-count SUMS across per-device
    recorders (satellite 1): the merged p50/p99 equals a single
    recorder fed the union stream — not an average of per-shard
    percentiles."""
    from repro.obs import trace as obs
    from repro.obs.slo import LatencyHistogram, SLORecorder
    obs.enable()
    try:
        fs = FleetService(rebalance_every=0)
        fs.admit("a", 16)
        fs.admit("b", 16)
        rng = np.random.default_rng(0)
        for t in ("a", "b"):
            fs.submit_insert(t, rng.integers(0, 16, (8, 2)))
        fs.run()
        for t in ("a", "b"):
            for _ in range(5):
                fs.submit_query(t, "same_component",
                                rng.integers(0, 16, (4, 2)))
        fs.run()
        merged = fs.slo()
        want = SLORecorder()
        for rec in [s.slo for s in fs.shards] + [fs.mesh_slo]:
            for (tenant, kind), h in rec._hists.items():
                union = want._hists.setdefault(
                    (tenant, kind), LatencyHistogram(want.spec))
                union.counts = union.counts + h.counts
        assert merged.summary() == want.summary()
        gl = merged.summary()["global"]
        assert gl["same_component"]["count"] == 10
        assert gl["insert"]["count"] == 2
        assert set(merged.summary()["tenants"]) == {"a", "b"}
    finally:
        obs.disable()


def test_engine_composes_with_bare_services():
    """The engine is usable over plain (unpinned) services — the fleet
    facade is sugar, not a requirement."""
    svc = ConnectivityService(slots=8)
    svc.registry.create("t", 8)
    eng = PipelinedTickEngine([svc])
    svc.submit_insert("t", [[0, 1]])
    svc.submit_query("t", "same_component", [[0, 1]])
    eng.tick()
    done = eng.flush()
    assert len(done) == 2 and all(r.done for r in done)
    assert eng.stats["batched_dispatches"] == 1


# ---------------------------------------------------------------------------
# multi-device (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

def test_fleet_8dev_placement_throughput_and_oracle():
    """Fast-tier 8-device fleet: tenants spread across ALL devices,
    mixed mutation/query traffic matches the dynamic oracle, a sharded
    tenant solves across the mesh, and the merged SLO sees every
    query."""
    out = run_sub("""
        from repro.core.unionfind import DynamicConnectivityOracle
        from repro.fleet import FleetService
        assert len(jax.devices()) == 8
        n = 32
        rng = np.random.default_rng(1)
        fs = FleetService(slots_per_device=64, shard_threshold=1 << 11,
                          rebalance_every=0)
        tenants = [f"t{i}" for i in range(16)]
        oracles = {}
        for t in tenants:
            fs.admit(t, n, expected_edges=48)
            oracles[t] = DynamicConnectivityOracle(n)
        # every device owns exactly 2 of the 16 equal-work tenants
        owners = {fs.placement_of(t) for t in tenants}
        assert owners == set(range(8)), owners
        for t in tenants:
            e = rng.integers(0, n, (24, 2)).astype(np.int32)
            fs.submit_insert(t, e)
            oracles[t].insert(e)
        fs.run()
        expect = {}
        for t in tenants:
            pairs = rng.integers(0, n, (6, 2)).astype(np.int32)
            expect[fs.submit_query(t, "same_component", pairs)] = (t, pairs)
        done = {r.uid: r for r in fs.run()}
        assert all(r.error is None for r in done.values())
        for uid, (t, pairs) in expect.items():
            labels = oracles[t].labels()
            want = labels[pairs[:, 0]] == labels[pairs[:, 1]]
            np.testing.assert_array_equal(np.asarray(done[uid].result),
                                          want, err_msg=t)
        # per-shard tick counters prove every device actually served
        assert all(s.stats["ticks"] > 0 for s in fs.shards)
        # sharded tenant across the full mesh
        fs.admit("whale", 1 << 11, expected_edges=1 << 12)
        assert fs.placement_of("whale") == "mesh"
        chain = np.stack([np.arange(500), np.arange(500) + 1], 1)
        fs.submit_insert("whale", chain)
        fs.submit_query("whale", "same_component", [[0, 500], [0, 501]])
        done = fs.run()
        assert [r.error for r in done] == [None, None]
        q = [r for r in done if r.kind == "same_component"][0]
        np.testing.assert_array_equal(np.asarray(q.result), [True, False])
        print("FLEET_8DEV_OK")
    """)
    assert "FLEET_8DEV_OK" in out


@pytest.mark.slow
def test_fleet_8dev_rebalance_migrates_drifted_tenants():
    """Load drift (one tenant ballooning) trips the imbalance trigger;
    the rebalancer migrates packed tenants off the hot device and
    answers stay oracle-correct after the move."""
    out = run_sub("""
        from repro.core.unionfind import DynamicConnectivityOracle
        from repro.fleet import FleetService
        n = 64
        rng = np.random.default_rng(5)
        fs = FleetService(slots_per_device=64, rebalance_every=2,
                          rebalance_factor=1.5, shard_threshold=1 << 30)
        # 16 tenants over 8 devices: every device owns a PAIR, so the
        # hot tenant has a co-tenant the rebalancer can move off
        tenants = [f"t{i}" for i in range(16)]
        oracles = {}
        for t in tenants:
            fs.admit(t, n, expected_edges=16)
            oracles[t] = DynamicConnectivityOracle(n)
        hot = tenants[0]
        # balloon the hot tenant's device
        for _ in range(4):
            e = rng.integers(0, n, (256, 2)).astype(np.int32)
            fs.submit_insert(hot, e)
            oracles[hot].insert(e)
            fs.run()
        assert fs.stats["migrations"] > 0, fs.stats
        for t in tenants:
            pairs = rng.integers(0, n, (6, 2)).astype(np.int32)
            uid = fs.submit_query(t, "same_component", pairs)
            done = {r.uid: r for r in fs.run()}
            labels = oracles[t].labels()
            want = labels[pairs[:, 0]] == labels[pairs[:, 1]]
            np.testing.assert_array_equal(np.asarray(done[uid].result),
                                          want, err_msg=t)
        print("FLEET_REBALANCE_OK", fs.stats["migrations"])
    """)
    assert "FLEET_REBALANCE_OK" in out
