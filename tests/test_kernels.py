"""Per-kernel validation: shape/dtype sweeps against the pure-jnp
oracles, in interpret mode (TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag import ops as eb_ops, ref as eb_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.hook import ops as hk_ops, ref as hk_ref
from repro.kernels.multi_jump import ops as mj_ops, ref as mj_ref
from repro.kernels.segment_reduce import ops as sr_ops, ref as sr_ref


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bh,s,d", [(2, 128, 64), (4, 256, 64),
                                    (1, 384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(rng, bh, s, d, dtype):
    q = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    out = fa_ops.flash_attention_pallas(q, k, v, sm_scale=d ** -0.5,
                                        causal=True, block_q=128,
                                        block_k=128, interpret=True)
    want = fa_ref.ref_attention(q, k, v, sm_scale=d ** -0.5, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (64, 0.0),
                                            (0, 30.0), (128, 50.0)])
def test_flash_attention_variants(rng, window, softcap):
    bh, s, d = 2, 256, 64
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    out = fa_ops.flash_attention_pallas(
        q, k, v, sm_scale=d ** -0.5, causal=True, window=window,
        softcap=softcap, interpret=True)
    want = fa_ref.ref_attention(q, k, v, sm_scale=d ** -0.5, causal=True,
                                window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# segment_reduce
# --------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("n,d,segs,tile", [(256, 16, 16, 128),
                                           (1024, 32, 64, 1024),
                                           (512, 8, 1, 256)])
def test_segment_reduce(rng, op, n, d, segs, tile):
    vals = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    ids = jnp.sort(jnp.asarray(rng.integers(0, segs, n), jnp.int32))
    out = sr_ops.segment_reduce_pallas(vals, ids, segs, op=op,
                                       tile=tile, interpret=True)
    want = sr_ref.ref_segment_reduce(vals, ids, segs, op=op)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_segment_reduce_empty_segments(rng):
    vals = jnp.asarray(rng.standard_normal((128, 4)), jnp.float32)
    ids = jnp.full((128,), 3, jnp.int32)       # all in one segment
    out = sr_ops.segment_reduce_pallas(vals, ids, 8, op="sum",
                                       tile=128, interpret=True)
    want = sr_ref.ref_segment_reduce(vals, ids, 8, op="sum")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5)


# --------------------------------------------------------------------------
# embedding_bag
# --------------------------------------------------------------------------

@pytest.mark.parametrize("rows,dim,bags,hot", [(100, 16, 256, 4),
                                               (1000, 32, 512, 1),
                                               (64, 8, 256, 8)])
def test_embedding_bag(rng, rows, dim, bags, hot):
    table = jnp.asarray(rng.standard_normal((rows, dim)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, rows, (bags, hot)), jnp.int32)
    out = eb_ops.embedding_bag_pallas(table, idx, interpret=True)
    want = eb_ref.ref_embedding_bag(table, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# hook + multi_jump (the paper's kernels)
# --------------------------------------------------------------------------

def test_hook_kernel_matches_ref(rng):
    n, e, tile = 200, 512, 128
    pi = jnp.arange(n, dtype=jnp.int32)
    edges = jnp.asarray(rng.integers(0, n, (e, 2)), jnp.int32)
    for lift in (0, 2):
        out = hk_ops.hook_pallas(pi, edges, edge_tile=tile,
                                 lift_steps=lift, interpret=True)
        # oracle of the kernel's sequential-tile semantics
        want = hk_ref.ref_hook_tiled(pi, edges, edge_tile=tile,
                                     lift_steps=lift)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_multi_jump_kernel_flattens(rng):
    n = 300
    # a chain: worst-case depth; full_compress = kernel sweeps to star
    pi = jnp.asarray(np.maximum(np.arange(n) - 1, 0), jnp.int32)
    out = mj_ops.full_compress(pi, tile=128, interpret=True)
    want = mj_ref.ref_full_compress(pi)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    assert np.all(np.asarray(out) == 0)


@pytest.mark.parametrize("seed", range(3))
def test_multi_jump_random_forest(seed):
    rng = np.random.default_rng(seed)
    n = 257
    parent = np.minimum(np.arange(n),
                        rng.integers(0, n, n)).astype(np.int32)
    out = mj_ops.full_compress(jnp.asarray(parent), tile=128,
                               interpret=True)
    want = mj_ref.ref_full_compress(jnp.asarray(parent))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
