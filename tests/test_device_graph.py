"""DeviceGraph substrate (DESIGN.md §8): pytree round trips, padding /
true-count invariants, device-side concat, on-device CSR, sharding, and
the engines' DeviceGraph entry points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batch import connected_components_batched
from repro.core.cc import connected_components
from repro.core.unionfind import connected_components_oracle
from repro.graphs import generators as G
from repro.graphs.device import DeviceGraph, as_device_graph
from repro.graphs.format import build_csr


def test_from_host_and_shim_agree():
    g = G.rmat(6, 4, seed=0)
    dg = DeviceGraph.from_host(g)
    dg2 = as_device_graph(g.edges, g.num_nodes)
    assert dg.num_nodes == dg2.num_nodes == g.num_nodes
    assert dg.true_edges_static == dg2.true_edges_static == g.num_edges
    assert dg.plan == dg2.plan
    np.testing.assert_array_equal(np.asarray(dg.edges),
                                  np.asarray(dg2.edges))
    # already-a-DeviceGraph passes through untouched
    assert as_device_graph(dg) is dg


def test_pad_pow2_invariants():
    g = G.grid_road(5, seed=1)
    dg = DeviceGraph.from_host(g)
    padded = dg.pad_pow2()
    e = g.num_edges
    assert padded.edges.shape[0] == 1 << (e - 1).bit_length()
    assert padded.true_edges_static == e          # true count preserved
    arr = np.asarray(padded.edges)
    np.testing.assert_array_equal(arr[:e], np.asarray(g.edges))
    assert (arr[e:] == 0).all()                   # (0,0) no-op rows
    # plan covers the stored rows, heuristic keyed on the TRUE count
    assert padded.plan.padded_edges >= padded.edges.shape[0]
    assert padded.plan.num_segments == dg.plan.num_segments


def test_concat_sums_true_counts_and_trims_padding():
    a = DeviceGraph.from_edges([[0, 1], [1, 2]], 6).pad_pow2()
    b = DeviceGraph.from_edges([[3, 4]], 6)
    c = DeviceGraph.concat([a, b])
    assert c.true_edges_static == 3
    # a's pad rows were trimmed: prefix invariant holds after concat
    np.testing.assert_array_equal(
        np.asarray(c.edges)[:3], [[0, 1], [1, 2], [3, 4]])
    with pytest.raises(ValueError, match="identical num_nodes"):
        DeviceGraph.concat([a, DeviceGraph.from_edges([[0, 1]], 7)])
    labels = connected_components(c).labels
    np.testing.assert_array_equal(
        np.asarray(labels),
        connected_components_oracle(np.asarray(c.edges)[:3], 6))


def test_concat_joins_degree_skew_none_aware():
    """ISSUE 9 satellite: ``concat`` folds per-graph ``degree_skew``
    with a None-aware max — device-resident inputs (skew unknown) must
    not poison the router-facing bound, and an all-unknown concat stays
    None instead of inventing a number."""
    host_a = DeviceGraph.from_edges([[0, 1], [0, 2], [0, 3]], 8)  # star
    host_b = DeviceGraph.from_edges([[4, 5]], 8)
    dev = DeviceGraph.from_edges(jnp.asarray([[6, 7]], jnp.int32), 8)
    assert host_a.degree_skew is not None
    assert host_b.degree_skew is not None
    assert dev.degree_skew is None                 # device ingest: unknown
    c = DeviceGraph.concat([host_a, dev, host_b])
    assert c.degree_skew == pytest.approx(
        max(host_a.degree_skew, host_b.degree_skew))
    c2 = DeviceGraph.concat(
        [dev, DeviceGraph.from_edges(jnp.asarray([[1, 2]], jnp.int32), 8)])
    assert c2.degree_skew is None


def test_compact_alive_perm_and_edgelog_compact():
    """ISSUE 9 satellite: ``compact_alive_perm`` returns the old→new
    row permutation alongside the packed prefix (dead rows map to -1),
    and ``EdgeLog.compact()`` applies it in place, pulling the append
    cursor back to the alive count."""
    from repro.graphs.device import (EdgeLog, compact_alive,
                                     compact_alive_perm)
    edges = jnp.asarray([[0, 1], [2, 3], [4, 5], [6, 7]], jnp.int32)
    alive = jnp.asarray([False, True, False, True])
    packed, true, perm = compact_alive_perm(edges, alive)
    assert int(true) == 2
    np.testing.assert_array_equal(np.asarray(packed),
                                  [[2, 3], [6, 7], [0, 0], [0, 0]])
    np.testing.assert_array_equal(np.asarray(perm), [-1, 0, -1, 1])
    # the 2-tuple spelling stays bit-identical (it delegates)
    packed2, true2 = compact_alive(edges, alive)
    np.testing.assert_array_equal(np.asarray(packed2), np.asarray(packed))
    assert int(true2) == int(true)

    log = EdgeLog(8)
    log.append(DeviceGraph.from_edges([[0, 1], [2, 3], [4, 5]], 8))
    from repro.graphs.device import _log_delete_jit
    log.alive, _ = _log_delete_jit(log.edges, log.alive,
                                   jnp.asarray([[3, 2]], jnp.int32),
                                   jnp.asarray(1, jnp.int32))
    rows_before = log.rows
    perm = log.compact()
    assert log.rows == 2 and rows_before == 3
    np.testing.assert_array_equal(np.asarray(log.edges)[:2],
                                  [[0, 1], [4, 5]])
    np.testing.assert_array_equal(np.asarray(perm)[:3], [0, -1, 1])


def test_pytree_roundtrip_and_jit_boundary():
    dg = DeviceGraph.from_host(G.star(9)).pad_pow2()
    leaves, treedef = jax.tree.flatten(dg)
    back = jax.tree.unflatten(treedef, leaves)
    assert back.num_nodes == dg.num_nodes
    assert back.true_edges_static == dg.true_edges_static
    assert back.plan == dg.plan

    @jax.jit
    def through(g):
        return g.edges.sum(), g

    total, out = through(dg)
    assert int(total) == int(np.asarray(dg.edges).sum())
    assert out.plan == dg.plan            # static aux survives the jit
    # traced true count flattens as a leaf
    traced = DeviceGraph(dg.edges, dg.num_nodes,
                         jnp.asarray(7, jnp.int32), dg.plan)
    assert traced.true_edges_static is None
    assert len(jax.tree.leaves(traced)) == 2


def test_csr_matches_host_builder():
    g = G.rmat(5, 4, seed=2)
    dg = DeviceGraph.from_host(g)
    offsets, neighbors = dg.csr()
    host = build_csr(g.edges, g.num_nodes, symmetrize=False)
    np.testing.assert_array_equal(np.asarray(offsets), host.indptr)
    # per-row neighbor MULTISETS agree (sort order within a row is free)
    off = np.asarray(offsets)
    nb = np.asarray(neighbors)
    for v in range(g.num_nodes):
        np.testing.assert_array_equal(
            np.sort(nb[off[v]:off[v + 1]]),
            np.sort(host.indices[host.indptr[v]:host.indptr[v + 1]]))
    assert dg._csr is not None            # cached after first build


def test_trim_and_density_metadata():
    g = G.disjoint_cliques(3, 4, seed=2)
    dg = DeviceGraph.from_host(g)
    assert dg.density == pytest.approx(2.0 * g.num_edges / g.num_nodes)
    padded = dg.pad_pow2(min_rows=2 * g.num_edges)
    assert padded.density == dg.density   # padding never inflates features
    trimmed = padded.trim()
    assert trimmed.edges.shape[0] == g.num_edges
    np.testing.assert_array_equal(np.asarray(trimmed.edges),
                                  np.asarray(g.edges))
    assert padded.trim().true_edges_static == g.num_edges
    with pytest.raises(ValueError, match="static true_edges"):
        DeviceGraph(dg.edges, dg.num_nodes,
                    jnp.asarray(3, jnp.int32), dg.plan).trim()


def test_engines_consume_device_graph():
    graphs = [G.rmat(5, 3, seed=s) for s in range(3)] + [G.chain(23)]
    dgs = [DeviceGraph.from_host(g) for g in graphs]
    # single-graph API
    for g, dg in zip(graphs, dgs):
        want = connected_components_oracle(g.edges, g.num_nodes)
        np.testing.assert_array_equal(
            np.asarray(connected_components(dg).labels), want)
    # batched API: device in -> device out, bit-identical to per-graph
    batched = connected_components_batched(dgs)
    for g, r in zip(graphs, batched):
        assert isinstance(r.labels, jax.Array)
        np.testing.assert_array_equal(
            np.asarray(r.labels),
            np.asarray(connected_components(g.edges, g.num_nodes).labels))


def test_padded_device_graph_bills_true_edges():
    g = G.disjoint_cliques(3, 4, seed=0)
    dg = DeviceGraph.from_host(g)
    padded = dg.pad_rows(4 * g.num_edges)
    lean = connected_components(dg)
    fat = connected_components(padded)
    np.testing.assert_array_equal(np.asarray(lean.labels),
                                  np.asarray(fat.labels))
    # 4x padding must NOT inflate hook billing (padding is free)
    assert int(fat.work.hook_ops) == int(lean.work.hook_ops)


def test_shard_single_device_mesh():
    from jax.sharding import Mesh
    g = G.star(13)                        # 12 edges: nothing to pad on 1 dev
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    dg = DeviceGraph.from_host(g).shard(mesh, ("data",))
    assert dg.edges.shape[0] % 1 == 0
    from repro.core.distributed import make_distributed_cc
    fn = make_distributed_cc(dg, mesh, ("data",))
    np.testing.assert_array_equal(
        np.asarray(fn(dg)),
        connected_components_oracle(g.edges, g.num_nodes))

def test_shard_concat_roundtrip_nondivisible_single_device():
    """shard() on a non-divisible edge count pads with (0, 0) no-ops
    but must preserve the TRUE count and the degree-skew aux — and a
    trim + concat round trip recovers the exact edge set with the
    None-aware skew join intact."""
    from jax.sharding import Mesh
    edges = np.array([[0, i + 1] for i in range(13)], np.int32)  # star
    dg = DeviceGraph.from_edges(edges, 16)
    assert dg.degree_skew is not None and dg.degree_skew > 1.0
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = dg.shard(mesh, ("data",))
    # 1 device: 13 rows need no padding, metadata rides through
    assert sh.true_edges_static == 13
    assert sh.degree_skew == dg.degree_skew
    # round trip: trim drops any padding, rows match exactly
    np.testing.assert_array_equal(np.asarray(sh.trim().edges), edges)
    # concat with a padded, skewless (device-ingest) part: true counts
    # sum, pads are trimmed out of the interior, skew joins None-aware
    other = DeviceGraph.from_edges(
        jnp.asarray([[14, 15], [15, 14]], jnp.int32), 16).pad_pow2()
    assert other.degree_skew is None
    assert int(other.edges.shape[0]) > 2          # really padded
    cat = DeviceGraph.concat([sh.trim(), other])
    assert cat.true_edges_static == 15
    assert cat.degree_skew == dg.degree_skew      # max of known
    np.testing.assert_array_equal(
        np.asarray(cat.edges)[:15],
        np.concatenate([edges, [[14, 15], [15, 14]]]))


def test_shard_roundtrip_nondivisible_8dev():
    """8-way shard of non-divisible counts: rows pad to a multiple of
    8, true count + skew survive, the padded tail is (0, 0), and the
    round trip back through trim/concat reproduces the original edges
    on every shard layout."""
    from test_distributed import run_sub
    out = run_sub("""
        from repro.graphs.device import DeviceGraph
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
        for e_count in (13, 30, 64):           # 2 non-divisible, 1 exact
            edges = np.stack([np.zeros(e_count, np.int32),
                              np.arange(1, e_count + 1, dtype=np.int32)],
                             axis=1)
            dg = DeviceGraph.from_edges(edges, e_count + 2)
            skew = dg.degree_skew
            assert skew is not None
            sh = dg.shard(mesh, ("data",))
            assert sh.edges.shape[0] % 8 == 0
            assert sh.true_edges_static == e_count
            assert sh.degree_skew == skew
            host = np.asarray(sh.edges)
            np.testing.assert_array_equal(host[:e_count], edges)
            assert (host[e_count:] == 0).all()     # (0,0) no-op pads
            # round trip: trim -> re-concat shards' worth of parts
            back = DeviceGraph.concat(
                [sh.trim(), DeviceGraph.from_edges(
                    np.zeros((0, 2), np.int32), e_count + 2)])
            assert back.true_edges_static == e_count
            assert back.degree_skew == skew
            np.testing.assert_array_equal(np.asarray(back.edges)[:e_count],
                                          edges)
        print("SHARD_ROUNDTRIP_OK")
    """)
    assert "SHARD_ROUNDTRIP_OK" in out
