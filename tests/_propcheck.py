"""Property-test front end: hypothesis when installed, a deterministic
fallback otherwise.

The container this repo ships in does not bake in ``hypothesis``, and a
module-level ``from hypothesis import ...`` used to kill collection of
the whole tier-1 run. Test modules import ``given / settings / st`` from
here instead; with hypothesis installed (``pip install -r
requirements-dev.txt``) they get the real shrinking fuzzer, without it a
small seeded generator draws a fixed, reproducible example sequence —
the property tests keep running either way.

The fallback implements only the strategy surface this suite uses:
``integers, lists, tuples, just`` plus ``.flatmap`` / ``.map``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                               # deterministic fallback
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    # Fallback example budget: capped below hypothesis' max_examples to
    # keep the fast tier fast (every example with a fresh shape is a
    # fresh jit compile).
    _MAX_EXAMPLES_CAP = 10
    _SEED = 0xC0FFEE

    class SearchStrategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

        def flatmap(self, fn):
            return SearchStrategy(
                lambda rng: fn(self._draw(rng)).example(rng))

        def map(self, fn):
            return SearchStrategy(lambda rng: fn(self._draw(rng)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return SearchStrategy(
                lambda rng: int(rng.integers(min_value, max_value,
                                             endpoint=True)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size, endpoint=True))
                return [elements.example(rng) for _ in range(size)]
            return SearchStrategy(draw)

        @staticmethod
        def tuples(*strats):
            return SearchStrategy(
                lambda rng: tuple(s.example(rng) for s in strats))

        @staticmethod
        def just(value):
            return SearchStrategy(lambda rng: value)

    st = _Strategies()

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._pc_max_examples = min(max_examples, _MAX_EXAMPLES_CAP)
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # The drawn values fill the LAST len(strats) parameters
            # (hypothesis' positional @given semantics); bind them by
            # name so fixtures occupying the leading parameters can't
            # collide with the drawn positionals.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            keep = params[: len(params) - len(strats)]
            drawn_names = [p.name for p in params[len(keep):]]

            @functools.wraps(fn)
            def wrapper(*fixture_args, **fixture_kw):
                n = getattr(wrapper, "_pc_max_examples",
                            _MAX_EXAMPLES_CAP)
                rng = np.random.default_rng(_SEED)
                for _ in range(n):
                    drawn = {name: s.example(rng)
                             for name, s in zip(drawn_names, strats)}
                    fn(*fixture_args, **fixture_kw, **drawn)

            wrapper._pc_max_examples = _MAX_EXAMPLES_CAP
            # hide the drawn parameters from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco
