"""Training substrate: optimizers, accumulation, checkpointing, fault
tolerance, gradient compression, data pipeline determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import pipeline as dp
from repro.train import checkpoint as ck
from repro.train import train_state
from repro.train.compression import (compress, compressed_psum,
                                     decompress, zero_residual)
from repro.train.fault_tolerance import (SimulatedFailure, StepWatchdog,
                                         run_with_restarts)
from repro.train.optimizer import (AdamWConfig, SGDConfig, adamw,
                                   clip_by_global_norm, cosine_schedule,
                                   sgd)


def quad_problem(rng):
    x = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}

    def loss(p, batch):
        return jnp.mean((p["w"] @ batch["x"] + p["b"][:, None]
                         - batch["t"]) ** 2)
    return params, loss, {"x": x, "t": t}


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(AdamWConfig(lr=0.05, weight_decay=0.0)),
    lambda: sgd(SGDConfig(lr=0.05, momentum=0.9)),
])
def test_optimizers_reach_least_squares_optimum(rng, make_opt):
    params, loss, batch = quad_problem(rng)
    opt = make_opt()
    state = train_state.create(params, opt)
    step = jax.jit(train_state.make_train_step(loss, opt))
    for _ in range(300):
        state, m = step(state, batch)
    # analytic LS optimum
    x, t = np.asarray(batch["x"]), np.asarray(batch["t"])
    A = np.vstack([x, np.ones((1, 8), np.float32)])
    W = t @ A.T @ np.linalg.inv(A @ A.T)
    opt_loss = float(((W @ A - t) ** 2).mean())
    assert float(m["loss"]) < opt_loss + 1e-2


def test_grad_accumulation_equals_single_shot(rng):
    params = {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["t"]) ** 2)

    batch = {"x": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
             "t": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)}
    opt = adamw(AdamWConfig(lr=1e-2, weight_decay=0.0))
    s1 = train_state.create(params, opt)
    s2 = train_state.create(params, opt)
    st1, m1 = jax.jit(train_state.make_train_step(loss, opt))(s1, batch)
    st4, m4 = jax.jit(train_state.make_train_step(
        loss, opt, accum_steps=4))(s2, batch)
    for a, b in zip(jax.tree.leaves(st1["params"]),
                    jax.tree.leaves(st4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.train.optimizer import global_norm
    np.testing.assert_allclose(float(norm), np.sqrt(90 + 160), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, floor=0.1)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(lr(110)), 0.1, rtol=1e-5)
    assert float(lr(5)) == pytest.approx(0.5)


def test_moment_dtype_bf16():
    opt = adamw(AdamWConfig(moment_dtype=jnp.bfloat16))
    state = opt.init({"w": jnp.ones((4, 4), jnp.bfloat16)})
    assert state["m"]["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------------------
# Checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention(rng):
    state = {"params": {"w": jnp.asarray(rng.standard_normal((3, 3)),
                                         jnp.float32)},
             "step": jnp.asarray(7, jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, state, 7)
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        restored = ck.restore(d, like=like)
        np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                                   np.asarray(state["params"]["w"]))
        saver = ck.AsyncCheckpointer(d, keep=2)
        for s in (8, 9, 10):
            saver.save(state, s)
        saver.wait()
        kept = sorted(os.listdir(d))
        assert kept == ["step_00000009", "step_00000010"]
        assert ck.latest_step(d) == 10


def test_checkpoint_shape_mismatch_raises(rng):
    state = {"w": jnp.zeros((3, 3))}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, state, 1)
        bad = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
        with pytest.raises(ValueError):
            ck.restore(d, like=bad)


def test_checkpoint_restore_with_sharding(rng):
    """Elastic path: restore under an explicit sharding tree."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.sharding.Mesh(jax.devices()[:1], ("data",))
    state = {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, state, 1)
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        sh = {"w": NamedSharding(mesh, P(None, None))}
        restored = ck.restore(d, like=like, sharding_tree=sh)
        assert restored["w"].sharding == sh["w"]


# --------------------------------------------------------------------------
# Fault tolerance
# --------------------------------------------------------------------------

def test_run_with_restarts_recovers_and_replays(rng):
    """Inject a failure mid-run; the loop must restore the checkpoint
    and converge to EXACTLY the same state as an uninterrupted run
    (deterministic (seed, step) data stream)."""
    params, loss, _ = quad_problem(rng)
    opt = adamw(AdamWConfig(lr=0.05, weight_decay=0.0))
    raw = jax.jit(train_state.make_train_step(loss, opt))

    def make_stream(start):
        def gen():
            step = start
            while True:
                r = np.random.default_rng((42, step))
                yield {"x": jnp.asarray(r.standard_normal((4, 8)),
                                        jnp.float32),
                       "t": jnp.asarray(r.standard_normal((4, 8)),
                                        jnp.float32)}
                step += 1
        return gen()

    def run(fail_at, d):
        tripped = {"done": False}

        def step_fn(state, batch):
            if fail_at and int(state["step"]) == fail_at \
                    and not tripped["done"]:
                tripped["done"] = True
                raise SimulatedFailure("boom")
            return raw(state, batch)

        return run_with_restarts(
            init_state_fn=lambda: train_state.create(params, opt),
            step_fn=step_fn, stream_fn=make_stream, total_steps=40,
            ckpt_dir=d, ckpt_every=10, max_restarts=2)

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        clean = run(0, d1)
        faulty = run(25, d2)
    assert faulty.restarts == 1
    for a, b in zip(jax.tree.leaves(clean.final_state["params"]),
                    jax.tree.leaves(faulty.final_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


def test_run_with_restarts_gives_up():
    def step_fn(state, batch):
        raise SimulatedFailure("always")

    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(RuntimeError, match="max_restarts"):
            run_with_restarts(
                init_state_fn=lambda: {"step": jnp.zeros((), jnp.int32)},
                step_fn=step_fn, stream_fn=lambda s: iter([{}] * 100),
                total_steps=10, ckpt_dir=d, max_restarts=2)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=3.0)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 1.0)          # 10x slower -> flagged
    assert not wd.observe(11, 0.11)
    assert len(wd.slow_steps) == 1


def test_elastic_controller_policy():
    from repro.launch.elastic import ElasticController
    c = ElasticController(dp_width=16, min_steps_between=10)
    assert c.decide(100, healthy_hosts=16) is None      # no change
    assert c.decide(200, healthy_hosts=9) == 8          # shrink
    assert c.decide(205, healthy_hosts=16) is None      # hysteresis
    assert c.decide(400, healthy_hosts=16) == 16        # recover


# --------------------------------------------------------------------------
# Gradient compression
# --------------------------------------------------------------------------

def test_compression_error_feedback_property(rng):
    g = {"a": jnp.asarray(rng.standard_normal(512), jnp.float32)}
    res = zero_residual(g)
    q, sc, res2 = compress(g, res)
    deq = decompress(q, sc, g)
    # int8 error bounded by scale/2 per element
    err = np.abs(np.asarray(deq["a"]) - np.asarray(g["a"]))
    assert err.max() <= float(sc["a"]) * 0.5 + 1e-7
    # EF invariant: deq + residual == original
    np.testing.assert_allclose(np.asarray(deq["a"] + res2["a"]),
                               np.asarray(g["a"]), atol=1e-6)


def test_compressed_psum_single_device(rng):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("d",))
    g = {"a": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    res = zero_residual(g)

    def f(g, r):
        return compressed_psum(g, r, "d")

    out, new_res = shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_rep=False)(g, res)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(g["a"]), atol=2e-2)


# --------------------------------------------------------------------------
# Data pipeline
# --------------------------------------------------------------------------

def test_pipeline_determinism():
    a = dp.lm_batch(7, 3, 4, 16, 100)
    b = dp.lm_batch(7, 3, 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = dp.lm_batch(7, 4, 4, 16, 100)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_resume_matches():
    full = [dp.recsys_batch(1, s, 8, 5, (10, 20)) for s in range(5)]
    it = dp.recsys_batches(1, 8, 5, (10, 20), start_step=3)
    resumed = next(it)
    np.testing.assert_array_equal(full[3]["sparse_idx"],
                                  resumed["sparse_idx"])


def test_prefetcher_order_and_exception():
    it = dp.Prefetcher(iter([{"i": 1}, {"i": 2}, {"i": 3}]), depth=2)
    assert [b["i"] for b in it] == [1, 2, 3]

    def bad():
        yield {"i": 1}
        raise ValueError("stream died")

    it = dp.Prefetcher(bad())
    assert next(it)["i"] == 1
    with pytest.raises(ValueError, match="stream died"):
        next(it)


@pytest.mark.slow
def test_launcher_smoke_train_with_injected_failure(tmp_path):
    from repro.launch import train as lt
    rc = lt.main(["--arch", "dcn-v2", "--steps", "30", "--batch", "8",
                  "--ckpt", str(tmp_path / "ck"), "--ckpt-every", "10",
                  "--fail-at", "15"])
    assert rc == 0
