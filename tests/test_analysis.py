"""repro.analysis — the static invariant checker (DESIGN.md §11).

Three layers:
  * per-pass unit fixtures — every seeded bug class flags, every
    known-good twin stays quiet (including the scale-only asymmetry of
    the int32 edge-key overflow);
  * the full sweep — every registered backend traces and produces zero
    non-baselined findings on the clean tree (this is the CI gate run
    as a test);
  * the plumbing — suppression pragmas, baseline gating, the AST lint
    on synthetic sources, and the audited ``to_host`` sink.
"""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.analysis import BUCKETS, analyze, selftest
from repro.analysis.astlint import (_lint_facade_bypass,
                                    _lint_pallas_file)
from repro.analysis.findings import (Finding, apply_suppressions,
                                     load_baseline, write_baseline)
from repro.analysis.fixtures import CLEAN, EXPECTED, fixture_entries
from repro.analysis.jaxpr_utils import repo_root, trace
from repro.analysis.runner import analyze as _analyze

SMALL = {"small": BUCKETS["small"]}
SCALE = {"scale": BUCKETS["scale"]}


def _fixture(name):
    return next(e for e in fixture_entries() if e.name == name)


def _codes(report):
    return {(f.pass_id, f.code) for f in report.findings}


# ---------------------------------------------------------------------------
# Per-pass fixtures: known-bad flags, known-good doesn't
# ---------------------------------------------------------------------------

def test_int32_edge_key_overflow_flags_at_scale_only():
    entry = _fixture("fixture.int32_edge_key")
    at_small = analyze([entry], buckets=SMALL, run_astlint=False)
    at_scale = analyze([entry], buckets=SCALE, run_astlint=False)
    assert ("int32", "mul-overflow") not in _codes(at_small), \
        "CI-sized shapes must NOT flag (the overflow is exact there)"
    assert ("int32", "mul-overflow") in _codes(at_scale)
    # the finding is file:line anchored into this repo's sources
    f = next(f for f in at_scale.findings if f.code == "mul-overflow")
    assert f.severity == "error" and f.entry == "fixture.int32_edge_key"


def test_int32_fixed_edge_key_is_clean_at_scale():
    entry = _fixture("fixture.int32_edge_key_fixed")
    rep = analyze([entry], buckets=SCALE, run_astlint=False)
    assert not rep.findings


def test_transfer_pass_flags_host_sync_as_trace_failure():
    rep = analyze([_fixture("fixture.host_sync")], buckets=SMALL,
                  run_astlint=False)
    assert ("transfer", "trace-host-sync") in _codes(rep)


def test_transfer_pass_flags_pure_callback():
    rep = analyze([_fixture("fixture.host_callback")], buckets=SMALL,
                  run_astlint=False)
    assert ("transfer", "callback-pure_callback") in _codes(rep)


def test_padmask_flags_unmasked_sum_not_masked_twin():
    bad = analyze([_fixture("fixture.unmasked_padded_sum")],
                  buckets=SMALL, run_astlint=False)
    good = analyze([_fixture("fixture.masked_padded_sum")],
                   buckets=SMALL, run_astlint=False)
    assert ("padmask", "unmasked-padded-sum") in _codes(bad)
    assert not good.findings, [f.render() for f in good.findings]


def test_retrace_flags_nonpow2_shape_and_weak_typed_static():
    rep = analyze([_fixture("fixture.retrace_nonpow2")], buckets=SMALL,
                  run_astlint=False)
    codes = _codes(rep)
    assert ("retrace", "non-pow2-shape-arg0") in codes
    assert any(c.startswith("weak-typed-arg") for _, c in codes)


def test_selftest_green():
    assert selftest() == []


def test_expected_table_matches_fixture_registry():
    names = {e.name for e in fixture_entries()}
    assert set(EXPECTED) <= names and CLEAN <= names
    assert not (set(EXPECTED) & CLEAN)


# ---------------------------------------------------------------------------
# The full sweep: all backends, zero non-baselined findings
# ---------------------------------------------------------------------------

def test_every_backend_has_a_trace_entry():
    from repro.analysis.entries import all_entries
    from repro.api.registry import BACKENDS
    covered = {e.backend for e in all_entries() if e.backend}
    assert covered == set(BACKENDS), (
        f"backends without a trace spec: {set(BACKENDS) - covered}")
    assert len(BACKENDS) == 14


def test_full_sweep_is_clean_vs_committed_baseline():
    rep = _analyze()          # every entry, both buckets, all passes
    baseline = load_baseline(repo_root() / "analysis_baseline.json")
    new = rep.new_vs(baseline)
    assert not new, "NEW findings:\n" + "\n".join(
        f.render() for f in new)
    # the sweep actually saw the whole surface
    assert len(rep.entries_checked) >= 26
    assert set(rep.passes_run) == {"transfer", "int32", "retrace",
                                   "padmask", "pallas-ast"}


def test_all_entries_trace_at_both_buckets():
    from repro.analysis.entries import all_entries
    for entry in all_entries():
        for bucket in BUCKETS.values():
            t = trace(entry, bucket)
            assert t.failure is None, (
                f"{entry.name} failed to trace at {bucket}: "
                f"{t.failure and t.failure.message}")
            assert len(t.arg_info) == len(t.jaxpr.jaxpr.invars), \
                f"{entry.name}: VarInfo/arg arity mismatch"


# ---------------------------------------------------------------------------
# Suppression pragmas + baseline gating
# ---------------------------------------------------------------------------

def test_suppression_pragma_round_trip(tmp_path):
    src = tmp_path / "mod.py"
    src.write_text(
        "x = 1\n"
        "y = overflowing_thing()  # analysis: ok[int32]\n"
        "z = other_thing()\n")
    anchored = Finding("int32", "e", "error", "mul-overflow", "m",
                       "mod.py", 2)
    wrong_pass = Finding("padmask", "e", "error", "c", "m", "mod.py", 2)
    unanchored = Finding("int32", "e", "error", "c", "m", "mod.py", 3)
    kept, suppressed = apply_suppressions(
        [anchored, wrong_pass, unanchored], tmp_path)
    assert suppressed == [anchored]          # pragma is pass-scoped
    assert kept == [wrong_pass, unanchored]
    # line-above form
    src.write_text("# analysis: ok[int32, padmask]\nq = thing()\n")
    above = Finding("padmask", "e", "error", "c", "m", "mod.py", 2)
    kept, suppressed = apply_suppressions([above], tmp_path)
    assert suppressed == [above]


def test_repo_carries_the_audited_facade_bypass_suppression():
    # the one sanctioned engine-entry import (AOT lowering in launch/)
    # is acknowledged via pragma, not baseline — the sweep must report
    # it as suppressed, not as a finding
    rep = _analyze()
    assert any(f.code == "facade-bypass" for f in rep.suppressed)
    assert not any(f.code == "facade-bypass" for f in rep.findings)


def test_baseline_write_load_round_trip(tmp_path):
    rep = analyze([_fixture("fixture.unmasked_padded_sum")],
                  buckets=SMALL, run_astlint=False)
    assert rep.findings
    path = tmp_path / "baseline.json"
    write_baseline(path, rep)
    keys = load_baseline(path)
    assert keys == {f.key for f in rep.findings}
    assert rep.new_vs(keys) == []            # baselined == not new
    assert json.loads(path.read_text())["keys"] == sorted(keys)


def test_finding_key_is_line_stable():
    a = Finding("int32", "e", "error", "c", "msg 123", "f.py", 10)
    b = Finding("int32", "e", "error", "c", "msg 456", "f.py", 99)
    assert a.key == b.key


# ---------------------------------------------------------------------------
# AST lint on synthetic sources
# ---------------------------------------------------------------------------

def test_astlint_flags_gridless_pallas_call(tmp_path):
    bad = tmp_path / "k.py"
    bad.write_text("import jax\n"
                   "out = pl.pallas_call(kernel, out_shape=s)(x)\n")
    assert any(f.code == "pallas-no-static-grid"
               for f in _lint_pallas_file(bad, "k.py"))
    good = tmp_path / "g.py"
    good.write_text("out = pl.pallas_call(kernel, grid=(4,),\n"
                    "                     out_shape=s)(x)\n")
    assert not _lint_pallas_file(good, "g.py")


def test_astlint_flags_x64_dtype_in_kernel(tmp_path):
    f = tmp_path / "k.py"
    f.write_text("y = x.astype(jnp.int64)\n")
    assert any(f_.code == "kernel-int64"
               for f_ in _lint_pallas_file(f, "k.py"))


def test_astlint_flags_facade_bypass(tmp_path):
    f = tmp_path / "rogue.py"
    f.write_text("from repro.core.cc import solve_static\n")
    hits = _lint_facade_bypass(f, "src/repro/bench/rogue.py")
    assert [h.code for h in hits] == ["facade-bypass"]
    # engine packages themselves are allowed
    assert not _lint_facade_bypass(f, "src/repro/api/rogue.py")


def test_real_tree_astlint_is_quiet_outside_suppressions():
    from repro.analysis.astlint import run as ast_run
    findings = ast_run(repo_root())
    kept, _ = apply_suppressions(findings, repo_root())
    assert not kept, [f.render() for f in kept]


# ---------------------------------------------------------------------------
# The audited host sink
# ---------------------------------------------------------------------------

def test_to_host_materializes_and_rejects_tracers():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.connectivity.queries import to_host

    out = to_host(jnp.arange(4))
    assert isinstance(out, np.ndarray) and out.tolist() == [0, 1, 2, 3]

    def leaky(x):
        return to_host(x)                   # sync inside a trace: bug

    with pytest.raises(TypeError, match="to_host"):
        jax.make_jaxpr(leaky)(jnp.arange(4))


def test_cli_selftest_and_sweep_exit_zero(tmp_path):
    from repro.analysis.__main__ import main
    assert main(["--selftest"]) == 0
    out = tmp_path / "report.json"
    assert main(["--json", str(out)]) == 0   # clean tree, default baseline
    data = json.loads(out.read_text())
    assert data["findings"] == [] and len(data["entries"]) >= 26


def test_cli_gates_on_new_findings(tmp_path, capsys):
    # empty baseline + a seeded violation => exit 1 and a NEW line;
    # baselining the same report => exit 0
    from repro.analysis.__main__ import main

    import repro.analysis.runner as runner_mod
    bad_entry = _fixture("fixture.unmasked_padded_sum")
    orig = runner_mod.analyze

    def patched(entries=None, **kw):
        kw.setdefault("run_astlint", False)
        return orig([bad_entry], buckets=SMALL, **kw)

    baseline = tmp_path / "b.json"
    import repro.analysis.__main__ as cli
    old = cli.analyze
    cli.analyze = patched
    try:
        assert main(["--baseline", str(baseline)]) == 1
        assert "NEW error[padmask]" in capsys.readouterr().out
        assert main(["--baseline", str(baseline),
                     "--write-baseline"]) == 0
        assert main(["--baseline", str(baseline)]) == 0
    finally:
        cli.analyze = old
