"""GNN family: smoke forward/train per arch, NequIP E(3) properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.gnn import gatedgcn, gin, graphsage, nequip


def graph_batch(rng, V=48, E=160, d=16, classes=5, d_edge=8):
    return {
        "x": jnp.asarray(rng.standard_normal((V, d)), jnp.float32),
        "src": jnp.asarray(rng.integers(0, V, E), jnp.int32),
        "dst": jnp.asarray(rng.integers(0, V, E), jnp.int32),
        "edge_attr": jnp.asarray(rng.standard_normal((E, d_edge)),
                                 jnp.float32),
        "y": jnp.asarray(rng.integers(0, classes, V), jnp.int32),
        "node_mask": jnp.ones((V,), jnp.float32),
    }


def mol_batch(rng, G=4, V_per=6, E_per=10, n_species=4):
    V = G * V_per
    pos = rng.standard_normal((V, 3)) * 1.5
    e = rng.integers(0, V, (G * E_per, 2))
    return {
        "positions": jnp.asarray(pos, jnp.float32),
        "species": jnp.asarray(rng.integers(0, n_species, V), jnp.int32),
        "src": jnp.asarray(e[:, 0], jnp.int32),
        "dst": jnp.asarray(e[:, 1], jnp.int32),
        "graph_ids": jnp.asarray(np.repeat(np.arange(G), V_per),
                                 jnp.int32),
        "energy": jnp.asarray(rng.standard_normal(G), jnp.float32),
    }, pos


def test_graphsage_smoke(rng):
    cfg = get_arch("graphsage-reddit").make_smoke_config()
    p = graphsage.init(jax.random.PRNGKey(0), cfg)
    b = graph_batch(rng, d=cfg.d_in, classes=cfg.n_classes)
    out = graphsage.forward(p, b, cfg)
    assert out.shape == (48, cfg.n_classes)
    assert np.isfinite(float(graphsage.loss_fn(p, b, cfg)))


def test_graphsage_sampled_blocks(rng):
    cfg = get_arch("graphsage-reddit").make_smoke_config()
    p = graphsage.init(jax.random.PRNGKey(0), cfg)
    V = 32
    b = {
        "x": jnp.asarray(rng.standard_normal((V, cfg.d_in)), jnp.float32),
        "src_0": jnp.asarray(rng.integers(0, V, 64), jnp.int32),
        "dst_0": jnp.asarray(rng.integers(0, V, 64), jnp.int32),
        "src_1": jnp.asarray(rng.integers(0, V, 32), jnp.int32),
        "dst_1": jnp.asarray(rng.integers(0, V, 32), jnp.int32),
        "y": jnp.asarray(rng.integers(0, cfg.n_classes, V), jnp.int32),
        "node_mask": jnp.asarray(
            (np.arange(V) < 8).astype(np.float32)),
    }
    out = graphsage.forward_sampled(p, b, cfg)
    assert out.shape == (V, cfg.n_classes)
    assert np.isfinite(float(graphsage.loss_fn(p, b, cfg)))


def test_gin_graph_level(rng):
    cfg = get_arch("gin-tu").make_smoke_config()
    p = gin.init(jax.random.PRNGKey(0), cfg)
    b = graph_batch(rng, V=cfg.num_graphs * 6, d=cfg.d_in,
                    classes=cfg.n_classes)
    b["graph_ids"] = jnp.asarray(
        np.repeat(np.arange(cfg.num_graphs), 6), jnp.int32)
    b["y"] = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.n_classes,
                                          cfg.num_graphs), jnp.int32)
    out = gin.forward(p, b, cfg)
    assert out.shape == (cfg.num_graphs, cfg.n_classes)
    assert np.isfinite(float(gin.loss_fn(p, b, cfg)))


def test_gatedgcn_smoke(rng):
    cfg = get_arch("gatedgcn").make_smoke_config()
    p = gatedgcn.init(jax.random.PRNGKey(0), cfg)
    b = graph_batch(rng, d=cfg.d_in, classes=cfg.n_classes,
                    d_edge=cfg.d_edge_in)
    out = gatedgcn.forward(p, b, cfg)
    assert out.shape == (48, cfg.n_classes)
    assert np.isfinite(float(gatedgcn.loss_fn(p, b, cfg)))


# --------------------------------------------------------------------------
# NequIP physics properties
# --------------------------------------------------------------------------

@pytest.fixture
def nq(rng):
    cfg = get_arch("nequip").make_smoke_config()
    p = nequip.init(jax.random.PRNGKey(0), cfg)
    b, pos = mol_batch(rng, n_species=cfg.n_species)
    return cfg, p, b, pos


def test_nequip_rotation_invariance(nq, rng):
    cfg, p, b, pos = nq
    e0 = np.asarray(nequip.forward(p, b, cfg))
    A = rng.standard_normal((3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    b2 = {**b, "positions": jnp.asarray(pos @ Q.T, jnp.float32)}
    e1 = np.asarray(nequip.forward(p, b2, cfg))
    np.testing.assert_allclose(e1, e0, atol=5e-4)


def test_nequip_translation_invariance(nq):
    cfg, p, b, pos = nq
    e0 = np.asarray(nequip.forward(p, b, cfg))
    b2 = {**b, "positions": jnp.asarray(pos + 11.7, jnp.float32)}
    np.testing.assert_allclose(np.asarray(nequip.forward(p, b2, cfg)),
                               e0, atol=1e-5)


def test_nequip_force_equivariance(nq, rng):
    cfg, p, b, pos = nq
    f0 = np.asarray(nequip.forces(p, b, cfg))
    A = rng.standard_normal((3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    b2 = {**b, "positions": jnp.asarray(pos @ Q.T, jnp.float32)}
    f1 = np.asarray(nequip.forces(p, b2, cfg))
    np.testing.assert_allclose(f1, f0 @ Q.T, atol=5e-3)


def test_nequip_chunking_invariance(nq):
    cfg, p, b, pos = nq
    import dataclasses as dc
    e_big = np.asarray(nequip.forward(
        p, b, dc.replace(cfg, edge_chunk=1 << 20)))
    e_small = np.asarray(nequip.forward(
        p, b, dc.replace(cfg, edge_chunk=8)))
    np.testing.assert_allclose(e_big, e_small, atol=1e-5)


def test_gaunt_tables_selection_rules():
    tables = nequip.gaunt_tables(2)
    for (l1, l2, l3) in tables:
        assert abs(l1 - l2) <= l3 <= l1 + l2
        assert (l1 + l2 + l3) % 2 == 0
    # canonical value: (0,0,0) Gaunt = 1/(2 sqrt(pi))
    g000 = float(tables[(0, 0, 0)][0, 0, 0])
    np.testing.assert_allclose(g000, 0.28209479177387814,
                               rtol=1e-6)   # tables stored f32
    assert len(tables) == 11      # parity-even paths at l_max=2


def test_spherical_harmonics_orthonormal(rng):
    """∫ Y_lm Y_l'm' dΩ = δ — validated with the same quadrature."""
    n_u, n_phi = 8, 16
    u, wu = np.polynomial.legendre.leggauss(n_u)
    phi = 2 * np.pi * np.arange(n_phi) / n_phi
    uu, pp = np.meshgrid(u, phi, indexing="ij")
    st = np.sqrt(1 - uu ** 2)
    xyz = np.stack([st * np.cos(pp), st * np.sin(pp), uu], -1)
    sh = nequip._sh_np(xyz.reshape(-1, 3), 2)
    w = (wu[:, None] * (2 * np.pi / n_phi)).repeat(n_phi, 1).reshape(-1)
    flat = np.concatenate(sh, axis=-1)          # [N, 9]
    gram = np.einsum("n,na,nb->ab", w, flat, flat)
    np.testing.assert_allclose(gram, np.eye(9), atol=1e-12)
