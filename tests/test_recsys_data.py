"""DCN-v2 + EmbeddingBag substrate + graph utilities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.configs import get_arch
from repro.graphs.format import Graph, build_csr
from repro.graphs.generators import (disjoint_cliques, grid_road,
                                     molecule_batch, rmat, table1_scaled)
from repro.graphs.partition import partition_edges
from repro.graphs.sampler import MiniBatchLoader, sample_minibatch
from repro.models import recsys


@pytest.fixture
def dcn(rng):
    cfg = get_arch("dcn-v2").make_smoke_config()
    p = recsys.init(jax.random.PRNGKey(0), cfg)
    B = 16
    batch = {
        "dense": jnp.asarray(rng.standard_normal((B, cfg.n_dense)),
                             jnp.float32),
        "sparse_idx": jnp.asarray(
            np.stack([rng.integers(0, s, B) for s in cfg.table_sizes],
                     1), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, B), jnp.int32),
    }
    return cfg, p, batch


def test_dcn_forward_loss(dcn):
    cfg, p, batch = dcn
    logits = recsys.forward(p, batch, cfg)
    assert logits.shape == (16,)
    loss = float(recsys.loss_fn(p, batch, cfg))
    assert np.isfinite(loss) and loss > 0


def test_dcn_learns(dcn, rng):
    from repro.train import loop
    from repro.train.optimizer import adamw, AdamWConfig
    cfg, p, batch = dcn
    stream = iter(lambda: batch, None)
    state, _ = loop.fit(loss_fn=lambda pp, b: recsys.loss_fn(pp, b, cfg),
                        params=p, opt=adamw(AdamWConfig(lr=1e-2,
                                                        weight_decay=0)),
                        stream=stream, steps=60, log_every=60,
                        log_fn=lambda s: None)
    assert float(recsys.loss_fn(state["params"], batch, cfg)) < \
        float(recsys.loss_fn(p, batch, cfg))


def test_embedding_bag_matches_manual(rng):
    table = jnp.asarray(rng.standard_normal((50, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 50, 24), jnp.int32)
    bags = jnp.sort(jnp.asarray(rng.integers(0, 6, 24), jnp.int32))
    out = recsys.embedding_bag(table, idx, bags, 6)
    want = np.zeros((6, 8), np.float32)
    for i, b in zip(np.asarray(idx), np.asarray(bags)):
        want[b] += np.asarray(table)[i]
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)


def test_fused_lookup_offsets(dcn, rng):
    cfg, p, batch = dcn
    offs = cfg.row_offsets
    # feature f row i lives at offs[f] + i in the fused table
    emb = recsys.fused_lookup(p["table"], batch["sparse_idx"],
                              jnp.asarray(offs))
    f, i = 2, 5
    row = int(batch["sparse_idx"][i, f])
    np.testing.assert_allclose(
        np.asarray(emb[i, f]),
        np.asarray(p["table"][offs[f] + row]), atol=1e-6)


def test_multihot_reduces(dcn, rng):
    cfg, p, batch = dcn
    hot = jnp.asarray(np.stack(
        [rng.integers(0, s, (16, 3)) for s in cfg.table_sizes], 1),
        jnp.int32)
    out = recsys.forward(p, {**batch, "sparse_idx": hot}, cfg)
    assert out.shape == (16,)


def test_retrieval_scores(dcn):
    cfg, p, batch = dcn
    q = {k: v[:1] for k, v in batch.items()}
    scores = recsys.retrieval_scores(p, q, cfg,
                                     jnp.arange(64, dtype=jnp.int32))
    assert scores.shape == (64,)
    assert np.isfinite(np.asarray(scores)).all()


def test_padded_tables_divisible():
    cfg = get_arch("dcn-v2").make_config()
    assert cfg.total_rows % 16 == 0
    assert all(s % 16 == 0 for s in cfg.padded_table_sizes)


# --------------------------------------------------------------------------
# Graph substrate
# --------------------------------------------------------------------------

def test_csr_roundtrip(rng):
    edges = rng.integers(0, 20, (60, 2))
    csr = build_csr(edges, 20)
    # every edge present in both directions
    for u, v in edges:
        assert v in csr.neighbors(u)
        assert u in csr.neighbors(v)


def test_sampler_shapes_and_determinism():
    g = rmat(8, 8, seed=0)
    csr = g.to_csr()
    rng1 = np.random.default_rng(5)
    rng2 = np.random.default_rng(5)
    seeds = np.arange(32)
    mb1 = sample_minibatch(csr, seeds, [15, 10], rng1)
    mb2 = sample_minibatch(csr, seeds, [15, 10], rng2)
    assert len(mb1.blocks) == 2
    np.testing.assert_array_equal(mb1.blocks[0].src, mb2.blocks[0].src)
    assert mb1.blocks[1].src.shape == (32 * 10,)
    # sampled neighbors are real neighbors (or self for isolated)
    blk = mb1.blocks[1]
    for s, d in zip(blk.src[:50], blk.dst[:50]):
        assert s == d or s in csr.neighbors(d)


def test_minibatch_loader_epochs():
    g = rmat(7, 4, seed=1)
    loader = MiniBatchLoader(g.to_csr(), np.arange(64), batch_size=16,
                             fanouts=[5, 5], seed=3)
    batches = list(loader.epoch(0))
    assert len(batches) == 4
    again = list(loader.epoch(0))
    np.testing.assert_array_equal(batches[0].seed_nodes,
                                  again[0].seed_nodes)


def test_partition_edges_covers_all():
    g = disjoint_cliques(4, 5)
    parts = partition_edges(g, 4)
    assert parts.shape[0] == 4
    flat = parts.reshape(-1, 2)
    # all original edges present (padding is (0,0))
    orig = {tuple(e) for e in g.edges.tolist()}
    got = {tuple(e) for e in flat.tolist()}
    assert orig <= got


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 30), st.integers(0, 100), st.integers(1, 8))
def test_partition_preserves_cc(n, e, parts):
    from repro.core.cc import connected_components
    from repro.core.unionfind import connected_components_oracle
    rng = np.random.default_rng(42)
    edges = rng.integers(0, n, (e, 2)).astype(np.int32)
    g = Graph(edges=edges, num_nodes=n)
    p = partition_edges(g, parts)
    got = connected_components(p.reshape(-1, 2), n)
    want = connected_components_oracle(edges, n)
    np.testing.assert_array_equal(np.asarray(got.labels), want)


def test_table1_scaled_degree_regimes():
    road = table1_scaled("usa-osm", scale=1 / 1024)
    kron = table1_scaled("kron-logn21", scale=1 / 256)
    assert road.avg_degree < 4.0
    assert kron.avg_degree > 20.0
    assert kron.max_degree > 50 * kron.avg_degree / 10


def test_molecule_batch_block_diagonal():
    g = molecule_batch(8, 10, 14, seed=0)
    blocks = g.edges // 10
    assert (blocks[:, 0] == blocks[:, 1]).all()
