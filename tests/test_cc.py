"""The paper's core: Connected Components correctness + work-efficiency
properties, all variants, against the union-find oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core.cc import (METHODS, WorkCounters, connected_components,
                           connected_components_hostloop,
                           connected_components_pallas, num_components)
from repro.core.segmentation import (adaptive_num_segments,
                                     plan_segmentation)
from repro.core.unionfind import connected_components_oracle
from repro.graphs import generators as G


def oracle_check(edges, n, **kw):
    want = connected_components_oracle(edges, n)
    for m in METHODS:
        got = connected_components(edges, n, method=m, **kw)
        np.testing.assert_array_equal(
            np.asarray(got.labels), want, err_msg=f"method={m}")
    return want


# --------------------------------------------------------------------------
# Deterministic structure tests
# --------------------------------------------------------------------------

def test_empty_graph():
    for m in METHODS:
        r = connected_components(np.zeros((0, 2)), 5, method=m)
        np.testing.assert_array_equal(np.asarray(r.labels),
                                      np.arange(5))


def test_zero_nodes():
    r = connected_components(np.zeros((0, 2)), 0)
    assert r.labels.shape == (0,)


def test_chain_star_cliques(rng):
    for g in (G.chain(17), G.star(9), G.disjoint_cliques(4, 5),
              G.grid_road(8, seed=1)):
        oracle_check(g.edges, g.num_nodes)


def test_self_loops_and_duplicates():
    edges = np.array([[0, 0], [1, 2], [1, 2], [2, 1], [3, 3]])
    want = oracle_check(edges, 5)
    assert num_components(want) == 4   # {0},{1,2},{3},{4}


def test_labels_are_canonical_minima(rng):
    g = G.rmat(8, 4, seed=3)
    r = connected_components(g.edges, g.num_nodes)
    labels = np.asarray(r.labels)
    for comp in np.unique(labels):
        members = np.where(labels == comp)[0]
        assert comp == members.min()


# --------------------------------------------------------------------------
# Property tests (hypothesis)
# --------------------------------------------------------------------------

edge_lists = st.integers(2, 40).flatmap(
    lambda n: st.tuples(
        st.just(n),
        st.lists(st.tuples(st.integers(0, n - 1),
                           st.integers(0, n - 1)),
                 min_size=0, max_size=120)))


@settings(max_examples=30, deadline=None)
@given(edge_lists)
def test_all_methods_match_oracle(case):
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    oracle_check(edges, n)


@settings(max_examples=15, deadline=None)
@given(edge_lists, st.integers(0, 2**31 - 1))
def test_permutation_equivariance(case, seed):
    """Relabeling vertices by a permutation perm maps component labels
    consistently: labels'(perm[v]) == min(perm[component of v])."""
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    perm = np.random.default_rng(seed).permutation(n).astype(np.int32)
    base = np.asarray(connected_components(edges, n).labels)
    permuted = np.asarray(
        connected_components(perm[edges] if len(edges) else edges,
                             n).labels)
    for v in range(n):
        comp = np.where(base == base[v])[0]
        assert permuted[perm[v]] == perm[comp].min()


@settings(max_examples=15, deadline=None)
@given(edge_lists)
def test_idempotent_relabel(case):
    """Running CC on (v, label(v)) edges reproduces the same labels."""
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    labels = np.asarray(connected_components(edges, n).labels)
    star_edges = np.stack([np.arange(n, dtype=np.int32), labels], 1)
    again = np.asarray(connected_components(star_edges, n).labels)
    np.testing.assert_array_equal(labels, again)


@settings(max_examples=10, deadline=None)
@given(edge_lists, st.integers(1, 9))
def test_segment_count_does_not_change_answer(case, s):
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    want = connected_components_oracle(edges, n)
    got = connected_components(edges, n, method="adaptive",
                               num_segments=s)
    np.testing.assert_array_equal(np.asarray(got.labels), want)


# --------------------------------------------------------------------------
# Work-efficiency claims (the paper's currency)
# --------------------------------------------------------------------------

def test_adaptive_heuristic_value():
    assert adaptive_num_segments(58_000_000, 24_000_000) == 5   # usa-osm
    assert adaptive_num_segments(182_000_000, 2_000_000) == 182
    assert adaptive_num_segments(10, 1000) == 1


def test_segmentation_plan_covers_edges():
    plan = plan_segmentation(1000, 300)
    assert plan.num_segments == adaptive_num_segments(1000, 300)
    assert plan.num_segments * plan.segment_size >= 1000


def test_multijump_reduces_syncs_vs_soman():
    """Fig. 5 mechanism: Multi-Jump removes the per-sweep host
    convergence checks of the Soman baseline."""
    g = G.grid_road(24, seed=2)
    soman = connected_components(g.edges, g.num_nodes, method="soman")
    mj = connected_components(g.edges, g.num_nodes, method="multijump")
    assert int(mj.work.sync_rounds) < int(soman.work.sync_rounds)
    np.testing.assert_array_equal(np.asarray(soman.labels),
                                  np.asarray(mj.labels))


def test_atomic_hook_single_pass_on_easy_graph():
    """Atomic-Hook (root chase) connects a star in one hook round."""
    g = G.star(64)
    r = connected_components(g.edges, g.num_nodes, method="atomic_hook")
    assert int(r.work.hook_rounds) <= 2
    assert num_components(r.labels) == 1


def test_adaptive_fewer_jump_sweeps_than_multijump_on_road():
    """Intermediate compressions shorten chases on high-diameter
    graphs (the paper's road-map speedup mechanism)."""
    g = G.grid_road(40, extra_prob=0.0, seed=5)
    mj = connected_components(g.edges, g.num_nodes, method="multijump")
    ad = connected_components(g.edges, g.num_nodes, method="adaptive")
    assert int(ad.work.jump_sweeps) <= int(mj.work.jump_sweeps) * 2
    np.testing.assert_array_equal(np.asarray(mj.labels),
                                  np.asarray(ad.labels))


def test_hostloop_matches_and_counts_syncs():
    g = G.disjoint_cliques(3, 6, seed=0)
    labels, stats = connected_components_hostloop(
        g.edges, g.num_nodes, method="soman")
    np.testing.assert_array_equal(
        labels, connected_components_oracle(g.edges, g.num_nodes))
    assert stats["sync_rounds"] >= stats["hook_rounds"]


# --------------------------------------------------------------------------
# Pallas kernel backend
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(edge_lists)
def test_pallas_backend_matches_oracle(case):
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    want = connected_components_oracle(edges, n)
    got = connected_components_pallas(edges, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_pallas_on_structured_graphs():
    for g in (G.grid_road(12, seed=7), G.rmat(7, 4, seed=7),
              G.disjoint_cliques(5, 4)):
        want = connected_components_oracle(g.edges, g.num_nodes)
        got = connected_components_pallas(g.edges, g.num_nodes,
                                          interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want)
