"""The paper's core: Connected Components correctness + work-efficiency
properties, all variants, against the union-find oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _graphgen import edge_lists
from _propcheck import given, settings, st

from repro.core.cc import (METHODS, WorkCounters, connected_components,
                           connected_components_hostloop,
                           connected_components_pallas, num_components)
from repro.core.segmentation import (adaptive_num_segments,
                                     plan_segmentation)
from repro.core.unionfind import connected_components_oracle
from repro.graphs import generators as G


def oracle_check(edges, n, **kw):
    want = connected_components_oracle(edges, n)
    for m in METHODS:
        got = connected_components(edges, n, method=m, **kw)
        np.testing.assert_array_equal(
            np.asarray(got.labels), want, err_msg=f"method={m}")
    return want


# --------------------------------------------------------------------------
# Deterministic structure tests
# --------------------------------------------------------------------------

def test_empty_graph():
    for m in METHODS:
        r = connected_components(np.zeros((0, 2)), 5, method=m)
        np.testing.assert_array_equal(np.asarray(r.labels),
                                      np.arange(5))


def test_zero_nodes():
    r = connected_components(np.zeros((0, 2)), 0)
    assert r.labels.shape == (0,)


def test_chain_star_cliques(rng):
    for g in (G.chain(17), G.star(9), G.disjoint_cliques(4, 5),
              G.grid_road(8, seed=1)):
        oracle_check(g.edges, g.num_nodes)


def test_self_loops_and_duplicates():
    edges = np.array([[0, 0], [1, 2], [1, 2], [2, 1], [3, 3]])
    want = oracle_check(edges, 5)
    assert num_components(want) == 4   # {0},{1,2},{3},{4}


def test_labels_are_canonical_minima(rng):
    g = G.rmat(8, 4, seed=3)
    r = connected_components(g.edges, g.num_nodes)
    labels = np.asarray(r.labels)
    for comp in np.unique(labels):
        members = np.where(labels == comp)[0]
        assert comp == members.min()


# --------------------------------------------------------------------------
# Property tests (hypothesis) — cases drawn from the shared _graphgen
# strategies so every suite fuzzes one distribution
# --------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(edge_lists)
def test_all_methods_match_oracle(case):
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    oracle_check(edges, n)


@settings(max_examples=15, deadline=None)
@given(edge_lists, st.integers(0, 2**31 - 1))
def test_permutation_equivariance(case, seed):
    """Relabeling vertices by a permutation perm maps component labels
    consistently: labels'(perm[v]) == min(perm[component of v])."""
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    perm = np.random.default_rng(seed).permutation(n).astype(np.int32)
    base = np.asarray(connected_components(edges, n).labels)
    permuted = np.asarray(
        connected_components(perm[edges] if len(edges) else edges,
                             n).labels)
    for v in range(n):
        comp = np.where(base == base[v])[0]
        assert permuted[perm[v]] == perm[comp].min()


@settings(max_examples=15, deadline=None)
@given(edge_lists)
def test_idempotent_relabel(case):
    """Running CC on (v, label(v)) edges reproduces the same labels."""
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    labels = np.asarray(connected_components(edges, n).labels)
    star_edges = np.stack([np.arange(n, dtype=np.int32), labels], 1)
    again = np.asarray(connected_components(star_edges, n).labels)
    np.testing.assert_array_equal(labels, again)


@settings(max_examples=10, deadline=None)
@given(edge_lists, st.integers(1, 9))
def test_segment_count_does_not_change_answer(case, s):
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    want = connected_components_oracle(edges, n)
    got = connected_components(edges, n, method="adaptive",
                               num_segments=s)
    np.testing.assert_array_equal(np.asarray(got.labels), want)


# --------------------------------------------------------------------------
# Work-efficiency claims (the paper's currency)
# --------------------------------------------------------------------------

def test_adaptive_heuristic_value():
    assert adaptive_num_segments(58_000_000, 24_000_000) == 5   # usa-osm
    assert adaptive_num_segments(182_000_000, 2_000_000) == 182
    assert adaptive_num_segments(10, 1000) == 1


def test_segmentation_plan_covers_edges():
    plan = plan_segmentation(1000, 300)
    assert plan.num_segments == adaptive_num_segments(1000, 300)
    assert plan.num_segments * plan.segment_size >= 1000


def test_multijump_reduces_syncs_vs_soman():
    """Fig. 5 mechanism: Multi-Jump removes the per-sweep host
    convergence checks of the Soman baseline."""
    g = G.grid_road(24, seed=2)
    soman = connected_components(g.edges, g.num_nodes, method="soman")
    mj = connected_components(g.edges, g.num_nodes, method="multijump")
    assert int(mj.work.sync_rounds) < int(soman.work.sync_rounds)
    np.testing.assert_array_equal(np.asarray(soman.labels),
                                  np.asarray(mj.labels))


def test_atomic_hook_single_pass_on_easy_graph():
    """Atomic-Hook (root chase) connects a star in one hook round."""
    g = G.star(64)
    r = connected_components(g.edges, g.num_nodes, method="atomic_hook")
    assert int(r.work.hook_rounds) <= 2
    assert num_components(r.labels) == 1


def test_adaptive_fewer_jump_sweeps_than_multijump_on_road():
    """Intermediate compressions shorten chases on high-diameter
    graphs (the paper's road-map speedup mechanism)."""
    g = G.grid_road(40, extra_prob=0.0, seed=5)
    mj = connected_components(g.edges, g.num_nodes, method="multijump")
    ad = connected_components(g.edges, g.num_nodes, method="adaptive")
    assert int(ad.work.jump_sweeps) <= int(mj.work.jump_sweeps) * 2
    np.testing.assert_array_equal(np.asarray(mj.labels),
                                  np.asarray(ad.labels))


def test_hostloop_matches_and_counts_syncs():
    g = G.disjoint_cliques(3, 6, seed=0)
    labels, stats = connected_components_hostloop(
        g.edges, g.num_nodes, method="soman")
    np.testing.assert_array_equal(
        labels, connected_components_oracle(g.edges, g.num_nodes))
    assert stats["sync_rounds"] >= stats["hook_rounds"]


# --------------------------------------------------------------------------
# Pallas kernel backend
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(edge_lists)
def test_pallas_backend_matches_oracle(case):
    n, edges = case
    edges = np.asarray(edges, np.int32).reshape(-1, 2)
    want = connected_components_oracle(edges, n)
    got = connected_components_pallas(edges, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_pallas_on_structured_graphs():
    for g in (G.grid_road(12, seed=7), G.rmat(7, 4, seed=7),
              G.disjoint_cliques(5, 4)):
        want = connected_components_oracle(g.edges, g.num_nodes)
        got = connected_components_pallas(g.edges, g.num_nodes,
                                          interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want)


# --------------------------------------------------------------------------
# Fused Pallas backend (method="pallas_fused"): one launch per segment scan
# --------------------------------------------------------------------------

def _fused_oracle_matrix():
    """The propcheck oracle matrix: RMAT, grid-road, star, disconnected."""
    return (G.rmat(7, 4, seed=11), G.grid_road(10, seed=11), G.star(33),
            G.disjoint_cliques(4, 6, seed=11))


def test_pallas_fused_bit_identical_to_jnp_backend():
    """Acceptance: labels bit-identical to the jnp backend on the oracle
    matrix — and the work counters match too (same hooks, same sweeps)."""
    for g in _fused_oracle_matrix():
        want = connected_components_oracle(g.edges, g.num_nodes)
        jnp_res = connected_components(g.edges, g.num_nodes,
                                       method="adaptive")
        fused = connected_components(g.edges, g.num_nodes,
                                     method="pallas_fused")
        np.testing.assert_array_equal(np.asarray(fused.labels), want,
                                      err_msg=g.name)
        np.testing.assert_array_equal(np.asarray(fused.labels),
                                      np.asarray(jnp_res.labels),
                                      err_msg=g.name)
        for field, a, b in zip(WorkCounters._fields, fused.work,
                               jnp_res.work):
            assert int(a) == int(b), (g.name, field, int(a), int(b))


def _subjaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _subjaxprs(x)


def _pallas_call_sites(jaxpr) -> int:
    """Static pallas_call call sites in a jaxpr (recursing through
    pjit/scan/while sub-jaxprs)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                n += _pallas_call_sites(sub)
    return n


def _launch_lower_bound(jaxpr) -> int:
    """Lower bound on runtime kernel launches: scan bodies multiply by
    their static trip count; while bodies count once (>= 1 trip for the
    compress loop, whose first sweep always runs)."""
    n = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            n += 1
        elif name == "scan":
            n += eqn.params["length"] * _launch_lower_bound(
                eqn.params["jaxpr"].jaxpr)
        else:
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    n += _launch_lower_bound(sub)
    return n


def test_fused_single_launch_vs_per_round_backend():
    """Acceptance: the fused path issues 1 pallas_call per segment scan
    where the per-round backend issued >= num_segments + jump_sweeps
    launches (one hook launch per segment + one multi_jump launch per
    compress sweep)."""
    import jax.numpy as jnp
    from repro.core import cc as cc_mod
    from repro.core import rounds
    from repro.graphs.device import as_device_graph
    from repro.kernels.cc_fused.ops import fused_segment_scan

    g = G.rmat(7, 4, seed=5)
    dg = as_device_graph(g)
    plan = dg.plan
    assert plan.num_segments > 1          # a real multi-segment scan
    segments = rounds.pad_and_segment(dg.edges, plan)
    counts = rounds.segment_true_counts(plan.num_edges, plan)
    pi0 = jnp.arange(g.num_nodes, dtype=jnp.int32)

    # fused: the WHOLE segment scan is ONE pallas_call
    fused_jaxpr = jax.make_jaxpr(
        lambda p, s, c: fused_segment_scan(p, s, c, interpret=True))(
            pi0, segments, counts).jaxpr
    assert _pallas_call_sites(fused_jaxpr) == 1

    # per-round backend: its hook launch is nested under the segment
    # scan (x num_segments at runtime) and its compress launch under the
    # sweep loop (x per-segment sweeps at runtime)
    old_jaxpr = jax.make_jaxpr(
        lambda e: cc_mod._cc_adaptive_pallas(
            e, num_nodes=g.num_nodes, num_segments=plan.num_segments,
            lift_steps=2, interpret=True))(dg.edges).jaxpr
    assert _launch_lower_bound(old_jaxpr) >= plan.num_segments + 1

    # scan-only sweep count from the fused kernel's counters (verified
    # bit-compatible with the jnp composition in the sibling test):
    # every segment compresses at least once, so the per-round backend's
    # num_segments hook launches + one launch per sweep dominate the
    # fused path's single launch many times over
    _, sweeps = fused_segment_scan(pi0, segments, counts, interpret=True)
    scan_sweeps = int(sweeps.sum())
    assert scan_sweeps >= plan.num_segments
    old_scan_launches = plan.num_segments + scan_sweeps
    assert old_scan_launches >= 2 * plan.num_segments > 2
    assert _pallas_call_sites(fused_jaxpr) < old_scan_launches


def test_fused_kernel_matches_ref_sweep_counts():
    """The fused kernel's per-segment sweep counters equal the jnp
    composition's exactly (work billing is bit-compatible)."""
    import jax.numpy as jnp
    from repro.core import rounds
    from repro.graphs.device import as_device_graph
    from repro.kernels.cc_fused.ops import fused_segment_scan
    from repro.kernels.cc_fused.ref import ref_segment_scan

    g = G.grid_road(9, seed=4)
    dg = as_device_graph(g)
    segments = rounds.pad_and_segment(dg.edges, dg.plan)
    counts = rounds.segment_true_counts(dg.plan.num_edges, dg.plan)
    pi0 = jnp.arange(g.num_nodes, dtype=jnp.int32)
    got_pi, sweeps = fused_segment_scan(pi0, segments, counts,
                                        interpret=True)
    ref_pi, ref_work = ref_segment_scan(pi0, segments, counts)
    np.testing.assert_array_equal(np.asarray(got_pi), np.asarray(ref_pi))
    assert int(sweeps.sum()) == int(ref_work.jump_sweeps)
