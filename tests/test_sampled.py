"""The sampling-accelerated backends (ISSUE 8): k-out sampling phase +
residue scan, the degree-skew policy feature, the phase-split
telemetry, and the headline work reduction.

Conformance (labels vs both oracles over the corpus) lives in
``test_conformance.py``'s matrix — ``sampled`` / ``sampled_fused`` are
ordinary rows there, and the spanning-forest property test covers the
forest product. This module pins what is SPECIFIC to sampling:

* ``DeviceGraph.degree_skew`` — measured once at host ingest, ``None``
  for device-resident arrays, preserved across the pytree protocol;
* the policy routing rule — ``method="auto"`` picks ``sampled`` on
  skewed-degree graphs at scale and never on road-like or corpus-sized
  inputs;
* the acceptance criterion — ``sampled`` total hook_ops <= half the
  ``jnp`` adaptive backend's on a power-law stand-in, labels identical;
* the ``repro.obs`` work split (``sampled.hook_ops.sample`` /
  ``.residue`` always-on counters) and the per-plan
  ``sampled_stats`` artifact.
"""
import numpy as np
import pytest

from _graphgen import power_law
from repro.api import Solver, solve
from repro.connectivity import policy
from repro.core.unionfind import connected_components_oracle


def _powerlaw_edges(n, e, seed=7):
    return np.asarray(power_law(n, e, seed), np.int32)


def _grid_edges(side):
    """Road-network stand-in: a 2D grid (skew ~= 2, tiny diameter of
    degree variation)."""
    idx = np.arange(side * side).reshape(side, side)
    return np.concatenate([
        np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], -1),
        np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], -1),
    ]).astype(np.int32)


# ---------------------------------------------------------------------------
# degree_skew: static metadata, measured at host ingest only
# ---------------------------------------------------------------------------

def test_degree_skew_measured_at_host_ingest():
    import jax
    import jax.numpy as jnp

    from repro.graphs.device import DeviceGraph

    star = np.stack([np.zeros(63, np.int64), np.arange(1, 64)], -1)
    g = DeviceGraph.from_edges(star, 64)
    # deg(hub) = 63, mean degree = 2*63/64 -> skew = exactly 32
    assert g.degree_skew == pytest.approx(32.0)

    # device-resident arrays skip the measurement (no transfer)
    g_dev = DeviceGraph.from_edges(jnp.asarray(star, jnp.int32), 64)
    assert g_dev.degree_skew is None

    # the skew rides the pytree aux data across jit boundaries
    leaves, treedef = jax.tree_util.tree_flatten(g)
    assert jax.tree_util.tree_unflatten(treedef, leaves).degree_skew \
        == pytest.approx(32.0)

    # ...and survives the device-side shaping helpers
    assert g.pad_pow2().degree_skew == pytest.approx(32.0)

    # degenerate inputs do not divide by zero
    assert DeviceGraph.from_edges(np.zeros((0, 2), np.int32),
                                  5).degree_skew == 1.0


def test_degree_skew_separates_skewed_from_road_like():
    from repro.graphs.device import measure_degree_skew
    skew_pl = measure_degree_skew(_powerlaw_edges(1024, 8192), 1024)
    skew_grid = measure_degree_skew(_grid_edges(32), 1024)
    assert skew_pl >= policy.SAMPLED_SKEW, skew_pl
    assert skew_grid < policy.SAMPLED_SKEW, skew_grid


# ---------------------------------------------------------------------------
# Policy routing: skewed at scale -> sampled; road/corpus-sized -> not
# ---------------------------------------------------------------------------

def test_policy_routes_sampled_on_skewed_graphs_at_scale():
    f = policy.extract_features
    assert policy.heuristic_method(
        f(10_000, 80_000, degree_skew=50.0)) == "sampled"
    # road-like skew: never sampled
    assert policy.heuristic_method(
        f(10_000, 80_000, degree_skew=2.0)) == "adaptive"
    # below the edge floor: the exact engines win (two extra jit
    # launches don't pay for themselves)
    assert policy.heuristic_method(
        f(512, 2_000, degree_skew=50.0)) != "sampled"
    # unmeasured skew (device-resident ingest): no sampling route
    assert policy.heuristic_method(f(10_000, 80_000)) == "adaptive"
    # select_method threads the kwarg through the explained path
    assert policy.select_method(10_000, 80_000, degree_skew=50.0,
                                cache=policy.AutotuneCache()) == "sampled"
    # the sampled engine is an autotune candidate
    assert "sampled" in policy.AUTOTUNE_METHODS


def test_auto_plan_picks_sampled_for_powerlaw_host_ingest():
    edges = _powerlaw_edges(1024, 8192)
    s = Solver.open(edges, 1024, policy_cache=policy.AutotuneCache())
    plan = s.plan()
    assert plan.backend == "sampled"
    assert plan.reason == "heuristic"
    assert plan.predicted["degree_skew"] >= policy.SAMPLED_SKEW
    assert "sampled" in plan.explain()

    # a road-like graph of the same size stays on the exact engines
    road = Solver.open(_grid_edges(32), 1024,
                       policy_cache=policy.AutotuneCache())
    assert road.plan().backend != "sampled"


# ---------------------------------------------------------------------------
# Acceptance criterion: >= 2x hook_ops reduction, labels identical
# ---------------------------------------------------------------------------

def test_sampled_halves_hook_ops_on_skewed_stand_in():
    n, e = 1024, 8192
    edges = _powerlaw_edges(n, e)
    want = connected_components_oracle(edges, n)

    base = solve(edges, n, backend="adaptive")
    samp = solve(edges, n, backend="sampled")
    np.testing.assert_array_equal(np.asarray(base.labels), want)
    np.testing.assert_array_equal(np.asarray(samp.labels), want)
    assert 2 * int(samp.work.hook_ops) <= int(base.work.hook_ops), (
        int(samp.work.hook_ops), int(base.work.hook_ops))


def test_sampled_stats_artifact_shows_phase_split():
    n, e = 1024, 8192
    s = Solver.open(_powerlaw_edges(n, e), n)
    res = s.solve(backend="sampled")
    stats = s.last_plan.artifacts["sampled_stats"]
    assert set(stats) == {"sample_hook_ops", "residue_hook_ops",
                          "n_sampled", "n_residue", "giant_label",
                          "giant_size"}
    # phase billing folds exactly into the total
    assert stats["sample_hook_ops"] + stats["residue_hook_ops"] \
        == int(res.work.hook_ops)
    # the sampling phase did collapse a giant component: the residue is
    # a small fraction of the edge list
    assert stats["giant_size"] > n // 2
    assert stats["n_residue"] < e // 4
    # k-out sampling touches at most V*k slots per round
    from repro.core.sampled import SAMPLE_K
    assert stats["n_sampled"] <= n * SAMPLE_K


def test_sampled_obs_counters_record_work_split():
    from repro.obs import trace as obs
    before = dict(obs.tracer().counters)
    solve(_powerlaw_edges(256, 1024, seed=9), 256, backend="sampled")
    counters = obs.tracer().counters
    for key in ("sampled.solves", "sampled.hook_ops.sample"):
        assert counters.get(key, 0) > before.get(key, 0), key
    # the residue side may legitimately bill 0 (the sampling phase can
    # fully collapse a small graph) but the counter must be surfaced
    assert "sampled.hook_ops.residue" in counters


def test_sampled_fused_matches_sampled_labels_and_counters():
    """The fused-residue variant is label-identical; its counters match
    the jnp-residue variant's (both bill true work only)."""
    from repro.core.rounds import WorkCounters
    n, e = 512, 4096
    edges = _powerlaw_edges(n, e, seed=11)
    a = solve(edges, n, backend="sampled")
    b = solve(edges, n, backend="sampled_fused")
    np.testing.assert_array_equal(np.asarray(a.labels),
                                  np.asarray(b.labels))
    for field, x, y in zip(WorkCounters._fields, a.work, b.work):
        assert int(x) == int(y), (field, int(x), int(y))
